"""Pure-JAX optimizers (no optax dependency): AdamW with decoupled weight
decay and global-norm gradient clipping, over arbitrary param pytrees.
Optimizer state moments are kept in float32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * \
        0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params,
                 trainable_mask=None):
    """Returns (new_params, new_opt_state, metrics). `trainable_mask` is an
    optional pytree of bools — frozen leaves pass through unchanged (used
    for LoRA-only fine-tuning of a frozen base model)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    if trainable_mask is None:
        trainable_mask = jax.tree.map(lambda _: True, params)

    def upd(p, g, mu, nu, t):
        g32 = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        p2 = (p.astype(jnp.float32) - delta).astype(p.dtype)
        keep = jnp.asarray(t)
        return (jnp.where(keep, p2, p), jnp.where(keep, mu2, mu),
                jnp.where(keep, nu2, nu))

    out = jax.tree.map(upd, params, grads, opt_state["mu"],
                       opt_state["nu"], trainable_mask)
    treedef = jax.tree.structure(params)
    leaves = treedef.flatten_up_to(out)
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_mu = treedef.unflatten([l[1] for l in leaves])
    new_nu = treedef.unflatten([l[2] for l in leaves])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gn, "lr": lr}
