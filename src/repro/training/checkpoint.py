"""Msgpack-based checkpointing for arbitrary pytrees of arrays.

Layout: one .msgpack file holding {flat_key: {dtype, shape, data}} plus a
'treedef' discriminator via the flat key paths — robust across runs
without pickling python objects.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/[{i}]"))
    else:
        out[prefix] = tree
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    flat = _flatten(tree)
    payload = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    flat_like = _flatten(like)
    restored = {}
    for k, v in flat_like.items():
        ent = payload[k]
        arr = np.frombuffer(ent["data"], dtype=ent["dtype"]).reshape(
            ent["shape"])
        restored[k] = jnp.asarray(arr)
    # rebuild via tree structure of `like`
    leaves_like, treedef = jax.tree.flatten(like)
    keys = sorted(_flatten(like).keys())
    # order of jax.tree.flatten on dicts is sorted-key order, matching ours
    ordered = [restored[k] for k in keys]
    return jax.tree.unflatten(treedef, ordered)
