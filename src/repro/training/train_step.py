"""Train steps: full-parameter pretraining and LoRA-only fine-tuning
(frozen base + one adapter, the workload that *produces* the adapters the
serving system multiplexes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.lora.batched import make_lora_cb
from repro.models import model as M
from repro.models.common import chunked_cross_entropy

from .optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg, opt_cfg: AdamWConfig, remat: bool = True):
    """Full-parameter train step: (params, opt_state, batch) ->
    (params, opt_state, metrics). batch: {tokens, labels[, frontend]}."""

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch, remat=remat)

    def step(params, opt_state, batch):
        l, grads = jax.value_and_grad(loss)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, {"loss": l, **om}

    return step


def make_lora_train_step(cfg, opt_cfg: AdamWConfig, remat: bool = True,
                         scaling: float = 1.0):
    """LoRA fine-tune step: base params frozen, one adapter trained.

    adapter: {target: {"A": (L,d,r), "B": (L,r,out)}} (repro.lora.adapter).
    """

    def loss(adapter, params, batch):
        bank = jax.tree.map(lambda t: t[:, None], adapter)  # Na=1 bank
        B = batch["tokens"].shape[0]
        idx = jnp.zeros((B,), jnp.int32)
        h, aux = M.forward(cfg, params, batch["tokens"],
                           frontend=batch.get("frontend"), bank=bank,
                           lora_idx=idx, remat=remat)
        return chunked_cross_entropy(h, M.lm_head(cfg, params),
                                     batch["labels"]) + 0.01 * aux

    def step(adapter, opt_state, params, batch):
        l, grads = jax.value_and_grad(loss)(adapter, params, batch)
        adapter, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                              adapter)
        return adapter, opt_state, {"loss": l, **om}

    return step


def init_train_state(cfg, key, opt_cfg: Optional[AdamWConfig] = None,
                     dtype=jnp.float32):
    params = M.init_params(cfg, key, dtype)
    return params, adamw_init(params)
