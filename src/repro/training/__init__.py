from .checkpoint import load_checkpoint, save_checkpoint
from .optimizer import (AdamWConfig, adamw_init, adamw_update, global_norm,
                        lr_schedule)
from .train_step import (init_train_state, make_lora_train_step,
                         make_train_step)
