"""The closed control loop: telemetry -> drift/SLO -> action.

``ClusterController`` is substrate-neutral: the host (the
discrete-event ``ClusterSimulator`` or the ``LoRAServeCluster`` facade
over real engines) feeds it request lifecycle events, calls ``tick``
with a ``ClusterState`` snapshot on its own clock, and executes the
returned ``Action``s through the existing orchestrator / adapter-store
machinery. The policy, in priority order:

1. **retire** any draining server the host reports empty (no HBM
   copies, no queued/running work, no in-flight transfers touching it);
2. on **drift** (new ``DriftEvent``s this tick) or an **SLO
   violation**, trigger an out-of-band rebalance so placement chases
   the new demand shape instead of waiting for the periodic timestep;
3. on **sustained violation** (``patience`` consecutive bad ticks) with
   room under ``max_servers``, **scale up** one server;
4. on **sustained headroom** (``drain_patience`` consecutive ticks at
   target attainment with windowed P95 TTFT under ``drain_margin *
   slo.ttft`` and per-server load light), **drain** the least-loaded
   server — the paper's fewer-GPUs-under-SLO claim closed end to end.

Scale actions share a cooldown so the loop cannot flap; draining pauses
all scaling until the drain retires.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .drift import DriftDetector, DriftEvent
from .slo import SLOSpec, SLOTracker
from .telemetry import TelemetryHub

ACT_REBALANCE = "rebalance"
ACT_SCALE_UP = "scale-up"
ACT_DRAIN = "drain"
ACT_RETIRE = "retire"


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str                 # rebalance | scale-up | drain | retire
    time: float
    server: int = -1          # target (drain/retire)
    reason: str = ""


@dataclasses.dataclass
class ClusterState:
    """Host-built snapshot the controller decides on."""
    now: float
    active: List[int]                       # serving (non-draining) ids
    draining: List[int] = dataclasses.field(default_factory=list)
    drained: List[int] = dataclasses.field(default_factory=list)
    # ^ draining servers now empty and safe to retire
    queue_depth: Dict[int, float] = dataclasses.field(default_factory=dict)
    # busy fraction over the last tick window, 0..1 per server; drains
    # are gated on the *projected* utilization after losing one server
    utilization: Dict[int, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ControllerConfig:
    tick_period: float = 5.0
    min_servers: int = 1
    max_servers: int = 8
    patience: int = 2            # bad ticks before a scale-up
    drain_patience: int = 4      # comfortable ticks before a drain
    cooldown: float = 20.0       # seconds between scale actions
    rebalance_cooldown: float = 10.0
    drain_margin: float = 0.5    # windowed P95 TTFT must sit under
    #                              drain_margin * slo.ttft to drain
    drain_queue_depth: float = 2.0   # ...and mean queue depth under this
    drain_util: float = 0.7      # ...and projected post-drain mean busy
    #                              fraction (util * n/(n-1)) under this
    min_samples: int = 5
    drift_min_share: float = 0.02    # only watch adapters carrying at
    #                                  least this share of windowed
    #                                  traffic (tail rates are pure
    #                                  Poisson noise; the head is what
    #                                  placement can chase — Fig 8)


class ClusterController:
    def __init__(self, slo: SLOSpec,
                 config: Optional[ControllerConfig] = None,
                 detector: Optional[DriftDetector] = None,
                 operating_points: Optional[Dict[int, float]] = None,
                 adapter_ranks: Optional[Dict[str, int]] = None):
        self.config = config or ControllerConfig()
        self.spec = slo
        self.telemetry = TelemetryHub(window=slo.window)
        self.slo = SLOTracker(slo)
        self.detector = detector or DriftDetector()
        # Algorithm-1 capacity math for the drain gate: windowed demand
        # (tokens/s) over per-rank operating points = servers' worth of
        # demand. Optional — without it the host's busy-fraction
        # heuristic gates drains instead.
        self.operating_points = operating_points
        self.adapter_ranks = adapter_ranks or {}
        self.actions: List[Action] = []       # everything ever emitted
        # decision inputs of the most recent tick — the flight
        # recorder's audit record for scale/drain/SLO-violation dumps
        self.last_inputs: dict = {}
        self.server_failures: List = []   # (server, now) crash log
        self._bad_ticks = 0
        self._good_ticks = 0
        self._last_scale = -float("inf")
        self._last_rebalance = -float("inf")
        self.ticks = 0

    # -- host feeds (both substrates call these) --------------------------
    def observe_arrival(self, adapter_id: str, server: int,
                        tokens: float, now: float) -> None:
        self.telemetry.observe_arrival(adapter_id, server, tokens, now)

    def observe_completion(self, req, now: float) -> None:
        self.telemetry.observe_completion(req, now)
        self.slo.observe(req, now)

    def observe_timeout(self, now: float) -> None:
        self.telemetry.observe_timeout(now)
        self.slo.observe_timeout(now)

    def observe_failure(self, server: int, now: float) -> None:
        """Chaos plane: a server was confirmed dead and recovered
        around. Capacity just dropped out from under the SLO window, so
        the scale-down comfort streak resets — the controller must not
        drain a survivor on pre-crash telemetry."""
        self.server_failures.append((server, now))
        self._good_ticks = 0

    # -- introspection ----------------------------------------------------
    def drift_events(self) -> List[DriftEvent]:
        return list(self.detector.events)

    def count(self, kind: str) -> int:
        return sum(1 for a in self.actions if a.kind == kind)

    # -- the loop ---------------------------------------------------------
    def tick(self, state: ClusterState) -> List[Action]:
        cfg = self.config
        now = state.now
        self.ticks += 1
        out: List[Action] = []

        # 1. finish drains first: an empty draining server retires now
        for sid in state.drained:
            out.append(self._act(ACT_RETIRE, now, server=sid,
                                 reason="drain complete"))

        # sample per-adapter demand once per tick for the detector,
        # head adapters only (tail windowed rates are Poisson noise)
        rates = self.telemetry.adapter_rates(now)
        total_rate = sum(rates.values())
        floor = cfg.drift_min_share * total_rate
        new_drift = self.detector.observe(
            {aid: r for aid, r in rates.items() if r >= floor}, now)

        n_active = len(state.active)
        violated = self.slo.violated(now, cfg.min_samples)
        self.last_inputs = {
            "now": now,
            "n_active": n_active,
            "attainment": self.slo.attainment(now),
            "window_samples": self.slo.sample_count(now),
            "violated": violated,
            "bad_ticks": self._bad_ticks + (1 if violated else 0),
            "good_ticks": self._good_ticks,
            "windowed_p95_ttft": self.telemetry.ttft_percentile(95, now),
            "demand_servers": self.demand_servers(now),
            "drift_events": [dataclasses.asdict(e) for e in new_drift],
            "server_failures": len(self.server_failures),
        }
        if violated:
            self._bad_ticks += 1
            self._good_ticks = 0
        else:
            self._bad_ticks = 0
            if self._comfortable(state):
                self._good_ticks += 1
            else:
                self._good_ticks = 0

        # 2. drift or violation: chase the new shape with a rebalance
        if (new_drift or violated) and \
                now - self._last_rebalance >= cfg.rebalance_cooldown:
            why = (f"drift:{','.join(e.kind for e in new_drift)}"
                   if new_drift else
                   f"slo attainment "
                   f"{self.slo.attainment(now):.2f}<{self.spec.target}")
            out.append(self._act(ACT_REBALANCE, now, reason=why))
            self._last_rebalance = now

        draining = bool(state.draining)
        cool = now - self._last_scale < cfg.cooldown

        # 3. sustained violation: add a server
        if self._bad_ticks >= cfg.patience and not draining and \
                not cool and n_active < cfg.max_servers:
            out.append(self._act(
                ACT_SCALE_UP, now,
                reason=f"attainment {self.slo.attainment(now):.2f} "
                       f"for {self._bad_ticks} ticks"))
            self._last_scale = now
            self._bad_ticks = 0

        # 4. sustained headroom: give a server back (the least-loaded
        # one by windowed token rate; its traffic re-places elsewhere)
        elif self._good_ticks >= cfg.drain_patience and not draining \
                and not cool and n_active > cfg.min_servers:
            victim = min(state.active,
                         key=lambda s: (
                             self.telemetry.server_token_rate(s, now),
                             state.queue_depth.get(s, 0.0), s))
            out.append(self._act(
                ACT_DRAIN, now, server=victim,
                reason=f"headroom for {self._good_ticks} ticks"))
            self._last_scale = now
            self._good_ticks = 0

        return out

    def demand_servers(self, now: float) -> Optional[float]:
        """Servers' worth of windowed demand (Algorithm 1 Step 1):
        sum over adapters of token_rate / operating_point(rank). None
        when the controller has no operating points."""
        if not self.operating_points:
            return None
        total = 0.0
        for aid, rate in self.telemetry.adapter_rates(now).items():
            rank = self.adapter_ranks.get(aid)
            op = self.operating_points.get(rank)
            if op:
                total += rate / op
        return total

    def _comfortable(self, state: ClusterState) -> bool:
        """Headroom check gating drains: attainment at target on real
        evidence, windowed P95 TTFT well under the target, queues
        shallow, and projected capacity after losing one server still
        inside ``drain_util``."""
        cfg = self.config
        now = state.now
        if not self.slo.headroom(now, cfg.min_samples):
            return False
        p95 = self.telemetry.ttft_percentile(95, now)
        if p95 is None or p95 > cfg.drain_margin * self.spec.ttft:
            return False
        if state.active:
            mean_q = sum(state.queue_depth.get(s, 0.0)
                         for s in state.active) / len(state.active)
            if mean_q > cfg.drain_queue_depth:
                return False
        n = len(state.active)
        if n <= 1:
            return False
        want = self.demand_servers(now)
        if want is not None:
            # paper-native capacity gate: demand in server-equivalents
            # against the fleet one server smaller
            if want / (n - 1) > cfg.drain_util:
                return False
        elif state.utilization:
            # fallback: host-reported busy fraction
            mean_u = sum(state.utilization.get(s, 0.0)
                         for s in state.active) / n
            if mean_u * n / (n - 1) > cfg.drain_util:
                return False
        return True

    def _act(self, kind: str, now: float, server: int = -1,
             reason: str = "") -> Action:
        a = Action(kind=kind, time=now, server=server, reason=reason)
        self.actions.append(a)
        return a
