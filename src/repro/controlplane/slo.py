"""SLO specification + windowed attainment tracking (paper §V, Fig 10).

An ``SLOSpec`` names the latency targets a deployment promises (TTFT
and optionally TBT) and the attainment fraction that counts as healthy
(e.g. 95% of requests under 10 s TTFT). ``SLOTracker`` scores every
finished request against the spec over a sliding window; the controller
reads ``attainment`` / ``violated`` / ``headroom`` to decide when to
rebalance, scale up, or drain.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    ttft: float = 10.0                  # seconds to first token
    tbt: Optional[float] = None         # seconds/token; None = untracked
    target: float = 0.95                # required attainment fraction
    window: float = 30.0                # seconds of history scored

    def met_by(self, ttft: Optional[float],
               tbt: Optional[float]) -> bool:
        if ttft is None or ttft > self.ttft:
            return False
        if self.tbt is not None and tbt is not None and tbt > self.tbt:
            return False
        return True


class SLOTracker:
    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self._scores: Deque[Tuple[float, bool]] = collections.deque()
        self.scored = 0
        self.met = 0

    # -- feeds ------------------------------------------------------------
    def observe(self, req, now: float) -> bool:
        ok = self.spec.met_by(req.ttft, req.tbt)
        self._push(now, ok)
        return ok

    def observe_timeout(self, now: float) -> None:
        """A dropped request is an SLO miss, not a gap in the data."""
        self._push(now, False)

    def _push(self, now: float, ok: bool) -> None:
        self._scores.append((now, ok))
        self.scored += 1
        self.met += ok

    # -- windowed state ---------------------------------------------------
    def _prune(self, now: float) -> None:
        cutoff = now - self.spec.window
        while self._scores and self._scores[0][0] < cutoff:
            self._scores.popleft()

    def sample_count(self, now: float) -> int:
        self._prune(now)
        return len(self._scores)

    def attainment(self, now: float) -> float:
        """Fraction of windowed requests meeting the spec; 1.0 when the
        window is empty (no evidence of trouble)."""
        self._prune(now)
        if not self._scores:
            return 1.0
        return sum(ok for _, ok in self._scores) / len(self._scores)

    def violated(self, now: float, min_samples: int = 5) -> bool:
        return (self.sample_count(now) >= min_samples
                and self.attainment(now) < self.spec.target)

    def headroom(self, now: float, min_samples: int = 5) -> bool:
        """Attainment at-or-above target on real evidence — the
        controller combines this with a windowed-P95 latency margin
        (from telemetry) before it dares drain a server."""
        return (self.sample_count(now) >= min_samples
                and self.attainment(now) >= self.spec.target)

    def lifetime_attainment(self) -> float:
        return self.met / self.scored if self.scored else 1.0
