"""SLO-driven control plane: telemetry -> drift/SLO -> rebalance/scale.

The closed loop the paper's "fewer GPUs under SLO" result needs:
sliding-window telemetry over the live request stream, online drift
detection on per-adapter demand (Fig 10 shapes), SLO attainment
tracking, and a controller that rebalances on drift, provisions servers
under sustained violation, and drains them back under sustained
headroom — on both execution substrates.
"""
from .controller import (ACT_DRAIN, ACT_REBALANCE, ACT_RETIRE,
                         ACT_SCALE_UP, Action, ClusterController,
                         ClusterState, ControllerConfig)
from .drift import (DriftDetector, DriftEvent, KIND_DIURNAL, KIND_FALLING,
                    KIND_RISING, KIND_SURGE)
from .slo import SLOSpec, SLOTracker
from .telemetry import SlidingWindow, TelemetryHub

__all__ = ["Action", "ClusterController", "ClusterState",
           "ControllerConfig", "ACT_REBALANCE", "ACT_SCALE_UP",
           "ACT_DRAIN", "ACT_RETIRE",
           "DriftDetector", "DriftEvent", "KIND_RISING", "KIND_FALLING",
           "KIND_SURGE", "KIND_DIURNAL",
           "SLOSpec", "SLOTracker", "SlidingWindow", "TelemetryHub"]
