"""Online per-adapter demand drift detection (paper Fig 10 shapes).

Each adapter's windowed token rate is sampled once per controller tick
and fed to a Page–Hinkley changepoint test over *relative* deviations
(sample / long-run EWMA baseline - 1), so one lambda works across
adapters whose absolute rates differ by orders of magnitude. A fast
EWMA tracks the post-change level; the ratio of fast to baseline at
detection time classifies the event:

* ``surge``  — abrupt jump (fast/baseline >= ``surge_ratio``), the
  Fig 10 late-surge adapter;
* ``rising`` / ``falling`` — gradual trend crossings;
* ``diurnal`` — an adapter that keeps alternating rising/falling
  detections (the sinusoidal Fig 10 pattern) is re-labeled once the
  oscillation shows up.

Detections reset the test, so a persistent new level re-arms instead of
firing forever.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

KIND_RISING = "rising"
KIND_FALLING = "falling"
KIND_SURGE = "surge"
KIND_DIURNAL = "diurnal"


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    adapter_id: str
    kind: str            # rising | falling | surge | diurnal
    time: float
    baseline: float      # long-run EWMA rate at detection
    level: float         # fast EWMA rate at detection
    magnitude: float     # level / baseline (0 when baseline is 0)


class _AdapterState:
    __slots__ = ("baseline", "fast", "mt_up", "min_up", "mt_dn", "max_dn",
                 "samples", "directions")

    def __init__(self):
        self.baseline: Optional[float] = None   # slow EWMA
        self.fast: Optional[float] = None       # fast EWMA
        self.mt_up = self.min_up = 0.0          # PH cumulative, upward
        self.mt_dn = self.max_dn = 0.0          # PH cumulative, downward
        self.samples = 0
        self.directions: List[str] = []         # detection history


class DriftDetector:
    def __init__(self, *, slow_alpha: float = 0.03, fast_alpha: float = 0.5,
                 delta: float = 0.25, lam: float = 2.5,
                 surge_ratio: float = 1.8, warmup_samples: int = 4,
                 min_rate: float = 0.0, diurnal_flips: int = 3):
        self.slow_alpha = slow_alpha
        self.fast_alpha = fast_alpha
        self.delta = delta          # PH drift tolerance (relative units)
        self.lam = lam              # PH detection threshold
        self.surge_ratio = surge_ratio
        self.warmup_samples = warmup_samples
        self.min_rate = min_rate    # ignore adapters quieter than this
        self.diurnal_flips = diurnal_flips
        self._state: Dict[str, _AdapterState] = {}
        self.events: List[DriftEvent] = []

    # -- single-adapter update -------------------------------------------
    def update(self, adapter_id: str, rate: float,
               now: float) -> Optional[DriftEvent]:
        st = self._state.setdefault(adapter_id, _AdapterState())
        st.samples += 1
        if st.baseline is None:
            st.baseline = st.fast = rate
            return None
        if rate < self.min_rate and st.baseline < self.min_rate:
            return None    # tail adapter: too quiet to call drift on
        st.fast = (self.fast_alpha * rate
                   + (1 - self.fast_alpha) * st.fast)
        # relative deviation against the *pre-update* baseline
        x = rate / st.baseline - 1.0 if st.baseline > 1e-9 else \
            (1.0 if rate > 1e-9 else 0.0)
        st.baseline = (self.slow_alpha * rate
                       + (1 - self.slow_alpha) * st.baseline)
        st.mt_up += x - self.delta
        st.min_up = min(st.min_up, st.mt_up)
        st.mt_dn += x + self.delta
        st.max_dn = max(st.max_dn, st.mt_dn)
        if st.samples <= self.warmup_samples:
            return None
        ev: Optional[DriftEvent] = None
        if st.mt_up - st.min_up > self.lam:
            ev = self._emit(adapter_id, st, now, up=True)
        elif st.max_dn - st.mt_dn > self.lam:
            ev = self._emit(adapter_id, st, now, up=False)
        return ev

    def _emit(self, adapter_id: str, st: _AdapterState, now: float,
              up: bool) -> DriftEvent:
        baseline = st.baseline or 0.0
        level = st.fast or 0.0
        mag = level / baseline if baseline > 1e-9 else 0.0
        if up:
            kind = KIND_SURGE if mag >= self.surge_ratio else KIND_RISING
        else:
            kind = KIND_FALLING
        st.directions.append("up" if up else "down")
        if self._oscillating(st.directions):
            kind = KIND_DIURNAL
        # reset the test; keep the EWMAs so a new level re-arms cleanly
        st.mt_up = st.min_up = 0.0
        st.mt_dn = st.max_dn = 0.0
        ev = DriftEvent(adapter_id=adapter_id, kind=kind, time=now,
                        baseline=baseline, level=level, magnitude=mag)
        self.events.append(ev)
        return ev

    def _oscillating(self, directions: List[str]) -> bool:
        if len(directions) < self.diurnal_flips:
            return False
        tail = directions[-self.diurnal_flips:]
        return all(a != b for a, b in zip(tail, tail[1:]))

    # -- batch update (one controller tick) -------------------------------
    def observe(self, rates: Dict[str, float],
                now: float) -> List[DriftEvent]:
        out = []
        for aid in sorted(rates):
            ev = self.update(aid, rates[aid], now)
            if ev is not None:
                out.append(ev)
        return out

    def events_for(self, adapter_id: str) -> List[DriftEvent]:
        return [e for e in self.events if e.adapter_id == adapter_id]
