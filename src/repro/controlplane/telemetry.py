"""Sliding-window cluster telemetry — the control plane's sensor layer.

``TelemetryHub`` aggregates the existing request lifecycle events
(arrival routing, completion, timeout) into windowed per-adapter and
per-server statistics: token/request rates and windowed TTFT/TBT
percentiles. (Queue depths are instantaneous backend state, not event
history — the hosts snapshot them into ``ClusterState`` per tick.) Both substrates feed it from the same places the
``DemandEstimator`` already observes, but where the estimator keeps one
smoothed level per adapter for *placement*, the hub keeps raw
timestamped samples so the drift detector and SLO tracker can look at
the actual recent distribution.
"""
from __future__ import annotations

import bisect
import collections
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from repro.serving.metrics import percentile


class SlidingWindow:
    """Timestamped samples pruned to a fixed horizon."""

    def __init__(self, horizon: float):
        self.horizon = horizon
        self._samples: Deque[Tuple[float, float]] = collections.deque()
        self._first: Optional[float] = None   # first-ever sample time

    def push(self, t: float, value: float) -> None:
        if self._first is None:
            self._first = t
        self._samples.append((t, value))

    def prune(self, now: float) -> None:
        cutoff = now - self.horizon
        q = self._samples
        while q and q[0][0] < cutoff:
            q.popleft()

    def values(self, now: float) -> List[float]:
        self.prune(now)
        return [v for _, v in self._samples]

    def count(self, now: float) -> int:
        self.prune(now)
        return len(self._samples)

    def total(self, now: float) -> float:
        self.prune(now)
        return sum(v for _, v in self._samples)

    def rate(self, now: float) -> float:
        """Sum of samples per second over the (elapsed part of the)
        window. Early in a feed the divisor is the time actually covered
        — measured from the first sample ever pushed, NOT from t=0: an
        engine wall clock or an offset-arrival trace can start feeding
        at an arbitrary clock value, and dividing by ``now`` would
        deflate those rates by however late the feed began."""
        if self._first is None:
            return 0.0
        span = min(self.horizon, now - self._first)
        if span <= 0.0:
            span = 1.0
        return self.total(now) / span


# log-spaced latency buckets, 1ms .. 60s (Prometheus `le` upper bounds)
DEFAULT_LATENCY_BOUNDS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Histogram:
    """Cumulative fixed-bucket histogram with Prometheus `histogram`
    semantics: ``cumulative()`` yields ``(le, count-with-value<=le)``
    pairs ending in ``("+Inf", total)``, plus ``sum``/``count`` — the
    `_bucket`/`_sum`/`_count` series external scrapers aggregate."""

    def __init__(self, bounds=DEFAULT_LATENCY_BOUNDS):
        self.bounds = tuple(bounds)
        self._counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        # Prometheus le semantics: bucket i counts value <= bounds[i]
        self._counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def cumulative(self) -> Iterator[Tuple[object, int]]:
        cum = 0
        for le, c in zip(self.bounds, self._counts):
            cum += c
            yield le, cum
        yield "+Inf", self.count

    def to_dict(self) -> dict:
        return {"buckets": list(self.cumulative()),
                "sum": self.sum, "count": self.count}


class TelemetryHub:
    def __init__(self, window: float = 30.0):
        self.window = window
        self._adapter_tokens: Dict[str, SlidingWindow] = {}
        self._adapter_requests: Dict[str, SlidingWindow] = {}
        self._server_tokens: Dict[int, SlidingWindow] = {}
        self._ttft = SlidingWindow(window)
        self._tbt = SlidingWindow(window)
        self._server_ttft: Dict[int, SlidingWindow] = {}
        # cumulative (never-pruned) latency histograms: the Prometheus
        # `histogram`-typed complement of the windowed percentiles, so
        # external scrapers can rate() and aggregate across gateways
        self.ttft_hist = Histogram()
        self.tbt_hist = Histogram()
        self.arrivals = 0
        self.completions = 0
        self.timeouts = 0

    def _win(self, table: Dict, key) -> SlidingWindow:
        w = table.get(key)
        if w is None:
            w = table[key] = SlidingWindow(self.window)
        return w

    # -- feeds ------------------------------------------------------------
    def observe_arrival(self, adapter_id: str, server: int,
                        tokens: float, now: float) -> None:
        self.arrivals += 1
        self._win(self._adapter_tokens, adapter_id).push(now, tokens)
        self._win(self._adapter_requests, adapter_id).push(now, 1.0)
        self._win(self._server_tokens, server).push(now, tokens)

    def observe_completion(self, req, now: float) -> None:
        """Feed one finished ``ServeRequest`` (either substrate)."""
        self.completions += 1
        ttft, tbt = req.ttft, req.tbt
        if ttft is not None and ttft >= 0:
            self._ttft.push(now, ttft)
            self._win(self._server_ttft, req.server).push(now, ttft)
            self.ttft_hist.observe(ttft)
        if tbt is not None and tbt > 0:
            self._tbt.push(now, tbt)
            self.tbt_hist.observe(tbt)

    def observe_timeout(self, now: float) -> None:
        self.timeouts += 1

    # -- windowed accessors ----------------------------------------------
    # (queue depths flow through ClusterState, host-built per tick —
    # they are instantaneous backend state, not event-stream history)
    def adapter_token_rate(self, adapter_id: str, now: float) -> float:
        w = self._adapter_tokens.get(adapter_id)
        return w.rate(now) if w else 0.0

    def adapter_request_rate(self, adapter_id: str, now: float) -> float:
        w = self._adapter_requests.get(adapter_id)
        return w.rate(now) if w else 0.0

    def adapter_rates(self, now: float) -> Dict[str, float]:
        """Per-adapter windowed token rates — the drift detector's
        input signal."""
        return {aid: w.rate(now)
                for aid, w in self._adapter_tokens.items()}

    def server_token_rate(self, server: int, now: float) -> float:
        w = self._server_tokens.get(server)
        return w.rate(now) if w else 0.0

    def ttft_percentile(self, p: float, now: float) -> Optional[float]:
        vs = self._ttft.values(now)
        return percentile(vs, p) if vs else None

    def tbt_percentile(self, p: float, now: float) -> Optional[float]:
        vs = self._tbt.values(now)
        return percentile(vs, p) if vs else None

    def server_ttft_percentile(self, server: int, p: float,
                               now: float) -> Optional[float]:
        w = self._server_ttft.get(server)
        vs = w.values(now) if w else []
        return percentile(vs, p) if vs else None

    def sample_count(self, now: float) -> int:
        return self._ttft.count(now)

    def snapshot(self, now: float) -> dict:
        """One consistent windowed view at ``now`` — what a live
        ``/metrics`` scrape renders. Percentile entries are ``None``
        (not NaN, not inf) while the window is empty so renderers can
        skip them cleanly."""
        return {
            "now": now,
            "window": self.window,
            "arrivals": self.arrivals,
            "completions": self.completions,
            "timeouts": self.timeouts,
            "samples": self.sample_count(now),
            "ttft_p50": self.ttft_percentile(50, now),
            "ttft_p95": self.ttft_percentile(95, now),
            "tbt_p50": self.tbt_percentile(50, now),
            "tbt_p95": self.tbt_percentile(95, now),
            "ttft_hist": self.ttft_hist.to_dict(),
            "tbt_hist": self.tbt_hist.to_dict(),
            "adapter_token_rates": self.adapter_rates(now),
            "adapter_request_rates": {
                aid: w.rate(now)
                for aid, w in self._adapter_requests.items()},
            "server_token_rates": {
                sid: w.rate(now)
                for sid, w in self._server_tokens.items()},
        }
