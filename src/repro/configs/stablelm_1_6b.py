"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b.

24L d_model=2048 32H (GQA kv=32 == MHA) d_ff=5632 vocab=100352.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        source="smoke",
    )
