"""llama-7b-paper — the paper's own evaluation model (Llama 7B, §V-C).

32L d_model=4096 32H MHA d_ff=11008 vocab=32000. Used by the serving
engine examples, cost-model calibration, and kernel benches.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-7b-paper",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        source="paper §V-C / arXiv:2302.13971",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-7b-paper-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        source="smoke",
    )
