"""qwen2.5-32b [dense] — hf:Qwen/Qwen2.5-0.5B (family card, scaled config).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064, QKV bias.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen2.5-0.5B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        qkv_bias=True,
        source="smoke",
    )
