"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434.

27L d_model=2048 16H MLA (kv_lora_rank=512, qk_nope=128, qk_rope=64, v=128)
MoE: 64 routed experts top-6 + 2 shared, d_ff_expert=1408, vocab=102400.
The assignment line also says "160 routed"; we follow the actual
DeepSeek-V2-Lite card (64 routed) — see DESIGN.md §4.
"""
from .base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128, q_lora_rank=0),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                      n_shared_experts=2),
        source="arXiv:2405.04434",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16, q_lora_rank=0),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared_experts=1),
        source="smoke",
    )
