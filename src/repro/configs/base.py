"""Config dataclasses for the model zoo and input shapes.

Every assigned architecture gets one file in this package constructing an
exact `ModelConfig` (citation in the file header) plus a `reduced()` smoke
variant (<=2 layers, d_model<=512, <=4 experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0      # always-on shared experts (DeepSeek style)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0           # 0 => full-rank q projection (V2-Lite)


@dataclass(frozen=True)
class SSMConfig:
    kind: str                      # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2                # inner = expand * d_model (mamba2)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (audio) models. Frontend is a stub:
    inputs are precomputed frame embeddings (batch, n_frames, d_model)."""
    n_layers: int
    n_frames: int = 1024           # default source length for dry-run/train


@dataclass(frozen=True)
class LoRAConfig:
    """Serving-time LoRA attach points."""
    ranks: Tuple[int, ...] = (8, 16, 32, 64, 128)
    max_rank: int = 128
    targets: Tuple[str, ...] = ("q", "k", "v", "o")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1            # hybrid: attention block period (0 = attn-free)
    shared_attn: bool = False      # Zamba2: one attention weight set reused
    cross_attn_every: int = 0      # vlm / enc-dec decoder: cross-attn period
    encoder: Optional[EncoderConfig] = None
    n_frontend_tokens: int = 0     # vlm: number of stub patch embeddings
    sliding_window: int = 0        # 0 = full attention; >0 = ring-buffer window
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    source: str = ""               # citation for the exact numbers

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def is_attention_free(self) -> bool:
        return self.ssm is not None and self.attn_every == 0

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, sliding_window=window)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = 0
        if self.n_heads:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            if self.mla is not None:
                m = self.mla
                qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                q = d * self.n_heads * qd if not m.q_lora_rank else (
                    d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd)
                kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + \
                    m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                o = self.n_heads * m.v_head_dim * d
            attn = q + kv + o
        if self.moe is not None:
            e = self.moe
            ffp = (e.n_experts + e.n_shared_experts) * 3 * d * e.d_ff_expert \
                + d * e.n_experts
        else:
            ffp = 3 * d * ff
        ssmp = 0
        if self.ssm is not None:
            if self.ssm.kind == "mamba2":
                inner = self.ssm.expand * d
                ssmp = d * (2 * inner) + inner * d + inner * (2 * self.ssm.d_state) \
                    + inner  # in/out proj + B,C proj + dt
            else:  # rwkv6
                ssmp = 5 * d * d + d * ff * 2  # r,k,v,g,o + channel mix
        n_attn = self.n_attn_layers()
        n_ssm = self.n_ssm_layers()
        n_ff = self.n_layers if self.ssm is None else n_attn
        if self.shared_attn:
            blocks = attn + n_ssm * ssmp + n_ff * ffp
        elif self.ssm is not None and self.ssm.kind == "rwkv6":
            blocks = self.n_layers * ssmp
        else:
            blocks = n_attn * attn + n_ssm * ssmp + n_ff * ffp
        if self.cross_attn_every and self.n_heads:
            blocks += (self.n_layers // self.cross_attn_every) * attn
        if self.encoder is not None:
            blocks += self.encoder.n_layers * (attn + 3 * d * ff)
            blocks += self.n_layers * attn  # decoder cross-attn
        return emb + blocks

    def n_attn_layers(self) -> int:
        if self.is_attention_free:
            return 0
        if self.ssm is None:
            return self.n_layers
        # hybrid: one attn application every attn_every blocks
        return (self.n_layers + self.attn_every - 1) // self.attn_every

    def n_ssm_layers(self) -> int:
        if self.ssm is None:
            return 0
        if self.is_attention_free:
            return self.n_layers
        return self.n_layers  # hybrid: every block is SSM; attn is interleaved extra


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Window used by dense/moe/vlm/audio archs for the long_500k shape
# (sub-quadratic requirement): ring-buffer sliding-window attention.
LONG_CONTEXT_WINDOW = 4096
