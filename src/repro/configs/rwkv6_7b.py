"""rwkv6-7b [ssm] — arXiv:2404.05892 (RWKV-6 "Finch").

32L d_model=4096, attention-free (data-dependent decay WKV), d_ff=14336
channel-mix, vocab=65536. WKV heads: 64 x head_dim 64.
"""
from .base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=14336,
        vocab_size=65536,
        ssm=SSMConfig(kind="rwkv6", head_dim=64),
        attn_every=0,
        source="arXiv:2404.05892",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        d_ff=256,
        vocab_size=512,
        ssm=SSMConfig(kind="rwkv6", head_dim=32),
        attn_every=0,
        source="smoke",
    )
