"""llama-3.2-vision-90b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision (family).

100L (80 self-attn + 20 cross-attn, every 5th layer) d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256. Vision encoder is a STUB: cross-attn
consumes precomputed patch embeddings (batch, n_patches, d_model).
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        cross_attn_every=5,
        n_frontend_tokens=1601,      # 1 tile of 1600 patches + cls
        rope_theta=5e5,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke",
        family="vlm",
        n_layers=5,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        cross_attn_every=5,
        n_frontend_tokens=16,
        source="smoke",
    )
