"""seamless-m4t-large-v2 [audio, enc-dec] — arXiv:2308.11596.

24L (per stack) d_model=1024 16H (GQA kv=16 == MHA) d_ff=8192 vocab=256206.
Speech frontend (mel + conformer conv) is a STUB: the encoder consumes
precomputed frame embeddings (batch, n_frames, 1024).
"""
from .base import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        cross_attn_every=1,          # every decoder layer cross-attends
        encoder=EncoderConfig(n_layers=24, n_frames=1024),
        source="arXiv:2308.11596",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        family="audio",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        cross_attn_every=1,
        encoder=EncoderConfig(n_layers=2, n_frames=16),
        source="smoke",
    )
