"""Config registry: `get_config(arch_id)` and `get_smoke_config(arch_id)`."""
from __future__ import annotations

from .base import (INPUT_SHAPES, LONG_CONTEXT_WINDOW, EncoderConfig,
                   InputShape, LoRAConfig, MLAConfig, ModelConfig, MoEConfig,
                   SSMConfig)

_REGISTRY = {}


def register(module_name: str):
    from importlib import import_module
    mod = import_module(f"repro.configs.{module_name}")
    cfg = mod.config()
    _REGISTRY[cfg.name] = mod
    return mod


_ARCH_MODULES = [
    "seamless_m4t_large_v2",
    "qwen2_5_32b",
    "zamba2_7b",
    "llama_3_2_vision_90b",
    "codeqwen1_5_7b",
    "rwkv6_7b",
    "llama4_scout_17b_16e",
    "internlm2_1_8b",
    "deepseek_v2_lite_16b",
    "stablelm_1_6b",
    "llama_7b_paper",
]

for _m in _ARCH_MODULES:
    register(_m)

ARCH_IDS = sorted(_REGISTRY.keys())
ASSIGNED_ARCH_IDS = [a for a in ARCH_IDS if a != "llama-7b-paper"]


def get_config(arch_id: str) -> ModelConfig:
    return _REGISTRY[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _REGISTRY[arch_id].reduced()


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "EncoderConfig",
    "LoRAConfig", "InputShape", "INPUT_SHAPES", "LONG_CONTEXT_WINDOW",
    "ARCH_IDS", "ASSIGNED_ARCH_IDS", "get_config", "get_smoke_config",
]
