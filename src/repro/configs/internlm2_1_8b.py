"""internlm2-1.8b [dense] — arXiv:2403.17297.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        rope_theta=1e6,
        source="arXiv:2403.17297",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        source="smoke",
    )
