"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416, QKV bias.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1e6,
        source="hf:Qwen/CodeQwen1.5-7B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        source="smoke",
    )
