"""zamba2-7b [hybrid] — arXiv:2411.15242.

81 Mamba2 blocks, d_model=3584, ssm_state=64; a SHARED full-attention block
(32H, GQA kv=32, d_ff=14336 MLP) applied every 6 blocks. vocab=32000.
"""
from .base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2),
        attn_every=6,
        shared_attn=True,
        source="arXiv:2411.15242",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        n_layers=4,          # 4 mamba blocks, shared attn every 2
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        ssm=SSMConfig(kind="mamba2", d_state=16, head_dim=32, expand=2),
        attn_every=2,
        shared_attn=True,
        source="smoke",
    )
