"""Minimal HTTP/1.1 over asyncio streams — the gateway's wire layer.

Deliberately dependency-light (stdlib only, no FastAPI/uvicorn) so
tier-1 stays runnable in a bare venv: request parsing with
Content-Length bodies, keep-alive responses, and Server-Sent Events
framing for per-token streaming. Chunked transfer encoding is refused
(nothing in the gateway needs it) and header/body sizes are bounded.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional
from urllib.parse import parse_qs, unquote

MAX_HEADER_BYTES = 64 * 1024
MAX_BODY_BYTES = 8 * 1024 * 1024

STATUS_TEXT = {
    200: "OK", 201: "Created", 202: "Accepted", 204: "No Content",
    400: "Bad Request", 404: "Not Found", 405: "Method Not Allowed",
    409: "Conflict", 413: "Payload Too Large",
    429: "Too Many Requests", 500: "Internal Server Error",
    503: "Service Unavailable",
}


class BadRequest(Exception):
    """Malformed or unsupported HTTP input; rendered as a 400."""


@dataclasses.dataclass
class HttpRequest:
    method: str
    target: str                       # raw request-target
    path: str                         # decoded path, no query string
    query: Dict[str, str]             # first value per key
    headers: Dict[str, str]           # keys lower-cased
    body: bytes

    def json(self) -> dict:
        if not self.body:
            return {}
        try:
            obj = json.loads(self.body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise BadRequest(f"invalid JSON body: {e}") from None
        if not isinstance(obj, dict):
            raise BadRequest("JSON body must be an object")
        return obj

    @property
    def wants_keepalive(self) -> bool:
        return self.headers.get("connection", "").lower() != "close"


async def read_request(reader) -> Optional[HttpRequest]:
    """Parse one request off the stream; None on a clean EOF (client
    closed between requests), ``BadRequest`` on malformed input."""
    try:
        line = await reader.readline()
    except (ConnectionError, EOFError):
        return None
    if not line:
        return None
    if len(line) > MAX_HEADER_BYTES:
        raise BadRequest("request line too long")
    parts = line.decode("latin-1").strip().split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/"):
        raise BadRequest(f"malformed request line: {line!r}")
    method, target, _version = parts
    headers: Dict[str, str] = {}
    total = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n"):
            break
        if not h:
            raise BadRequest("EOF inside header block")
        total += len(h)
        if total > MAX_HEADER_BYTES:
            raise BadRequest("header block too large")
        key, sep, value = h.decode("latin-1").partition(":")
        if not sep:
            raise BadRequest(f"malformed header line: {h!r}")
        headers[key.strip().lower()] = value.strip()
    if "chunked" in headers.get("transfer-encoding", "").lower():
        raise BadRequest("chunked transfer encoding not supported")
    length = headers.get("content-length", "")
    try:
        n = int(length) if length else 0
    except ValueError:
        raise BadRequest(f"bad Content-Length: {length!r}") from None
    if n < 0 or n > MAX_BODY_BYTES:
        raise BadRequest(f"body of {n} bytes out of bounds")
    body = await reader.readexactly(n) if n else b""
    raw_path, _, raw_query = target.partition("?")
    query = {k: vs[0] for k, vs in parse_qs(raw_query).items()}
    return HttpRequest(method=method.upper(), target=target,
                       path=unquote(raw_path), query=query,
                       headers=headers, body=body)


def response_bytes(status: int, body=b"", *,
                   content_type: str = "application/json",
                   headers: Optional[Dict[str, str]] = None,
                   close: bool = False) -> bytes:
    """One complete keep-alive-friendly response. ``body`` may be
    bytes, str, or a JSON-serializable object."""
    if isinstance(body, (dict, list)):
        body = json.dumps(body).encode("utf-8")
    elif isinstance(body, str):
        body = body.encode("utf-8")
    text = STATUS_TEXT.get(status, "Unknown")
    lines = [f"HTTP/1.1 {status} {text}",
             f"Content-Type: {content_type}",
             f"Content-Length: {len(body)}",
             f"Connection: {'close' if close else 'keep-alive'}"]
    for k, v in (headers or {}).items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + body


def sse_headers() -> bytes:
    """Response head opening a Server-Sent Events stream. No
    Content-Length — the stream is delimited by connection close."""
    return (b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")


def sse_event(data) -> bytes:
    """One SSE frame. ``data`` may be a JSON-serializable object or a
    literal string (e.g. the ``[DONE]`` sentinel)."""
    if not isinstance(data, str):
        data = json.dumps(data)
    return f"data: {data}\n\n".encode("utf-8")
