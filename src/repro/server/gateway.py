"""Async streaming gateway over the incremental ``LoRAServeCluster``.

One asyncio event loop, one pump task, zero locks: handlers and the
pump interleave only at ``await`` points, so every call into the
cluster (submit, register, poll) runs to completion before any other
handler observes state — the single-loop design *is* the concurrency
control. The pump drives ``cluster.poll`` on the cluster clock and fans
completion/token events out to per-request ``asyncio.Queue``s; handlers
await their queue and translate events to SSE frames.

Endpoints (OpenAI-style where applicable):

* ``POST /v1/completions`` — submit a request; SSE per-token streaming
  by default (``"stream": false`` for a single JSON response);
* ``POST /v1/adapters`` / ``DELETE /v1/adapters/{id}`` /
  ``GET /v1/adapters`` — runtime adapter lifecycle (register with
  immediate placement, loss-free retire, live placement/tier table);
* ``GET /metrics`` — Prometheus text format from the incremental
  ``ClusterReport`` snapshot + live telemetry window;
* ``GET /healthz`` — liveness + drain state.

Graceful shutdown (SIGTERM/SIGINT or ``begin_shutdown()``): stop
admitting (503), finish every in-flight request and flush its stream,
complete pending adapter retires, then release backend resources —
zero lost tokens by construction, pinned by ``tests/test_server.py``.
"""
from __future__ import annotations

import asyncio
import itertools
import signal
from typing import Dict, Optional

from repro.core.request import ServeRequest
from repro.core.routing import UnknownAdapterError
from repro.core.types import AdapterInfo

from . import http
from .admission import AdmissionController
from .prom import render_metrics

# default weight payload for adapters registered over HTTP without an
# explicit nbytes (rank-16-ish LoRA on a 7B base)
DEFAULT_ADAPTER_NBYTES = 64 << 20


class _Disconnect:
    """Sentinel queue event: the client's connection is (to be treated
    as) gone — injected by chaos plans or detected via EOF."""
    kind = "disconnect"
    tokens: tuple = ()


_DISCONNECT_EVENT = _Disconnect()


class ServeGateway:
    def __init__(self, cluster, host: str = "127.0.0.1", port: int = 0,
                 *, admission: Optional[AdmissionController] = None,
                 poll_interval: float = 0.002,
                 default_max_tokens: int = 16,
                 submit_retries: int = 3):
        cluster.track_tokens = True   # per-token events feed the SSE path
        self.cluster = cluster
        self.host = host
        self.port = port              # 0: ephemeral; real port after start
        self.admission = admission or AdmissionController()
        self.poll_interval = poll_interval
        self.default_max_tokens = default_max_tokens
        # degradation under faults: transient routing failures (e.g. a
        # crash mid-recovery) are retried this many times before a 503
        self.submit_retries = submit_retries
        self.state = "created"        # serving -> draining -> stopped
        self.codes: Dict[int, int] = {}
        self.streamed_tokens = 0
        self.disconnects = 0          # client-gone streams cancelled
        self.final_report = None
        self._streams: Dict[int, asyncio.Queue] = {}
        self._req_ids = itertools.count(1)
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._stopped: Optional[asyncio.Event] = None

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> None:
        """Bind the listener, install signal handlers, start the pump.
        Returns once the gateway is accepting connections."""
        assert self.state == "created", f"start() in state {self.state}"
        self._stopped = asyncio.Event()
        self.cluster.start()
        self._server = await asyncio.start_server(
            self._handle_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                loop.add_signal_handler(sig, self.begin_shutdown)
            except (NotImplementedError, RuntimeError, ValueError):
                # non-main-thread loops (test harness) can't install
                # handlers; begin_shutdown() is called directly there
                pass
        self.state = "serving"
        self._pump_task = asyncio.ensure_future(self._pump())

    def begin_shutdown(self) -> None:
        """SIGTERM entry point: stop admitting, let the pump finish all
        in-flight work, then tear down. Safe to call more than once and
        from a signal handler (sync, no awaits)."""
        if self.state in ("draining", "stopped"):
            return
        self.state = "draining"

    async def serve_until_stopped(self) -> None:
        await self._stopped.wait()

    async def _pump(self) -> None:
        """The cluster's event loop: poll on the cluster clock, fan
        events out to request streams, and — once draining — exit when
        everything in flight has finished *and* been flushed."""
        try:
            while True:
                events = self.cluster.poll(self.cluster.clock())
                for ev in events:
                    q = self._streams.get(ev.req.req_id)
                    if q is not None:
                        q.put_nowait(ev)
                # injector-driven client drops (disconnect_client
                # faults): sever the matching live SSE stream
                take = getattr(self.cluster, "take_disconnects", None)
                for target in (take() if take is not None else ()):
                    req_id = target if target in self._streams else (
                        next(iter(self._streams), None))
                    if req_id is None:
                        continue
                    self._streams[req_id].put_nowait(
                        _DISCONNECT_EVENT)
                if self.state == "draining" and self.cluster.idle() \
                        and not self._streams:
                    break
                await asyncio.sleep(self.poll_interval)
        finally:
            await self._teardown()

    async def _teardown(self) -> None:
        self.final_report = self.cluster.report()
        self.cluster.close()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.state = "stopped"
        self._stopped.set()

    # -- connection handling ----------------------------------------------
    async def _handle_connection(self, reader, writer) -> None:
        try:
            while True:
                try:
                    req = await http.read_request(reader)
                except http.BadRequest as e:
                    await self._send(writer, 400, {"error": str(e)},
                                     close=True)
                    break
                if req is None:
                    break
                close = await self._route(req, writer, reader)
                if close or not req.wants_keepalive:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _send(self, writer, status: int, body=b"", *,
                    content_type: str = "application/json",
                    headers: Optional[Dict[str, str]] = None,
                    close: bool = False) -> bool:
        self.codes[status] = self.codes.get(status, 0) + 1
        writer.write(http.response_bytes(status, body,
                                         content_type=content_type,
                                         headers=headers, close=close))
        await writer.drain()
        return close

    async def _route(self, req: http.HttpRequest, writer,
                     reader=None) -> bool:
        """Dispatch one request; returns True when the connection must
        close (SSE streams are close-delimited)."""
        method, path = req.method, req.path
        if path == "/healthz" and method == "GET":
            return await self._send(writer, 200, {
                "status": "ok" if self.state == "serving" else self.state,
                "pending": self.cluster.pending(),
                "servers": len(self.cluster.orch.placeable_servers()),
                "adapters": len(self.cluster.meta),
            })
        if path == "/metrics" and method == "GET":
            text = render_metrics(
                self.cluster.snapshot(),
                self.cluster.hub.snapshot(self.cluster.clock()),
                {"state": self.state, "codes": self.codes,
                 "streamed_tokens": self.streamed_tokens,
                 "rejected": self.admission.rejected,
                 "open_streams": len(self._streams),
                 "disconnects": self.disconnects})
            return await self._send(
                writer, 200, text,
                content_type="text/plain; version=0.0.4; charset=utf-8")
        if path == "/v1/adapters" and method == "GET":
            return await self._send(
                writer, 200,
                {"adapters": self.cluster.adapter_entries()})
        if path == "/v1/adapters" and method == "POST":
            return await self._register_adapter(req, writer)
        if path.startswith("/v1/adapters/") and method == "DELETE":
            return await self._unregister_adapter(
                path[len("/v1/adapters/"):], writer)
        if path == "/v1/completions" and method == "POST":
            return await self._completions(req, writer, reader)
        if path in ("/healthz", "/metrics", "/v1/adapters",
                    "/v1/completions"):
            return await self._send(writer, 405,
                                    {"error": f"{method} not allowed"})
        return await self._send(writer, 404,
                                {"error": f"no route for {path}"})

    # -- adapter lifecycle -------------------------------------------------
    async def _register_adapter(self, req, writer) -> bool:
        if self.state != "serving":
            return await self._send(writer, 503,
                                    {"error": "gateway is draining"})
        body = req.json()
        aid = body.get("adapter_id") or body.get("id")
        rank = body.get("rank")
        if not aid or not isinstance(rank, int) or rank <= 0:
            return await self._send(writer, 400, {
                "error": "body must carry adapter_id and a positive "
                         "integer rank"})
        info = AdapterInfo(adapter_id=str(aid), rank=rank,
                           nbytes=int(body.get("nbytes",
                                               DEFAULT_ADAPTER_NBYTES)))
        try:
            sid = self.cluster.register_adapter(info,
                                                now=self.cluster.clock())
        except ValueError as e:
            return await self._send(writer, 409, {"error": str(e)})
        return await self._send(writer, 201, {
            "adapter_id": info.adapter_id, "rank": info.rank,
            "nbytes": info.nbytes, "server": sid})

    async def _unregister_adapter(self, aid: str, writer) -> bool:
        try:
            self.cluster.unregister_adapter(aid,
                                            now=self.cluster.clock())
        except UnknownAdapterError:
            return await self._send(writer, 404, {
                "error": f"adapter {aid!r} is not registered"})
        return await self._send(writer, 202, {
            "adapter_id": aid, "draining": True})

    # -- completions -------------------------------------------------------
    def _build_request(self, body: dict) -> ServeRequest:
        prompt = body.get("prompt")
        max_tokens = int(body.get("max_tokens",
                                  self.default_max_tokens))
        if max_tokens <= 0:
            raise http.BadRequest("max_tokens must be positive")
        aid = body.get("adapter_id") or body.get("model")
        if not aid:
            raise http.BadRequest("body must carry adapter_id (or model)")
        if prompt is not None and not (
                isinstance(prompt, list)
                and all(isinstance(t, int) for t in prompt)):
            raise http.BadRequest("prompt must be a list of token ids")
        plen = body.get("prompt_len",
                        len(prompt) if prompt is not None else 8)
        if not isinstance(plen, int) or plen <= 0:
            raise http.BadRequest("prompt_len must be a positive integer")
        return ServeRequest(
            req_id=next(self._req_ids), adapter_id=str(aid),
            prompt_len=plen, output_len=max_tokens,
            arrival=self.cluster.clock(),
            prompt=list(prompt) if prompt is not None else None)

    async def _completions(self, req, writer, reader=None) -> bool:
        if self.state != "serving":
            return await self._send(
                writer, 503, {"error": "gateway is draining"},
                headers={"Retry-After": "1.000"})
        body = req.json()
        try:
            sreq = self._build_request(body)
        except http.BadRequest as e:
            return await self._send(writer, 400, {"error": str(e)})
        tenant = req.headers.get("x-tenant") or body.get("user") \
            or "default"
        tracer = getattr(self.cluster, "tracer", None)
        t_adm = self.cluster.clock()
        ok, retry_after, reason = self.admission.admit(tenant, t_adm)
        if tracer is not None:
            tracer.record("admission", t_adm, self.cluster.clock(),
                          cat="gateway", track="gateway",
                          req_id=sreq.req_id,
                          attrs={"tenant": tenant, "admitted": ok,
                                 "reason": reason})
        if not ok:
            return await self._send(
                writer, 429,
                {"error": f"admission refused ({reason})",
                 "tenant": tenant, "retry_after": retry_after},
                headers={"Retry-After": f"{max(retry_after, 0.001):.3f}"})
        # register the stream before submitting: the first poll may
        # already carry this request's events
        queue: asyncio.Queue = asyncio.Queue()
        self._streams[sreq.req_id] = queue
        try:
            server = None
            for attempt in range(max(1, self.submit_retries)):
                try:
                    server = self.cluster.submit(sreq,
                                                 self.cluster.clock())
                    break
                except UnknownAdapterError as e:
                    return await self._send(writer, 404,
                                            {"error": str(e)})
                except RuntimeError:
                    # transient routing failure (crash mid-recovery):
                    # let the pump's next poll repair placement, retry
                    await asyncio.sleep(self.poll_interval)
            else:
                return await self._send(
                    writer, 503,
                    {"error": "no server available (recovering)"},
                    headers={"Retry-After":
                             f"{max(self.poll_interval * 10, 0.05):.3f}"})
            if tracer is not None:
                # HTTP receive -> routed/submitted on the cluster clock
                tracer.record("gateway.receive", sreq.arrival,
                              self.cluster.clock(), cat="gateway",
                              track="gateway", req_id=sreq.req_id,
                              attrs={"tenant": tenant, "server": server,
                                     "adapter_id": sreq.adapter_id})
            if body.get("stream", True):
                return await self._stream_response(sreq, server, queue,
                                                   writer, reader)
            return await self._json_response(sreq, server, queue, writer)
        finally:
            self._streams.pop(sreq.req_id, None)
            self.admission.release(tenant)

    def _client_gone(self, sreq) -> bool:
        """The client vanished mid-stream: cancel the request so its
        slot, KV pages and admission token free immediately instead of
        decoding to a dead socket (the pre-chaos gateway leaked the
        slot until the request ran to completion)."""
        self.disconnects += 1
        self.cluster.cancel_request(sreq.req_id)
        return True

    async def _next_stream_event(self, queue, eof_task):
        """Await the next stream event, racing the connection's EOF
        watcher. Returns ``(event, eof_task)``; the event is the
        disconnect sentinel when the client went away."""
        if eof_task is None or eof_task.done():
            return await queue.get(), eof_task
        get_task = asyncio.ensure_future(queue.get())
        await asyncio.wait({get_task, eof_task},
                           return_when=asyncio.FIRST_COMPLETED)
        if eof_task.done():
            try:
                data = eof_task.result()
            except (ConnectionError, OSError):
                data = b""
            if not data:          # EOF: the client hung up
                get_task.cancel()
                return _DISCONNECT_EVENT, None
            eof_task = None       # stray bytes mid-SSE: stop watching
        return await get_task, eof_task

    async def _stream_response(self, sreq, server: int, queue,
                               writer, reader=None) -> bool:
        self.codes[200] = self.codes.get(200, 0) + 1
        writer.write(http.sse_headers())
        await writer.drain()
        # disconnect watcher: an SSE client sends nothing after the
        # request, so a completed read means EOF (or a dying socket)
        eof_task = (asyncio.ensure_future(reader.read(1))
                    if reader is not None else None)
        index = 0
        finished = False
        try:
            while not finished:
                ev, eof_task = await self._next_stream_event(queue,
                                                             eof_task)
                if ev.kind == "disconnect":
                    return self._client_gone(sreq)
                if ev.kind == "timeout":
                    writer.write(http.sse_event(
                        {"id": f"cmpl-{sreq.req_id}",
                         "error": "timeout"}))
                    break
                if ev.tokens:
                    self.streamed_tokens += len(ev.tokens)
                    writer.write(http.sse_event({
                        "id": f"cmpl-{sreq.req_id}",
                        "object": "completion.chunk",
                        "adapter_id": sreq.adapter_id,
                        "index": index,
                        "tokens": list(ev.tokens)}))
                    index += len(ev.tokens)
                if ev.kind == "finish":
                    finished = True
                    writer.write(http.sse_event({
                        "id": f"cmpl-{sreq.req_id}",
                        "object": "completion.chunk",
                        "adapter_id": sreq.adapter_id,
                        "index": index,
                        "tokens": [],
                        "finish_reason": "stop",
                        "usage": self._usage(sreq, server)}))
                try:
                    await writer.drain()
                except (ConnectionError, OSError):
                    return self._client_gone(sreq)
            writer.write(http.sse_event("[DONE]"))
            try:
                await writer.drain()
            except (ConnectionError, OSError):
                return self._client_gone(sreq)
        finally:
            if eof_task is not None:
                eof_task.cancel()
        tracer = getattr(self.cluster, "tracer", None)
        if tracer is not None:
            t = self.cluster.clock()
            tracer.record("stream.finish", t, t, cat="gateway",
                          track="gateway", req_id=sreq.req_id,
                          attrs={"streamed": index})
        return True    # SSE streams are close-delimited

    async def _json_response(self, sreq, server: int, queue,
                             writer) -> bool:
        tokens = []
        while True:
            ev = await queue.get()
            if ev.kind == "timeout":
                return await self._send(writer, 503, {
                    "id": f"cmpl-{sreq.req_id}", "error": "timeout"})
            tokens.extend(t for t in ev.tokens)
            if ev.kind == "finish":
                break
        return await self._send(writer, 200, {
            "id": f"cmpl-{sreq.req_id}",
            "object": "completion",
            "adapter_id": sreq.adapter_id,
            "tokens": tokens,
            "usage": self._usage(sreq, server)})

    def _usage(self, sreq, server: int) -> dict:
        n_out = len(sreq.output) if sreq.output else sreq.decoded
        return {
            "prompt_tokens": sreq.prompt_len,
            "completion_tokens": n_out,
            "server": server,
            "ttft": sreq.ttft,
            "fetch_latency": sreq.fetch_latency,
        }
