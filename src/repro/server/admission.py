"""Per-tenant admission control: token-bucket rate limiting plus a
max-inflight fairness cap.

Every tenant gets its own bucket and inflight counter, so one greedy
tenant exhausts *its* budget (and starts seeing 429 + Retry-After)
while everyone else keeps admitting — the fairness property
``tests/test_server.py`` pins down. Both knobs are optional: a gateway
built with neither admits everything.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class TokenBucket:
    """Classic token bucket on the caller's clock: ``rate`` tokens/s
    refill up to ``burst`` capacity; one token per admission."""
    rate: float
    burst: float
    tokens: float = 0.0
    last: float = 0.0

    def __post_init__(self):
        self.tokens = self.burst

    def try_take(self, now: float) -> float:
        """Take one token. Returns 0.0 on success, else the seconds
        until a token will be available (the Retry-After hint)."""
        if now > self.last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last) * self.rate)
            self.last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return 0.0
        if self.rate <= 0:
            return float("inf")
        return (1.0 - self.tokens) / self.rate


class AdmissionController:
    """Gate one tenant's request at a time: rate bucket first, then the
    inflight cap. ``admit`` returns (ok, retry_after_seconds, reason);
    the caller must ``release`` every admitted request exactly once."""

    def __init__(self, rate: Optional[float] = None,
                 burst: Optional[float] = None,
                 max_inflight: Optional[int] = None):
        self.rate = rate
        self.burst = burst if burst is not None else \
            (max(1.0, rate) if rate is not None else 1.0)
        self.max_inflight = max_inflight
        self._buckets: Dict[str, TokenBucket] = {}
        self._inflight: Dict[str, int] = {}
        self.admitted: Dict[str, int] = {}
        self.rejected: Dict[str, int] = {}

    def inflight(self, tenant: str) -> int:
        return self._inflight.get(tenant, 0)

    def admit(self, tenant: str, now: float
              ) -> Tuple[bool, float, str]:
        if self.max_inflight is not None \
                and self.inflight(tenant) >= self.max_inflight:
            self.rejected[tenant] = self.rejected.get(tenant, 0) + 1
            # no rate involved: a slot frees when a request finishes,
            # so the hint is a short fixed backoff
            return False, 0.1, "max-inflight"
        if self.rate is not None:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets[tenant] = TokenBucket(
                    rate=self.rate, burst=self.burst, last=now)
            wait = bucket.try_take(now)
            if wait > 0:
                self.rejected[tenant] = self.rejected.get(tenant, 0) + 1
                return False, wait, "rate"
        self._inflight[tenant] = self.inflight(tenant) + 1
        self.admitted[tenant] = self.admitted.get(tenant, 0) + 1
        return True, 0.0, ""

    def release(self, tenant: str) -> None:
        n = self.inflight(tenant)
        assert n > 0, f"release without admit for tenant {tenant!r}"
        self._inflight[tenant] = n - 1
