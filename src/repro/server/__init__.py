"""Streaming serving surface: asyncio HTTP gateway over the
incremental ``LoRAServeCluster`` API (``repro.server.gateway``), with
minimal HTTP/1.1 + SSE framing (``http``), per-tenant admission control
(``admission``), and Prometheus text exposition (``prom``)."""
from .admission import AdmissionController, TokenBucket
from .gateway import ServeGateway
from .prom import render_metrics

__all__ = ["AdmissionController", "TokenBucket", "ServeGateway",
           "render_metrics"]
