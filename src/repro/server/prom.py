"""Prometheus text-format exposition for the gateway's ``/metrics``.

Pure rendering: takes an incremental ``ClusterReport`` snapshot, a
``TelemetryHub.snapshot()`` dict (the live sliding window), and the
gateway's own HTTP counters; emits the text format a Prometheus scraper
ingests. Empty or still-warming windows simply omit their series
(NaN/None values are skipped, never rendered).
"""
from __future__ import annotations

import math
from typing import Dict, List


def _ok(v) -> bool:
    return v is not None and isinstance(v, (int, float)) \
        and math.isfinite(v)


def _fmt(v) -> str:
    if isinstance(v, float):
        return repr(v)
    return str(v)


class _Writer:
    def __init__(self):
        self.lines: List[str] = []

    def metric(self, name: str, mtype: str, help_text: str,
               samples: List) -> None:
        """``samples`` is a list of (labels_dict_or_None, value); the
        whole family is omitted when no sample survives the NaN/None
        filter."""
        kept = [(labels, v) for labels, v in samples if _ok(v)]
        if not kept:
            return
        self.lines.append(f"# HELP {name} {help_text}")
        self.lines.append(f"# TYPE {name} {mtype}")
        for labels, v in kept:
            if labels:
                lab = ",".join(f'{k}="{val}"'
                               for k, val in sorted(labels.items()))
                self.lines.append(f"{name}{{{lab}}} {_fmt(v)}")
            else:
                self.lines.append(f"{name} {_fmt(v)}")

    def histogram(self, name: str, help_text: str, snap) -> None:
        """Render a ``Histogram.to_dict()`` snapshot as a real
        Prometheus ``histogram`` family: cumulative ``_bucket{le=...}``
        series plus ``_sum``/``_count``. Empty (or absent) histograms
        are omitted entirely, matching ``metric``'s behavior."""
        if not snap or not snap.get("count"):
            return
        self.lines.append(f"# HELP {name} {help_text}")
        self.lines.append(f"# TYPE {name} histogram")
        for le, cum in snap["buckets"]:
            le_s = le if isinstance(le, str) else _fmt(float(le))
            self.lines.append(f'{name}_bucket{{le="{le_s}"}} {cum}')
        self.lines.append(f"{name}_sum {_fmt(float(snap['sum']))}")
        self.lines.append(f"{name}_count {snap['count']}")

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


def render_metrics(report, telemetry: Dict,
                   gateway: Dict) -> str:
    """Render one scrape. ``report`` is a (possibly mid-flight)
    ``ClusterReport``; ``telemetry`` a ``TelemetryHub.snapshot``;
    ``gateway`` the gateway's counter dict (http codes, streamed
    tokens, admission rejections, state)."""
    w = _Writer()
    # -- gateway-level ---------------------------------------------------
    w.metric("repro_gateway_up", "gauge", "1 while serving, 0 draining",
             [(None, 1 if gateway.get("state") == "serving" else 0)])
    w.metric("repro_gateway_requests_total", "counter",
             "HTTP responses by status code",
             [({"code": str(code)}, n)
              for code, n in sorted(gateway.get("codes", {}).items())])
    w.metric("repro_gateway_streamed_tokens_total", "counter",
             "Tokens delivered over SSE streams",
             [(None, gateway.get("streamed_tokens", 0))])
    w.metric("repro_gateway_admission_rejected_total", "counter",
             "Requests refused by per-tenant admission control",
             [({"tenant": t, "reason": "any"}, n)
              for t, n in sorted(gateway.get("rejected", {}).items())])
    w.metric("repro_gateway_open_streams", "gauge",
             "SSE streams currently open",
             [(None, gateway.get("open_streams", 0))])
    w.metric("repro_gateway_client_disconnects_total", "counter",
             "Streams cancelled because the client went away",
             [(None, gateway.get("disconnects", 0))])
    # -- cluster state ---------------------------------------------------
    w.metric("repro_cluster_pending", "gauge",
             "Requests queued or running across all servers",
             [(None, report.in_progress)])
    w.metric("repro_cluster_servers", "gauge",
             "Active (placeable) servers",
             [(None, report.final_servers)])
    w.metric("repro_cluster_completed_total", "counter",
             "Requests finished since start",
             [(None, report.completed())])
    w.metric("repro_cluster_timed_out_total", "counter",
             "Requests dropped by the admission timeout",
             [(None, report.timed_out)])
    w.metric("repro_cluster_rebalances_total", "counter",
             "Periodic placement timesteps fired",
             [(None, report.rebalances)])
    w.metric("repro_cluster_adapter_fetches_total", "counter",
             "Miss-driven adapter fetches",
             [(None, report.fetches)])
    w.metric("repro_cluster_adapter_fetch_bytes_total", "counter",
             "Bytes moved by miss-driven fetches",
             [(None, report.fetch_bytes)])
    w.metric("repro_cluster_remote_reads_total", "counter",
             "Misses served via peer GDR remote reads",
             [(None, report.remote_reads)])
    w.metric("repro_cluster_prefetches_total", "counter",
             "Rebalance-driven proactive adapter warms",
             [(None, report.prefetches)])
    w.metric("repro_cluster_adapters_registered_total", "counter",
             "Adapters registered at runtime",
             [(None, report.registered)])
    w.metric("repro_cluster_adapters_unregistered_total", "counter",
             "Adapters retired at runtime (loss-free drains)",
             [(None, report.unregistered)])
    w.metric("repro_cluster_max_adapters_per_server", "gauge",
             "Peak HBM adapter count on any one server",
             [(None, report.max_adapters_per_server)])
    # -- whole-run latency (report percentiles are snapshot-safe) --------
    w.metric("repro_cluster_ttft_seconds", "gauge",
             "TTFT percentiles over all finished requests",
             [({"quantile": "0.5"}, report.p50_ttft()),
              ({"quantile": "0.95"}, report.p95_ttft())])
    w.metric("repro_cluster_tbt_seconds", "gauge",
             "Mean/P95 time-between-tokens over finished requests",
             [({"quantile": "mean"}, report.mean_tbt()),
              ({"quantile": "0.95"}, report.p95_tbt())])
    # -- live sliding window (TelemetryHub) ------------------------------
    w.metric("repro_window_ttft_seconds", "gauge",
             "Windowed TTFT percentiles (live sliding window)",
             [({"quantile": "0.5"}, telemetry.get("ttft_p50")),
              ({"quantile": "0.95"}, telemetry.get("ttft_p95"))])
    w.metric("repro_window_tbt_seconds", "gauge",
             "Windowed TBT percentiles (live sliding window)",
             [({"quantile": "0.5"}, telemetry.get("tbt_p50")),
              ({"quantile": "0.95"}, telemetry.get("tbt_p95"))])
    w.metric("repro_window_arrivals_total", "counter",
             "Requests routed since start",
             [(None, telemetry.get("arrivals"))])
    w.metric("repro_window_server_token_rate", "gauge",
             "Windowed per-server token throughput (tokens/s)",
             [({"server": str(sid)}, rate) for sid, rate in
              sorted(telemetry.get("server_token_rates", {}).items())])
    w.metric("repro_window_adapter_token_rate", "gauge",
             "Windowed per-adapter token demand (tokens/s)",
             [({"adapter": aid}, rate) for aid, rate in
              sorted(telemetry.get("adapter_token_rates", {}).items())])
    # -- cumulative latency histograms -----------------------------------
    w.histogram("repro_ttft_seconds",
                "TTFT distribution over all finished requests",
                telemetry.get("ttft_hist"))
    w.histogram("repro_tbt_seconds",
                "Time-between-tokens distribution over finished requests",
                telemetry.get("tbt_hist"))
    # -- cost-model drift (tracer-fed, empty without a tracer) -----------
    drift = getattr(report, "cost_drift", None) or {}
    w.metric("repro_costmodel_seconds_total", "counter",
             "Modeled vs measured phase time accumulated by the tracer",
             [({"phase": ph, "kind": kind}, d.get(f"{kind}_s"))
              for ph, d in sorted(drift.items())
              for kind in ("modeled", "measured")])
    w.metric("repro_costmodel_iterations_total", "counter",
             "Iteration spans paired with a cost-model prediction",
             [({"phase": ph}, d.get("count"))
              for ph, d in sorted(drift.items())])
    w.metric("repro_costmodel_drift_ratio", "gauge",
             "Signed (measured-modeled)/modeled bias per phase",
             [({"phase": ph}, d.get("bias"))
              for ph, d in sorted(drift.items())])
    w.metric("repro_costmodel_mean_abs_rel_err", "gauge",
             "Mean absolute relative error of the phase cost model",
             [({"phase": ph}, d.get("mean_abs_rel_err"))
              for ph, d in sorted(drift.items())])
    return w.render()
