"""Exhaustive-interleaving model checker for the adapter / control-plane
state machines.

Drives the REAL implementations — ``repro.core.pool.AdapterStore``,
``repro.cluster.network.NetworkModel``, ``repro.core.routing.
RoutingTable`` — through every interleaving of a bounded action
alphabet (access / rebalance / scale-up / drain / retire / clock
advance) via breadth-first search over canonicalized states, and checks
the cluster's safety + liveness invariants at every reachable state:

* **inflight-src-resident** — GC never frees an adapter copy that an
  in-flight transfer is sourcing from (the PR 3 GC-vs-fetch race,
  re-found mechanically when the ``_gc`` in-flight guard is removed);
* **min-copy / index-consistent / tier-exclusive** — every adapter has
  ≥ 1 HBM copy, ``index`` and ``local`` agree, and no adapter sits in a
  server's HBM and host tiers simultaneously (residency is exactly what
  the store claims);
* **retired-silent** — a retired server holds no copies in any tier,
  feeds no transfers, and appears in no routing entry (no request can
  be routed to it);
* **link-occupancy** — per-source egress slots in the network model
  exactly match the store's in-flight plans (never negative, never
  leaked);
* **drain-termination** (liveness) — from any state with a draining
  server, advancing the clock alone empties it in finitely many steps
  so retirement is enabled.

The invariants are shared with the opt-in runtime debug hook:
``AdapterStore.check_invariants()`` / the simulator's
``REPRO_CHECK_INVARIANTS=1`` path call :func:`check_store_invariants`
on live objects, so sim runs validate what the checker proves
exhaustively on small models.

No external dependencies (and no jax): states are deep-copied real
objects; canonical keys use ETAs *relative to the model clock* so the
unbounded absolute clock does not blow up the state space. Telemetry
counters are excluded from the key for the same reason.
"""
from __future__ import annotations

import copy
import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

_EPS = 1e-12


# --------------------------------------------------------------------------
# Shared invariants (model checker + runtime debug hook)
# --------------------------------------------------------------------------


def check_store_invariants(store, now: float = 0.0,
                           routing=None,
                           closed_world: bool = False) -> List[str]:
    """Safety invariants over a live ``AdapterStore`` (+ its network
    model, + optionally the routing table). Returns human-readable
    violation strings; empty list means the state is consistent.

    ``closed_world=True`` (the model checker) additionally requires the
    network's egress slots to match the store's in-flight plans exactly
    — every transfer in the model is store-driven, so an extra slot is a
    leaked ``end_transfer``. At runtime other traffic shares the links
    (e.g. tests pre-loading a link via ``begin_transfer``), so only the
    ``slots >= plans`` direction is checked there."""
    errs: List[str] = []
    failed = getattr(store, "failed", set())
    lost = getattr(store, "lost", set())
    for aid in sorted(store.meta):
        holders = store.index.get(aid, set())
        if not holders:
            # fault plane: a crash can legitimately kill the last HBM
            # copy — the adapter is *recovering* (not breached) while a
            # re-warm fetch is in flight, a host-tier copy survives on a
            # live server, or the durable SSD tier owns it (store.lost)
            recovering = (
                aid in lost
                or store.inflight_count(aid) > 0
                or any(aid in store.host_cache[s]
                       for s in range(store.n_servers)
                       if s not in failed))
            if not recovering:
                errs.append(f"min-copy: adapter {aid!r} has zero HBM "
                            f"copies cluster-wide")
        for s in holders:
            if s >= store.n_servers or aid not in store.local[s]:
                errs.append(f"index-consistent: index says {aid!r} on "
                            f"server {s} but the server does not hold it")
    for s in range(store.n_servers):
        for aid in store.local[s]:
            if s not in store.index.get(aid, set()):
                errs.append(f"index-consistent: server {s} holds {aid!r} "
                            f"but the index does not know")
        overlap = store.local[s] & set(store.host_cache[s])
        if overlap:
            errs.append(f"tier-exclusive: {sorted(overlap)} in both HBM "
                        f"and host tiers of server {s}")
        if store.host_cache_used(s) > store.host_cache_bytes:
            errs.append(f"host-cache-budget: server {s} host tier "
                        f"over budget")
    for (dest, aid), p in sorted(store._inflight.items()):
        if p.src_server >= 0 and aid not in store.local[p.src_server]:
            errs.append(
                f"inflight-src-resident: fetch of {aid!r} to server "
                f"{dest} sources server {p.src_server}, which no longer "
                f"holds a copy (GC-vs-fetch race)")
        if dest in store.retired:
            errs.append(f"retired-silent: in-flight fetch of {aid!r} "
                        f"targets retired server {dest}")
    for s in sorted(store.retired):
        if store.local[s] or store.host_cache[s]:
            errs.append(f"retired-silent: retired server {s} still "
                        f"holds copies")
        if store.inflight_from(s) or store.inflight_to(s):
            errs.append(f"retired-silent: retired server {s} still "
                        f"feeds transfers")
    for s in sorted(failed):
        # confirmed-dead silence: a crashed server holds nothing and
        # neither feeds nor receives transfers until restored
        if store.local[s] or store.host_cache[s]:
            errs.append(f"failed-silent: failed server {s} still "
                        f"holds copies")
        if store.inflight_from(s) or store.inflight_to(s):
            errs.append(f"failed-silent: failed server {s} still "
                        f"feeds transfers")
    net = store.network
    if net is not None:
        live_plans: Dict[int, int] = {}
        for p in store._inflight.values():
            if p.src_server >= 0 and p.eta > now + _EPS:
                live_plans[p.src_server] = \
                    live_plans.get(p.src_server, 0) + 1
        srcs = set(net._egress) | set(live_plans)
        for src in sorted(srcs):
            slots = len([t for t in net._egress.get(src, [])
                         if t > now + _EPS])
            plans = live_plans.get(src, 0)
            bad = (slots != plans) if closed_world else (slots < plans)
            if bad:
                errs.append(
                    f"link-occupancy: server {src} egress has {slots} "
                    f"occupied slots but {plans} live in-flight plans")
    if routing is not None:
        # a confirmed-dead (failed) server must never receive a route —
        # the chaos-plane invariant — alongside the retired-silent one
        dead = set(routing.blocked) | set(store.retired) | set(failed)
        for aid, entry in sorted(routing._table.items()):
            for sid, phi in entry:
                if sid in dead:
                    errs.append(f"retired-silent: routing entry for "
                                f"{aid!r} references dead server "
                                f"{sid}")
                if phi < -_EPS:
                    errs.append(f"routing: negative phi for {aid!r} on "
                                f"server {sid}")
            tot = sum(phi for _, phi in entry)
            if entry and abs(tot - 1.0) > 1e-6:
                errs.append(f"routing: phi for {aid!r} sums to {tot}")
    return errs


# --------------------------------------------------------------------------
# The model
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ModelConfig:
    """A bounded protocol model: initial fleet + action alphabet."""
    n_servers: int = 2
    adapters: Tuple[Tuple[str, int], ...] = (("a0", 64 << 20),
                                             ("a1", 64 << 20))
    seed_placement: Optional[dict] = None
    rebalance_templates: Tuple[dict, ...] = ()
    max_servers: int = 3          # add_server enabled below this
    enable_add_server: bool = True
    enable_drain: bool = False
    max_depth: int = 8
    max_states: int = 200_000
    host_cache_bytes: int = 512 << 20
    store_cls: Optional[type] = None   # test hook: inject a buggy store
    fabric: str = "ib_gdr"
    enable_crash: bool = False         # crash_server / restore_server
    enable_stall: bool = False         # fetch_timeout (stall + retry)
    durable_ssd: bool = False          # SSD recovers last-copy loss


@dataclasses.dataclass
class Violation:
    invariant: str
    message: str
    trace: Tuple[str, ...]


@dataclasses.dataclass
class CheckResult:
    states: int
    transitions: int
    violations: List[Violation]
    truncated: bool = False       # state/depth cap hit: NOT exhaustive

    @property
    def ok(self) -> bool:
        return not self.violations


class World:
    """One model state: real store + network + routing + a clock."""

    def __init__(self, cfg: ModelConfig):
        from repro.cluster.network import NetworkModel
        from repro.core.pool import AdapterStore
        from repro.core.routing import RoutingTable
        from repro.core.types import AdapterInfo

        self.cfg = cfg
        infos = [AdapterInfo(aid, rank=8, nbytes=nb)
                 for aid, nb in cfg.adapters]
        store_cls = cfg.store_cls or AdapterStore
        self.network = NetworkModel(fabric=cfg.fabric)
        self.store = store_cls(cfg.n_servers, infos,
                               network=self.network,
                               host_cache_bytes=cfg.host_cache_bytes,
                               durable_ssd=cfg.durable_ssd)
        placement = cfg.seed_placement or {
            aid: {i % cfg.n_servers: 1.0}
            for i, (aid, _) in enumerate(cfg.adapters)}
        self.store.seed(placement)
        self.routing = RoutingTable(placement)
        self.now = 0.0

    def clone(self) -> "World":
        return copy.deepcopy(self)

    # -- canonical state key (clock-relative, telemetry-free) -----------
    def key(self) -> tuple:
        s = self.store
        # ETA abstraction: completion *rank* plus a coarse (1 ms) grid
        # bucket. Exact clock-relative offsets accumulate unboundedly
        # many distinct values (every overlap shifts them), while the
        # protocol's decisions depend only on completion order and link
        # load — which rank+bucket preserve — so this keeps the BFS
        # finite without hiding interleavings.
        pending = sorted({round(p.eta - self.now, 9)
                          for p in s._inflight.values()
                          if self.now + _EPS < p.eta < float("inf")})
        def rel(t: float) -> tuple:
            if t == float("inf"):     # stalled / retry-wait sentinel
                return (10 ** 9, -1)
            if t <= self.now + _EPS:
                return (-1, 0)
            r = round(t - self.now, 9)
            rank = pending.index(r) if r in pending else len(pending)
            return (rank, round((t - self.now) / 1e-3))
        inflight = tuple(sorted(
            (dest, aid, p.src_server, p.source, rel(p.eta),
             p.attempt, p.stalled,
             rel(p.retry_at) if p.retry_at >= 0 else (-2, 0))
            for (dest, aid), p in s._inflight.items()))
        egress = tuple(sorted(
            (src, tuple(sorted(rel(t) for t in etas if t > self.now
                               + _EPS)))
            for src, etas in self.network._egress.items()
            if any(t > self.now + _EPS for t in etas)))
        table = tuple(sorted(
            (aid, tuple((sid, round(phi, 9)) for sid, phi in entry))
            for aid, entry in self.routing._table.items()))
        return (
            s.n_servers,
            tuple(tuple(sorted(loc)) for loc in s.local),
            tuple(tuple(sorted(hc)) for hc in s.host_cache),
            tuple(sorted((aid, tuple(sorted(v)))
                         for aid, v in s.desired.items())),
            tuple(sorted(s.draining)), tuple(sorted(s.retired)),
            tuple(sorted(s.failed)), tuple(sorted(s.lost)),
            inflight, egress, table,
            tuple(sorted(self.routing.blocked)),
        )

    def invariant_errors(self) -> List[str]:
        return check_store_invariants(self.store, self.now, self.routing,
                                      closed_world=True)

    # -- actions --------------------------------------------------------
    def enabled_actions(self) -> List[Tuple[str, Callable[["World"], None]]]:
        cfg, s = self.cfg, self.store
        acts: List[Tuple[str, Callable[["World"], None]]] = []
        live = [sid for sid in s.live_servers() if sid not in s.draining]
        for sid in live:
            for aid, _ in cfg.adapters:
                acts.append((f"access({sid},{aid})",
                             _mk_access(sid, aid)))
        for i, tmpl in enumerate(cfg.rebalance_templates):
            if all(sid < s.n_servers and sid not in s.retired
                   and sid not in s.draining
                   for entry in tmpl.values() for sid in entry):
                acts.append((f"rebalance(t{i})", _mk_rebalance(tmpl)))
        if cfg.enable_add_server and s.n_servers < cfg.max_servers:
            acts.append(("add_server", _do_add_server))
        if cfg.enable_drain:
            for sid in live:
                # keep at least one live non-draining server
                if len(live) > 1 and not s.draining:
                    acts.append((f"drain({sid})", _mk_drain(sid)))
            for sid in sorted(s.draining):
                if not s.local[sid] and not s.inflight_from(sid) \
                        and not s.inflight_to(sid):
                    acts.append((f"retire({sid})", _mk_retire(sid)))
        if cfg.enable_crash:
            for sid in live:
                if len(live) > 1:        # never crash the last server
                    acts.append((f"crash_server({sid})", _mk_crash(sid)))
            for sid in sorted(s.failed):
                acts.append((f"restore_server({sid})", _mk_restore(sid)))
        if cfg.enable_stall:
            for (dest, aid), p in sorted(s._inflight.items()):
                if p.retry_at < 0 and not p.stalled:
                    acts.append((f"fetch_timeout({dest},{aid})",
                                 _mk_stall(dest, aid)))
        if s.next_event_time(self.now) is not None:
            acts.append(("advance", _do_advance))
        return acts


class ExpectedRefusal(Exception):
    """An action the protocol legitimately refuses (no-op transition)."""


def _mk_access(sid: int, aid: str):
    def act(w: World):
        try:
            w.store.start_fetch(sid, aid, now=w.now)
        except RuntimeError as e:   # draining/retired refusal is correct
            raise ExpectedRefusal(str(e))
    return act


def _mk_rebalance(tmpl: dict):
    def act(w: World):
        w.routing.update(tmpl)
        w.store.apply_placement(tmpl, now=w.now, prefetch=True)
    return act


def _do_add_server(w: World):
    w.store.add_server()


def _mk_drain(sid: int):
    def act(w: World):
        live = [x for x in w.store.live_servers()
                if x != sid and x not in w.store.draining]
        placement: Dict[str, Dict[int, float]] = {}
        for aid, entry in w.routing._table.items():
            kept = {s: phi for s, phi in entry if s != sid}
            placement[aid] = kept or {live[0]: 1.0}
        w.routing.update(placement)
        w.store.apply_placement(placement, now=w.now)
        w.store.drain_server(sid, now=w.now)
    return act


def _mk_retire(sid: int):
    def act(w: World):
        w.store.retire_server(sid)
        w.routing.block_server(sid)
    return act


def _mk_crash(sid: int):
    """Confirmed-dead handling, mirroring ``Orchestrator.fail_server``:
    drop every copy the dead server held, re-place its adapters onto
    survivors (prefetch re-warms), then block routing — block comes
    last so renormalization never strands an empty entry."""
    def act(w: World):
        live = [x for x in w.store.live_servers() if x != sid]
        if not live:
            raise ExpectedRefusal("last live server")
        w.store.fail_server(sid, now=w.now)
        placement: Dict[str, Dict[int, float]] = {}
        for aid, entry in w.routing._table.items():
            kept = {s: phi for s, phi in entry if s != sid}
            tot = sum(kept.values())
            if kept and tot > 0:
                placement[aid] = {s: phi / tot
                                  for s, phi in kept.items()}
            else:
                placement[aid] = {live[0]: 1.0}
        w.routing.update(placement)
        w.store.apply_placement(placement, now=w.now, prefetch=True)
        w.routing.block_server(sid)
    return act


def _mk_restore(sid: int):
    def act(w: World):
        w.store.restore_server(sid)
        w.routing.unblock_server(sid)
    return act


def _mk_stall(dest: int, aid: str):
    def act(w: World):
        if not w.store.stall_transfer(dest, aid):
            raise ExpectedRefusal("no stallable transfer")
    return act


def _do_advance(w: World):
    t = w.store.next_event_time(w.now)
    if t is None:
        raise ExpectedRefusal("no pending event")
    w.now = max(w.now, t)
    w.store.poll(w.now)


def _drain_terminates(w: World, max_steps: int = 64) -> Optional[str]:
    """Liveness probe: advancing the clock alone must empty every
    draining server (enabling retirement) in finitely many steps."""
    probe = w.clone()
    for _ in range(max_steps):
        if probe.store.next_event_time(probe.now) is None:
            break
        _do_advance(probe)
    else:
        return "drain-termination: transfers still pending after " \
               f"{max_steps} clock advances"
    for sid in sorted(probe.store.draining):
        if probe.store.local[sid]:
            return (f"drain-termination: draining server {sid} still "
                    f"holds {sorted(probe.store.local[sid])} after all "
                    f"transfers landed — it can never retire")
        if probe.store.inflight_from(sid) or probe.store.inflight_to(sid):
            return (f"drain-termination: draining server {sid} still "
                    f"has transfers in flight after quiescence")
    return None


def _fetch_terminates(w: World, max_steps: int = 64) -> Optional[str]:
    """Liveness probe for the chaos plane: no fetch waits forever. From
    any state with in-flight transfers, advancing the clock alone must
    land or retry every one of them to completion — a transfer whose
    source died must fail over (backoff → alternate source / SSD), not
    hang."""
    probe = w.clone()
    for _ in range(max_steps):
        if not probe.store._inflight:
            return None
        if probe.store.next_event_time(probe.now) is None:
            break
        try:
            _do_advance(probe)
        except Exception as e:
            return (f"fetch-liveness: clock advance raised "
                    f"{type(e).__name__}: {e}")
    if probe.store._inflight:
        stuck = sorted(probe.store._inflight)
        return (f"fetch-liveness: transfers {stuck} still in flight "
                f"after {max_steps} clock advances — a fetch is "
                f"waiting forever (dead source never failed over)")
    return None


# --------------------------------------------------------------------------
# BFS driver
# --------------------------------------------------------------------------


def check_model(cfg: ModelConfig,
                max_violations: int = 10) -> CheckResult:
    """Breadth-first exploration of every action interleaving up to
    ``cfg.max_depth``, deduplicating on the canonical state key."""
    root = World(cfg)
    violations: List[Violation] = []
    truncated = False

    def record(world: World, trace: Tuple[str, ...]) -> bool:
        errs = world.invariant_errors()
        if cfg.enable_drain and not errs and world.store.draining:
            live = _drain_terminates(world)
            if live:
                errs = [live]
        if (cfg.enable_crash or cfg.enable_stall) and not errs \
                and world.store._inflight:
            live = _fetch_terminates(world)
            if live:
                errs = [live]
        for e in errs:
            violations.append(Violation(e.split(":", 1)[0], e, trace))
        return bool(errs)

    seen = {root.key(): ()}
    queue = deque([(root, ())])
    transitions = 0
    record(root, ())
    while queue and len(violations) < max_violations:
        world, trace = queue.popleft()
        if len(trace) >= cfg.max_depth:
            truncated = True
            continue
        for label, act in world.enabled_actions():
            nxt = world.clone()
            try:
                act(nxt)
            except ExpectedRefusal:
                continue
            except Exception as e:   # unexpected crash is a finding
                violations.append(Violation(
                    "crash", f"{type(e).__name__}: {e}",
                    trace + (label,)))
                continue
            transitions += 1
            k = nxt.key()
            if k in seen:
                continue
            ntrace = trace + (label,)
            seen[k] = ntrace
            if record(nxt, ntrace):
                continue             # don't explore past a violation
            if len(seen) >= cfg.max_states:
                truncated = True
                queue.clear()
                break
            queue.append((nxt, ntrace))
    return CheckResult(states=len(seen), transitions=transitions,
                       violations=violations, truncated=truncated)


# --------------------------------------------------------------------------
# The small-model suite (run by `python -m repro.analysis` and CI)
# --------------------------------------------------------------------------


def fetch_gc_model(store_cls: Optional[type] = None,
                   max_depth: int = 7) -> ModelConfig:
    """The 2-server/2-adapter fetch+rebalance model (growable to 3 via
    scale-up): reaches the PR 3 GC-vs-fetch race in 4 actions when the
    ``_gc`` in-flight guard is removed — rebalance a0 onto one server,
    scale up, fetch toward the new server (sourcing the stale copy),
    then a hit on the placed server GCs the source mid-flight."""
    return ModelConfig(
        n_servers=2,
        adapters=(("a0", 64 << 20), ("a1", 64 << 20)),
        seed_placement={"a0": {0: 0.5, 1: 0.5}, "a1": {0: 1.0}},
        rebalance_templates=({"a0": {1: 1.0}, "a1": {0: 1.0}},),
        max_servers=3, enable_add_server=True, enable_drain=False,
        max_depth=max_depth, store_cls=store_cls)


def drain_retire_model(store_cls: Optional[type] = None,
                       max_depth: int = 7) -> ModelConfig:
    """2-server/2-adapter drain→retire lifecycle: every interleaving of
    accesses, a rebalance that spreads copies (creating in-flight
    transfers for drains to race with), a drain of either server, clock
    advances and the final retire + routing block."""
    return ModelConfig(
        n_servers=2,
        adapters=(("a0", 64 << 20), ("a1", 64 << 20)),
        seed_placement={"a0": {0: 1.0}, "a1": {1: 1.0}},
        rebalance_templates=({"a0": {0: 0.5, 1: 0.5},
                              "a1": {1: 1.0}},),
        max_servers=2, enable_add_server=False, enable_drain=True,
        max_depth=max_depth, store_cls=store_cls)


def crash_recovery_model(store_cls: Optional[type] = None,
                         max_depth: int = 8) -> ModelConfig:
    """2-server/2-adapter chaos model: every interleaving of accesses,
    crashes of either server (with survivor re-placement + routing
    block), restores, injected fetch stalls and clock advances. Checks
    that a confirmed-dead server never receives a route or feeds a
    transfer, that losing the last HBM copy recovers via SSD instead
    of breaching min-copy, and (fetch-liveness) that no fetch waits
    forever on a dead or stalled source — retry must fail over."""
    return ModelConfig(
        n_servers=2,
        adapters=(("a0", 64 << 20), ("a1", 64 << 20)),
        seed_placement={"a0": {0: 0.5, 1: 0.5}, "a1": {1: 1.0}},
        max_servers=2, enable_add_server=False, enable_drain=False,
        enable_crash=True, enable_stall=True, durable_ssd=True,
        max_depth=max_depth, store_cls=store_cls)


def small_model_suite() -> List[Tuple[str, CheckResult]]:
    return [
        # depths chosen past each model's BFS fixpoint: the first two
        # come back with truncated=False, i.e. the full reachable state
        # space was explored; crash-recovery's fault alphabet keeps
        # minting fresh retry states, so it is depth-bounded instead
        ("fetch-gc", check_model(fetch_gc_model(max_depth=30))),
        ("drain-retire", check_model(drain_retire_model(max_depth=14))),
        ("crash-recovery", check_model(crash_recovery_model(max_depth=8))),
    ]
