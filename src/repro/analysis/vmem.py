"""Static Pallas VMEM / BlockSpec analyzer.

Extracts BlockSpec, scratch and grid shapes from every ``pl.pallas_call``
entry point in ``kernels/sgmv.py`` and ``kernels/flash.py`` by
symbolically executing the wrapper function bodies with array *stubs*
(shape + itemsize, no jax, no numpy), then evaluates worst-case per-core
VMEM bytes over the configured parameter space and checks them against
the v5e roofline constants in ``launch/mesh.py`` (read from its AST so
this module never imports jax).

The checked envelope:

* **production** — bf16 operands, the max ``d_model`` / LoRA rank set /
  head dim over every registered model config, ``block_t`` drawn from
  the defaults of the ``kernels/ops.py`` dispatch wrappers (that file's
  contribution: its wrappers are the only callers, so their defaults
  define the reachable block shapes). Violations are **errors**.
* **fp32 headroom probe** — the same shapes at fp32. fp32 runs in this
  repo are CPU interpret-mode (no VMEM constraint exists there), so a
  bust is reported as a **warning**: it documents that the kernel only
  fits the TPU budget in bf16.

Cost model: the Pallas TPU pipeline double-buffers every input and
output block, scratch is single-buffered —

    VMEM ≈ 2·Σ bytes(in blocks) + 2·bytes(out block) + Σ bytes(scratch)

Alignment checks: a block's last dim must be a multiple of the 128-wide
lane (or cover the whole operand dim); the second-to-last must be a
multiple of the 8-deep sublane (or be 1, or cover the operand dim).
Grid checks: every grid dim is a positive int and every block dim
divides its operand dim.

Rules: ``vmem-budget`` (error) / ``vmem-headroom`` (warning),
``vmem-align``, ``vmem-grid``, ``vmem-parse``, ``vmem-unregistered``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Tuple

from . import Finding, Severity

# --------------------------------------------------------------------------
# Value model for the mini symbolic interpreter
# --------------------------------------------------------------------------


class Opaque:
    """Unknown value (lambdas, jit machinery, interpret flags)."""

    def __repr__(self):
        return "<opaque>"


OPAQUE = Opaque()


class Arr:
    """Array stub: shape + itemsize, nothing else."""

    def __init__(self, shape: Tuple[int, ...], itemsize: int):
        self.shape = tuple(int(s) for s in shape)
        self.itemsize = int(itemsize)

    def __repr__(self):
        return f"Arr{self.shape}x{self.itemsize}B"


class Dtype:
    def __init__(self, itemsize: int):
        self.itemsize = itemsize


class Block:
    """pl.BlockSpec stand-in (index_map is deliberately ignored)."""

    def __init__(self, shape: Tuple[int, ...]):
        self.shape = tuple(int(s) for s in shape)


class Scratch:
    """pltpu.VMEM scratch allocation."""

    def __init__(self, shape: Tuple[int, ...], itemsize: int):
        self.shape = tuple(int(s) for s in shape)
        self.itemsize = int(itemsize)

    def bytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n * self.itemsize


class KernelCall:
    """One captured pl.pallas_call site."""

    def __init__(self, fn_name: str, line: int):
        self.fn_name = fn_name
        self.line = line
        self.grid: Tuple[int, ...] = ()
        self.num_scalar_prefetch = 0
        self.in_specs: List[Block] = []
        self.out_specs: Optional[Block] = None
        self.scratch: List[Scratch] = []
        self.out_shape: Optional[Arr] = None
        self.operands: List[object] = []

    def vmem_bytes(self) -> int:
        """2x every in/out block (double-buffered pipeline) + scratch."""
        total = 0
        ops = [o for o in self.operands if isinstance(o, Arr)]
        # operands after the scalar-prefetch args align with in_specs
        data_ops = ops[self.num_scalar_prefetch:]
        for i, spec in enumerate(self.in_specs):
            itemsize = (data_ops[i].itemsize if i < len(data_ops) else 4)
            n = 1
            for s in spec.shape:
                n *= s
            total += 2 * n * itemsize
        if self.out_specs is not None and self.out_shape is not None:
            n = 1
            for s in self.out_specs.shape:
                n *= s
            total += 2 * n * self.out_shape.itemsize
        total += sum(s.bytes() for s in self.scratch)
        return total


_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "float64": 8, "int64": 8, "bool_": 1,
}


def _dotted(node: ast.AST) -> Optional[tuple]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


class _Halt(Exception):
    """Raised on a construct the interpreter can't model."""


class Evaluator:
    """Executes one wrapper-function body over stub values, recording
    every ``pl.pallas_call`` (spec shapes, grid, scratch, operands)."""

    def __init__(self, fn: ast.FunctionDef, env: Dict[str, object],
                 path: str):
        self.fn = fn
        self.path = path
        self.env = dict(env)
        self.calls: List[KernelCall] = []
        # seed keyword-only defaults not overridden by the env
        kw = fn.args.kwonlyargs
        for arg, default in zip(kw, fn.args.kw_defaults):
            if arg.arg not in self.env and default is not None:
                try:
                    self.env[arg.arg] = self.eval(default)
                except _Halt:
                    self.env[arg.arg] = OPAQUE

    # -- statements --------------------------------------------------------
    def run(self):
        for stmt in self.fn.body:
            self.exec_stmt(stmt)
        return self.calls

    def exec_stmt(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            val = self.eval(stmt.value)
            for tgt in stmt.targets:
                self.bind(tgt, val)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.bind(stmt.target, self.eval(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            self.env[getattr(stmt.target, "id", "_")] = OPAQUE
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.eval(stmt.value)
        elif isinstance(stmt, ast.For):
            self.exec_for(stmt)
        elif isinstance(stmt, ast.If):
            test = self.eval(stmt.test)
            body = stmt.body if (not isinstance(test, Opaque) and test) \
                else stmt.orelse
            for s in body:
                self.exec_stmt(s)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Pass, ast.Assert)):
            pass
        else:
            raise _Halt(f"unsupported statement {type(stmt).__name__} "
                        f"at line {stmt.lineno}")

    def exec_for(self, stmt: ast.For):
        items = self.eval(stmt.iter)
        if isinstance(items, Opaque):
            raise _Halt(f"opaque loop iterable at line {stmt.lineno}")
        for item in items:
            self.bind(stmt.target, item)
            for s in stmt.body:
                self.exec_stmt(s)

    def bind(self, target: ast.AST, value):
        if isinstance(target, ast.Name):
            self.env[target.id] = value
        elif isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, Opaque) or not hasattr(value, "__iter__"):
                for elt in target.elts:
                    self.bind(elt, OPAQUE)
            else:
                seq = list(value)
                for elt, v in zip(target.elts, seq):
                    self.bind(elt, v)
        # attribute/subscript targets: ignored (not used by wrappers)

    # -- expressions -------------------------------------------------------
    def eval(self, node: ast.AST):
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            return self.env.get(node.id, OPAQUE)
        if isinstance(node, (ast.Tuple, ast.List)):
            return [self.eval(e) for e in node.elts] \
                if isinstance(node, ast.List) \
                else tuple(self.eval(e) for e in node.elts)
        if isinstance(node, ast.Attribute):
            return self.eval_attribute(node)
        if isinstance(node, ast.BinOp):
            return self.eval_binop(node)
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand)
            if isinstance(v, Opaque):
                return OPAQUE
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.Not):
                return not v
            return OPAQUE
        if isinstance(node, ast.Compare):
            left = self.eval(node.left)
            right = self.eval(node.comparators[0])
            if isinstance(left, Opaque) or isinstance(right, Opaque):
                return OPAQUE
            op = node.ops[0]
            try:
                if isinstance(op, ast.Eq):
                    return left == right
                if isinstance(op, ast.NotEq):
                    return left != right
                if isinstance(op, ast.Lt):
                    return left < right
                if isinstance(op, ast.LtE):
                    return left <= right
                if isinstance(op, ast.Gt):
                    return left > right
                if isinstance(op, ast.GtE):
                    return left >= right
                if isinstance(op, ast.Is):
                    return left is right
                if isinstance(op, ast.IsNot):
                    return left is not right
            except TypeError:
                return OPAQUE
            return OPAQUE
        if isinstance(node, ast.BoolOp):
            vals = [self.eval(v) for v in node.values]
            if any(isinstance(v, Opaque) for v in vals):
                return OPAQUE
            if isinstance(node.op, ast.And):
                return all(vals)
            return any(vals)
        if isinstance(node, ast.IfExp):
            test = self.eval(node.test)
            if isinstance(test, Opaque):
                return OPAQUE
            return self.eval(node.body if test else node.orelse)
        if isinstance(node, ast.Subscript):
            return self.eval_subscript(node)
        if isinstance(node, ast.Call):
            return self.eval_call(node)
        if isinstance(node, ast.Lambda):
            return OPAQUE
        if isinstance(node, ast.GeneratorExp):
            return self.eval_generator(node)
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.JoinedStr):
            return OPAQUE
        raise _Halt(f"unsupported expression {type(node).__name__} "
                    f"at line {getattr(node, 'lineno', 0)}")

    def eval_attribute(self, node: ast.Attribute):
        base = self.eval(node.value)
        if isinstance(base, Arr):
            if node.attr == "shape":
                return base.shape
            if node.attr == "dtype":
                return Dtype(base.itemsize)
            return OPAQUE
        d = _dotted(node)
        if d and d[0] in ("jnp", "np", "numpy") and d[-1] in _DTYPE_BYTES:
            return Dtype(_DTYPE_BYTES[d[-1]])
        if isinstance(base, list) and node.attr in ("append", "extend"):
            return ("__listmethod__", base, node.attr)
        return d or OPAQUE           # dotted path marker for eval_call

    def eval_binop(self, node: ast.BinOp):
        left, right = self.eval(node.left), self.eval(node.right)
        if isinstance(left, Opaque) or isinstance(right, Opaque):
            return OPAQUE
        op = node.op
        try:
            if isinstance(op, ast.Add):
                return left + right
            if isinstance(op, ast.Sub):
                return left - right
            if isinstance(op, ast.Mult):
                return left * right
            if isinstance(op, ast.FloorDiv):
                return left // right
            if isinstance(op, ast.Div):
                return left / right
            if isinstance(op, ast.Mod):
                return left % right
            if isinstance(op, ast.Pow):
                return left ** right
            if isinstance(op, ast.BitAnd):
                return left & right
        except TypeError:
            return OPAQUE
        return OPAQUE

    def eval_subscript(self, node: ast.Subscript):
        base = self.eval(node.value)
        if isinstance(base, Opaque):
            return OPAQUE
        if isinstance(base, Arr):
            return OPAQUE            # slicing an array stub: unmodelled
        idx = node.slice
        if isinstance(idx, ast.Slice):
            return OPAQUE
        i = self.eval(idx)
        if isinstance(i, Opaque) or not isinstance(i, int):
            return OPAQUE
        try:
            return base[i]
        except (IndexError, KeyError, TypeError):
            return OPAQUE

    def eval_generator(self, node: ast.GeneratorExp):
        gen = node.generators[0]
        items = self.eval(gen.iter)
        if isinstance(items, Opaque):
            raise _Halt("opaque generator iterable")
        out = []
        for item in items:
            self.bind(gen.target, item)
            if all(self.eval(c) for c in gen.ifs):
                out.append(self.eval(node.elt))
        return tuple(out)

    def eval_call(self, node: ast.Call):
        fn = self.eval(node.func)
        args = []
        for a in node.args:
            v = self.eval(a)
            if isinstance(a, ast.Starred):
                args.extend(list(v) if not isinstance(v, Opaque)
                            else [OPAQUE])
            else:
                args.append(v)
        kwargs = {kw.arg: self.eval(kw.value) for kw in node.keywords
                  if kw.arg is not None}

        if isinstance(fn, tuple) and fn and fn[0] == "__listmethod__":
            _, lst, meth = fn
            if meth == "append":
                lst.append(args[0])
            else:
                lst.extend(list(args[0]))
            return None

        name = fn if isinstance(fn, tuple) else None
        if isinstance(node.func, ast.Name):
            name = (node.func.id,)

        if name:
            builtin = {
                ("min",): min, ("max",): max, ("len",): len,
                ("abs",): abs, ("sum",): sum, ("int",): int,
                ("tuple",): tuple, ("list",): list, ("range",): range,
            }.get(name)
            if builtin is not None:
                if any(isinstance(a, Opaque) for a in args):
                    return OPAQUE
                try:
                    out = builtin(*args)
                    return list(out) if builtin is range else out
                except (TypeError, ValueError):
                    return OPAQUE
            if name == ("enumerate",):
                seq = args[0]
                if isinstance(seq, Opaque):
                    return OPAQUE
                return [(i, v) for i, v in enumerate(seq)]
            last = name[-1]
            if last == "BlockSpec":
                shape = kwargs.get("block_shape", args[0] if args else ())
                if isinstance(shape, Opaque) or \
                        any(isinstance(s, Opaque) for s in shape):
                    raise _Halt(f"unresolvable BlockSpec shape at line "
                                f"{node.lineno}")
                return Block(shape)
            if last == "VMEM":
                shape, dt = args[0], args[1]
                itemsize = dt.itemsize if isinstance(dt, Dtype) else 4
                if any(isinstance(s, Opaque) for s in shape):
                    raise _Halt(f"unresolvable scratch shape at line "
                                f"{node.lineno}")
                return Scratch(shape, itemsize)
            if last == "ShapeDtypeStruct":
                shape, dt = args[0], args[1]
                itemsize = dt.itemsize if isinstance(dt, Dtype) else 4
                if any(isinstance(s, Opaque) for s in shape):
                    raise _Halt(f"unresolvable out_shape at line "
                                f"{node.lineno}")
                return Arr(shape, itemsize)
            if last == "PrefetchScalarGridSpec":
                return ("__gridspec__", kwargs)
            if last == "pad" and name[0] in ("jnp", "np", "numpy"):
                arr, pads = args[0], args[1]
                if not isinstance(arr, Arr) or isinstance(pads, Opaque):
                    return OPAQUE
                shape = tuple(s + lo + hi
                              for s, (lo, hi) in zip(arr.shape, pads))
                return Arr(shape, arr.itemsize)
            if last == "pallas_call":
                return self.capture_call(node, args, kwargs)

        if isinstance(fn, KernelCall):
            fn.operands = args
            self.calls.append(fn)
            return fn.out_shape if fn.out_shape is not None else OPAQUE
        return OPAQUE

    def capture_call(self, node: ast.Call, args, kwargs) -> KernelCall:
        call = KernelCall(self.fn.name, node.lineno)
        spec = kwargs.get("grid_spec")
        fields = dict(kwargs)
        if isinstance(spec, tuple) and spec and spec[0] == "__gridspec__":
            fields.update(spec[1])
        grid = fields.get("grid", ())
        if isinstance(grid, int):
            grid = (grid,)
        if isinstance(grid, Opaque) or \
                any(isinstance(g, Opaque) for g in grid):
            raise _Halt(f"unresolvable grid at line {node.lineno}")
        call.grid = tuple(grid)
        nsp = fields.get("num_scalar_prefetch", 0)
        call.num_scalar_prefetch = nsp if isinstance(nsp, int) else 0
        in_specs = fields.get("in_specs", [])
        if isinstance(in_specs, Opaque):
            raise _Halt(f"unresolvable in_specs at line {node.lineno}")
        call.in_specs = [s for s in in_specs if isinstance(s, Block)]
        out = fields.get("out_specs")
        call.out_specs = out if isinstance(out, Block) else None
        scratch = fields.get("scratch_shapes", [])
        if not isinstance(scratch, Opaque):
            call.scratch = [s for s in scratch if isinstance(s, Scratch)]
        osh = fields.get("out_shape")
        call.out_shape = osh if isinstance(osh, Arr) else None
        return call


# --------------------------------------------------------------------------
# Worst-case parameter spaces (from repro.configs + ops.py defaults)
# --------------------------------------------------------------------------

_SRC_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", ".."))


def _mesh_constants(src_root: str = _SRC_ROOT) -> Dict[str, float]:
    """Read launch/mesh.py's module-level numeric constants from its AST
    (it imports jax at top level; this package must not)."""
    path = os.path.join(src_root, "repro", "launch", "mesh.py")
    out: Dict[str, float] = {}
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            try:
                val = ast.literal_eval(stmt.value)
            except ValueError:
                try:
                    val = eval(compile(ast.Expression(stmt.value),
                                       "<mesh>", "eval"), {}, {})
                except Exception:
                    continue
            if isinstance(val, (int, float)):
                out[stmt.targets[0].id] = val
    return out


def vmem_budget(src_root: str = _SRC_ROOT) -> int:
    consts = _mesh_constants(src_root)
    return int(consts.get("VMEM_BYTES_PER_CORE", 16 * 2**20))


def _config_space() -> Dict[str, object]:
    """Worst-case model dims over every registered config (import-light:
    repro.configs has no jax dependency)."""
    from repro.configs import ARCH_IDS, get_config
    d = 0
    hd = 0
    ranks: set = set()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        dims = [cfg.d_model]
        if cfg.n_heads:
            dims.append(cfg.n_heads * cfg.resolved_head_dim)
            dims.append(2 * cfg.n_kv_heads * cfg.resolved_head_dim)
        d = max(d, max(dims))
        hd = max(hd, cfg.resolved_head_dim)
        ranks.update(cfg.lora.ranks)
        ranks.add(cfg.lora.max_rank)
    return {"d": d, "head_dim": hd, "ranks": tuple(sorted(ranks))}


def _ops_block_ts(src_root: str = _SRC_ROOT) -> Tuple[int, ...]:
    """block_t values reachable through the kernels/ops.py dispatch
    wrappers: the union of their declared defaults and literal call-site
    overrides (bgmv's block_t=1)."""
    path = os.path.join(src_root, "repro", "kernels", "ops.py")
    vals = set()
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for arg, default in zip(node.args.kwonlyargs,
                                    node.args.kw_defaults):
                if arg.arg == "block_t" and \
                        isinstance(default, ast.Constant) and \
                        isinstance(default.value, int):
                    vals.add(int(default.value))
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "block_t" and \
                        isinstance(kw.value, ast.Constant) and \
                        isinstance(kw.value.value, int):
                    vals.add(int(kw.value.value))
    # block_t=None defaults defer to the kernels/tune.py heuristic
    # table: every block_t it can emit is reachable
    from repro.kernels import tune as _tune
    vals.update(_tune._BLOCK_T_TABLE.values())
    return tuple(sorted(vals)) or (1, 16)


MODEL_SHARDS = (1, 2, 4, 8)


def kernel_envs(src_root: str = _SRC_ROOT, itemsize: int = 2,
                model_shards: Tuple[int, ...] = MODEL_SHARDS
                ) -> Dict[str, List[Dict[str, object]]]:
    """Per-entry-point worst-case environments: every (block_t, max-d,
    max-rank) corner reachable through the ops.py wrappers, at the given
    operand itemsize — swept over the mesh-sharded engine's per-shard
    slices too (each tp degree in ``model_shards`` shrinks the d_model /
    d_out operand dims to d/s, which changes block geometry and can
    flip the tune plan's residency decisions)."""
    space = _config_space()
    d = space["d"]
    ranks = space["ranks"]
    r = max(ranks)
    hd = space["head_dim"]
    na = 8
    envs: Dict[str, List[Dict[str, object]]] = {
        "sgmv_shrink": [], "sgmv_expand": [], "sgmv_fused_blocks": [],
        "sgmv_multibank_blocks": [], "sgmv_multibank_shrink": [],
        "sgmv_multibank_expand": [], "flash_mha": [],
    }
    shard_ds = [d // s for s in model_shards if s >= 1 and d % s == 0]
    for bt in _ops_block_ts(src_root):
        t_pad = bt * 8
        nblocks = t_pad // bt
        for dl in shard_ds:
            envs["sgmv_shrink"].append({
                "x_pad": Arr((t_pad, dl), itemsize),
                "A": Arr((na, dl, r), itemsize),
                "block_adapter": Arr((nblocks,), 4), "block_t": bt})
            envs["sgmv_expand"].append({
                "h_pad": Arr((t_pad, r), itemsize),
                "B": Arr((na, r, dl), itemsize),
                "block_adapter": Arr((nblocks,), 4), "block_t": bt})
            envs["sgmv_multibank_shrink"].append({
                "x_pad": Arr((t_pad, dl), itemsize),
                "A_banks": tuple(Arr((na, dl, rb), itemsize)
                                 for rb in ranks),
                "block_bucket": Arr((nblocks,), 4),
                "block_row": Arr((nblocks,), 4), "block_t": bt})
            envs["sgmv_multibank_expand"].append({
                "h_pad": Arr((t_pad, r), itemsize),
                "B_banks": tuple(Arr((na, rb, dl), itemsize)
                                 for rb in ranks),
                "block_bucket": Arr((nblocks,), 4),
                "block_row": Arr((nblocks,), 4), "block_t": bt})
        envs["sgmv_fused_blocks"].append({
            "x_pad": Arr((t_pad, d), itemsize),
            "A": Arr((na, d, r), itemsize),
            "B": Arr((na, r, d), itemsize),
            "block_adapter": Arr((nblocks,), 4), "block_t": bt})
        envs["sgmv_multibank_blocks"].append({
            "x_pad": Arr((t_pad, d), itemsize),
            "banks": tuple((Arr((na, d, rb), itemsize),
                            Arr((na, rb, d), itemsize)) for rb in ranks),
            "block_bucket": Arr((nblocks,), 4),
            "block_row": Arr((nblocks,), 4), "block_t": bt})
    # tune-plan corner: the geometry (block_t + bank residency) that
    # sgmv_bucketed_fused actually dispatches with at the deployment
    # envelope — the plan promises plan_vmem_bytes() <= budget, and this
    # env makes the checker hold it to that with its own accounting
    from repro.kernels import tune as _tune
    for dl in shard_ds:
        plan = _tune.block_plan(1024, dl, dl, tuple(ranks),
                                tuple(na for _ in ranks))
        t_pad = plan.block_t * 8
        nblocks = t_pad // plan.block_t
        envs["sgmv_multibank_blocks"].append({
            "x_pad": Arr((t_pad, dl), itemsize),
            "banks": tuple((Arr((na, dl, rb), itemsize),
                            Arr((na, rb, dl), itemsize))
                           for rb in ranks),
            "block_bucket": Arr((nblocks,), 4),
            "block_row": Arr((nblocks,), 4),
            "block_t": plan.block_t, "resident": plan.resident})
    seq = 4096
    envs["flash_mha"].append({
        "q": Arr((1, 2, seq, hd), itemsize),
        "k": Arr((1, 2, seq, hd), itemsize),
        "v": Arr((1, 2, seq, hd), itemsize)})
    return envs


# --------------------------------------------------------------------------
# Checks
# --------------------------------------------------------------------------

LANE = 128
SUBLANE = 8


def _check_block(path: str, call: KernelCall, block: Block,
                 operand: Optional[Arr], what: str) -> List[Finding]:
    out: List[Finding] = []
    shape = block.shape
    oshape = operand.shape if operand is not None else None
    if shape and isinstance(shape[-1], int):
        full = oshape is not None and shape[-1] == oshape[-1]
        if shape[-1] % LANE != 0 and not full:
            out.append(Finding(
                path, call.line, "vmem-align",
                f"{call.fn_name}: {what} block last dim {shape[-1]} is "
                f"neither a multiple of the {LANE}-wide lane nor the "
                f"full operand dim"))
    if len(shape) >= 2 and isinstance(shape[-2], int):
        full = oshape is not None and len(oshape) >= 2 \
            and shape[-2] == oshape[-2]
        if shape[-2] % SUBLANE != 0 and shape[-2] != 1 and not full:
            out.append(Finding(
                path, call.line, "vmem-align",
                f"{call.fn_name}: {what} block dim {shape[-2]} is not a "
                f"multiple of the {SUBLANE}-deep sublane (nor 1)"))
    if oshape is not None and len(oshape) == len(shape):
        for bdim, odim in zip(shape, oshape):
            if isinstance(bdim, int) and isinstance(odim, int) \
                    and bdim > 0 and odim % bdim != 0:
                out.append(Finding(
                    path, call.line, "vmem-grid",
                    f"{call.fn_name}: {what} block dim {bdim} does not "
                    f"divide operand dim {odim}"))
    return out


def check_call(path: str, call: KernelCall, budget: int,
               env_label: str = "",
               severity: Severity = Severity.ERROR) -> List[Finding]:
    findings: List[Finding] = []
    for g in call.grid:
        if not (isinstance(g, int) and g >= 1):
            findings.append(Finding(
                path, call.line, "vmem-grid",
                f"{call.fn_name}: grid dim {g!r} is not a positive int"))
    data_ops = [o for o in call.operands if isinstance(o, Arr)]
    data_ops = data_ops[call.num_scalar_prefetch:]
    for i, spec in enumerate(call.in_specs):
        operand = data_ops[i] if i < len(data_ops) else None
        findings.extend(_check_block(path, call, spec, operand,
                                     f"in_specs[{i}]"))
    if call.out_specs is not None:
        findings.extend(_check_block(path, call, call.out_specs,
                                     call.out_shape, "out"))
    used = call.vmem_bytes()
    if used > budget:
        rule = ("vmem-budget" if severity is Severity.ERROR
                else "vmem-headroom")
        findings.append(Finding(
            path, call.line, rule,
            f"{call.fn_name}: worst-case VMEM {used / 2**20:.1f} MiB "
            f"exceeds the {budget / 2**20:.0f} MiB/core budget"
            f"{' (' + env_label + ')' if env_label else ''}",
            severity))
    return findings


def analyze_source(source: str, path: str,
                   envs_by_fn: Dict[str, List[Dict[str, object]]],
                   budget: int,
                   severity: Severity = Severity.ERROR,
                   env_label: str = "",
                   require_registered: bool = True) -> List[Finding]:
    """Symbolically execute each pallas_call-bearing function in
    ``source`` under every registered worst-case env and check it."""
    findings: List[Finding] = []
    tree = ast.parse(source, filename=path)
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        has_pc = any(
            isinstance(sub, ast.Call)
            and (_dotted(sub.func) or ())[-1:] == ("pallas_call",)
            for sub in ast.walk(node))
        if not has_pc:
            continue
        envs = envs_by_fn.get(node.name)
        if not envs:
            if require_registered:
                findings.append(Finding(
                    path, node.lineno, "vmem-unregistered",
                    f"kernel entry point `{node.name}` has no registered "
                    f"worst-case parameter space", Severity.WARNING))
            continue
        for env in envs:
            try:
                ev = Evaluator(node, env, path)
                calls = ev.run()
            except _Halt as e:
                findings.append(Finding(
                    path, node.lineno, "vmem-parse",
                    f"could not symbolically evaluate `{node.name}`: "
                    f"{e}"))
                break
            if not calls:
                findings.append(Finding(
                    path, node.lineno, "vmem-parse",
                    f"`{node.name}` contains a pallas_call the evaluator "
                    f"never reached"))
                break
            for call in calls:
                findings.extend(check_call(path, call, budget,
                                           env_label, severity))
    return findings


KERNEL_FILES = ("sgmv.py", "flash.py")


def analyze_kernels(src_root: str = _SRC_ROOT) -> List[Finding]:
    """The full pass: production (bf16) envelope as errors, the fp32
    headroom probe as warnings."""
    budget = vmem_budget(src_root)
    findings: List[Finding] = []
    probes = [
        (kernel_envs(src_root, itemsize=2), Severity.ERROR, "bf16"),
        (kernel_envs(src_root, itemsize=4), Severity.WARNING,
         "fp32 headroom probe"),
    ]
    for name in KERNEL_FILES:
        path = os.path.join(src_root, "repro", "kernels", name)
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        for envs, sev, label in probes:
            findings.extend(analyze_source(
                source, path, envs, budget, severity=sev, env_label=label,
                require_registered=(sev is Severity.ERROR)))
    return findings
