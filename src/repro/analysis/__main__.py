"""CLI: ``python -m repro.analysis`` — run all three passes, exit
non-zero on any error-severity finding so CI can gate on it.

    python -m repro.analysis                      # all passes, text
    python -m repro.analysis --format=github      # CI annotations
    python -m repro.analysis --passes=lint,vmem   # subset
    python -m repro.analysis --report=out.json    # findings artifact

Warnings (e.g. the fp32 VMEM headroom probe) are printed but do not
gate; errors do.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from . import Finding, Severity, format_findings, has_errors

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC_ROOT = os.path.normpath(os.path.join(_HERE, "..", ".."))


def run_lint(src_root: str) -> List[Finding]:
    from . import linter
    return linter.lint_tree(os.path.join(src_root, "repro"))


def run_vmem(src_root: str) -> List[Finding]:
    from . import vmem
    return vmem.analyze_kernels(src_root)


def run_protocol(src_root: str) -> List[Finding]:
    from . import protocol
    pool_py = os.path.join(src_root, "repro", "core", "pool.py")
    findings: List[Finding] = []
    for name, res in protocol.small_model_suite():
        for v in res.violations:
            findings.append(Finding(
                pool_py, 1, f"protocol-{v.invariant}",
                f"[{name}] {v.message}; trace: "
                f"{' -> '.join(v.trace) or '<initial state>'}"))
        if res.truncated:
            findings.append(Finding(
                pool_py, 1, "protocol-truncated",
                f"[{name}] state space truncated at "
                f"{res.states} states — result is bounded, not "
                f"exhaustive", Severity.WARNING))
        print(f"protocol[{name}]: {res.states} states / "
              f"{res.transitions} transitions explored"
              f"{' (truncated)' if res.truncated else ' (exhaustive)'}, "
              f"{len(res.violations)} violation(s)", file=sys.stderr)
    return findings


PASSES = {"lint": run_lint, "vmem": run_vmem, "protocol": run_protocol}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text")
    ap.add_argument("--passes", default="lint,vmem,protocol",
                    help="comma-separated subset of: "
                         + ",".join(PASSES))
    ap.add_argument("--root", default=_SRC_ROOT,
                    help="source root containing the repro package")
    ap.add_argument("--report", default=None,
                    help="write findings as JSON to this path")
    args = ap.parse_args(argv)

    findings: List[Finding] = []
    for name in args.passes.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in PASSES:
            print(f"unknown pass {name!r} (have: "
                  f"{', '.join(PASSES)})", file=sys.stderr)
            return 2
        findings.extend(PASSES[name](args.root))

    if findings:
        print(format_findings(findings, args.format))
    errors = [f for f in findings if f.severity is Severity.ERROR]
    warnings = [f for f in findings if f.severity is Severity.WARNING]
    print(f"repro.analysis: {len(errors)} error(s), "
          f"{len(warnings)} warning(s)", file=sys.stderr)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump([x.as_dict() for x in findings], f, indent=2)
    return 1 if has_errors(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
