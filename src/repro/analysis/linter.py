"""AST linter for JAX tracing hazards and Python sharing hazards.

Rules (ids used in ``# analysis: ignore[rule]`` markers):

* ``host-sync``        — host↔device synchronization inside a traced
  (jit / scan / pallas-kernel) region or a decode-path host method:
  ``.item()``, ``np.asarray`` / ``np.array`` / ``jax.device_get`` on
  device values, ``float()`` / ``int()`` on non-literal arguments.
* ``host-sync-loop``   — ``int()`` / ``float()`` applied to a
  *subscripted device array* inside a host-side ``for`` loop
  (one blocking transfer per element — materialize once with
  ``np.asarray`` outside the loop instead).
* ``traced-if``        — Python ``if`` whose condition references a
  traced (jnp/lax-produced) value inside a traced region; under jit
  this raises ``TracerBoolConversionError`` at trace time, or silently
  bakes in one branch when the value is concrete by accident.
* ``raw-pallas-call``  — a ``pl.pallas_call`` site whose enclosing
  function never resolves its ``interpret`` mode through
  ``kernels.default_interpret()`` / ``resolve_interpret()``; such
  kernels silently interpret on TPU (or compile on CPU CI).
* ``mutable-default``  — mutable default argument values.
* ``shared-mutable-class-attr`` — class-level mutable container
  attribute (shared by every instance).
* ``shared-mutable-dataclass``  — dataclass field whose default is a
  shared mutable object (``field(default=<mutable>)``, a module-level
  name, or a raw mutable literal) — one object crossing every
  sim/engine boundary instance.
* ``side-effect-cond`` — statement-position conditional expression
  (``f(x) if c else None``): side effects hidden inside an expression
  statement; write the ``if`` out.
* ``async-blocking`` — a known blocking call (``time.sleep``,
  ``subprocess.*``, ``requests.*``, ``urllib.request.urlopen``,
  ``socket.create_connection``, ``os.system``) directly inside an
  ``async def``: it stalls the event loop — and in the serving gateway
  the cluster pump, every open SSE stream, and all other handlers ride
  that one loop. Use the ``await``-able equivalent (e.g.
  ``asyncio.sleep``) or push the work to a thread.

The traced-region analysis is heuristic but deliberately so: a
function is "traced" if it is decorated with ``jax.jit`` (directly or
via ``functools.partial``), passed to ``jax.jit`` / ``jax.lax.scan`` /
``jax.lax.while_loop`` / ``jax.lax.cond`` / ``jax.lax.fori_loop`` /
``pl.pallas_call``, decorated with ``pl.when``, or nested inside a
traced function. Within a traced function, names assigned from
``jnp.*`` / ``jax.lax.*`` expressions (or arithmetic on such names) are
considered traced values.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from . import Finding, Severity, apply_suppressions, suppressions

RULES: Dict[str, str] = {
    "host-sync": "host<->device sync inside a traced region or decode "
                 "hot path",
    "host-sync-loop": "per-element device sync inside a host loop",
    "traced-if": "Python `if` on a traced value inside a traced region",
    "raw-pallas-call": "pl.pallas_call bypassing "
                       "kernels.default_interpret()",
    "mutable-default": "mutable default argument",
    "shared-mutable-class-attr": "class-level mutable attribute shared "
                                 "by all instances",
    "shared-mutable-dataclass": "dataclass field defaulting to a shared "
                                "mutable object",
    "side-effect-cond": "statement-position conditional expression",
    "async-blocking": "blocking call inside an async function stalls "
                      "the event loop",
    "raw-log": "print()/ad-hoc logging call in library code; emit "
               "through the tracer or telemetry instead",
}

# ad-hoc log sinks: `logging.info(...)`, `logger.debug(...)`, etc.
_LOG_LEVEL_METHODS = {"debug", "info", "warning", "warn", "error",
                      "critical", "exception", "log", "basicConfig"}
_LOGGER_NAMES = {"logging", "logger", "log"}

# dotted names whose call blocks the thread — poison inside `async def`
_ASYNC_BLOCKING_CALLS = {
    ("time", "sleep"),
    ("os", "system"),
    ("subprocess", "run"), ("subprocess", "call"),
    ("subprocess", "check_call"), ("subprocess", "check_output"),
    ("socket", "create_connection"), ("socket", "getaddrinfo"),
    ("urllib", "request", "urlopen"),
    ("requests", "get"), ("requests", "post"), ("requests", "put"),
    ("requests", "delete"), ("requests", "head"),
    ("requests", "request"),
}

_MUTABLE_CALLS = {"list", "dict", "set", "defaultdict", "OrderedDict",
                  "deque", "Counter"}
_JIT_ROOTS = {("jax", "jit"), ("jit",)}
_TRACE_CONSUMERS = {("jax", "jit"), ("jit",),
                    ("jax", "lax", "scan"), ("lax", "scan"),
                    ("jax", "lax", "while_loop"), ("lax", "while_loop"),
                    ("jax", "lax", "cond"), ("lax", "cond"),
                    ("jax", "lax", "fori_loop"), ("lax", "fori_loop"),
                    ("jax", "lax", "map"), ("lax", "map"),
                    ("pl", "pallas_call"), ("pallas_call",),
                    ("jax", "vmap"), ("jax", "grad"),
                    ("jax", "value_and_grad")}
_TRACED_VALUE_ROOTS = ("jnp", "lax")
_DECODE_PATH_MARKERS = ("decode",)


def _dotted(node: ast.AST) -> Optional[tuple]:
    """`a.b.c` -> ("a","b","c"); plain name -> ("a",); else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_partial_jit(call: ast.Call) -> bool:
    fn = _dotted(call.func)
    if fn not in {("functools", "partial"), ("partial",)}:
        return False
    return any(_dotted(a) in _JIT_ROOTS for a in call.args[:1])


def _decorated_traced(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        d = _dotted(dec)
        if d in _JIT_ROOTS or d in {("pl", "when"), ("when",)}:
            return True
        if isinstance(dec, ast.Call):
            dfn = _dotted(dec.func)
            if dfn in _JIT_ROOTS or dfn in {("pl", "when"), ("when",)}:
                return True
            if _is_partial_jit(dec):
                return True
    return False


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = _dotted(node.func)
        return bool(fn) and fn[-1] in _MUTABLE_CALLS and not node.args \
            and not node.keywords or bool(fn) and fn[-1] in _MUTABLE_CALLS
    return False


class _FunctionNames(ast.NodeVisitor):
    """Collect names of functions handed to trace consumers anywhere in
    the module (``jax.jit(fn)``, ``jax.lax.scan(body, ...)``,
    ``pl.pallas_call(kernel, ...)``)."""

    def __init__(self):
        self.traced_names: Set[str] = set()

    def visit_Call(self, node: ast.Call):
        fn = _dotted(node.func)
        if fn in _TRACE_CONSUMERS:
            for arg in node.args[:1]:
                target = arg
                # functools.partial(kernel, ...) as the traced callable
                if isinstance(arg, ast.Call) and _dotted(arg.func) in {
                        ("functools", "partial"), ("partial",)}:
                    target = arg.args[0] if arg.args else arg
                d = _dotted(target)
                if d and len(d) == 1:
                    self.traced_names.add(d[0])
        self.generic_visit(node)


class Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.findings: List[Finding] = []
        self.tree = ast.parse(source, filename=path)
        names = _FunctionNames()
        names.visit(self.tree)
        self._traced_names = names.traced_names
        # stack of (function node, traced?, decode_path?, traced_vars,
        #           loop_depth_at_entry)
        self._fn_stack: List[dict] = []
        self._loop_depth = 0
        self._class_stack: List[ast.ClassDef] = []
        # launch/ entry points are CLI drivers: stdout IS their UI
        norm = "/" + path.replace(os.sep, "/").lstrip("/")
        self._raw_log_exempt = "/launch/" in norm

    # -- helpers ----------------------------------------------------------
    def _emit(self, node: ast.AST, rule: str, message: str,
              severity: Severity = Severity.ERROR):
        self.findings.append(Finding(
            self.path, getattr(node, "lineno", 0), rule, message,
            severity, getattr(node, "col_offset", 0)))

    def _in_traced(self) -> bool:
        return bool(self._fn_stack) and self._fn_stack[-1]["traced"]

    def _in_decode_path(self) -> bool:
        return bool(self._fn_stack) and self._fn_stack[-1]["decode"]

    def _traced_vars(self) -> Set[str]:
        return self._fn_stack[-1]["traced_vars"] if self._fn_stack \
            else set()

    def _is_traced_expr(self, node: ast.AST) -> bool:
        """Does the expression (transitively) involve jnp/lax output or
        a name already known to hold one?"""
        for sub in ast.walk(node):
            d = _dotted(sub) if isinstance(
                sub, (ast.Attribute, ast.Name)) else None
            if isinstance(sub, ast.Call):
                f = _dotted(sub.func)
                if f and f[0] in _TRACED_VALUE_ROOTS:
                    return True
                if f and len(f) >= 2 and f[:2] == ("jax", "lax"):
                    return True
            if d and len(d) >= 1 and d[0] in self._traced_vars():
                return True
        return False

    # -- scope tracking ---------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef):
        self._class_stack.append(node)
        self._check_class_body(node)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_function(self, node):
        traced = (_decorated_traced(node)
                  or node.name in self._traced_names
                  or self._in_traced())
        decode = any(m in node.name.lower()
                     for m in _DECODE_PATH_MARKERS) and not traced
        self._check_defaults(node)
        self._fn_stack.append({"node": node, "traced": traced,
                               "decode": decode, "traced_vars": set(),
                               "loops": self._loop_depth})
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_For(self, node: ast.For):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_While(self, node: ast.While):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    # -- rules ------------------------------------------------------------
    def _check_defaults(self, fn):
        args = fn.args
        for default in list(args.defaults) + [
                d for d in args.kw_defaults if d is not None]:
            if _is_mutable_default(default):
                self._emit(default, "mutable-default",
                           f"mutable default argument in "
                           f"`{fn.name}()` is shared across calls; use "
                           f"None and create inside")

    def _check_class_body(self, cls: ast.ClassDef):
        is_dataclass = any(
            (_dotted(d) or ())[-1:] == ("dataclass",)
            or (isinstance(d, ast.Call)
                and (_dotted(d.func) or ())[-1:] == ("dataclass",))
            for d in cls.decorator_list)
        for stmt in cls.body:
            if isinstance(stmt, ast.Assign) and not is_dataclass:
                if stmt.targets and isinstance(stmt.targets[0], ast.Name) \
                        and stmt.targets[0].id.startswith("__"):
                    continue        # __slots__ and friends
                if _is_mutable_default(stmt.value):
                    self._emit(stmt, "shared-mutable-class-attr",
                               f"class attribute on `{cls.name}` holds "
                               f"a mutable container shared by every "
                               f"instance; assign it in __init__")
            if isinstance(stmt, ast.AnnAssign) and is_dataclass \
                    and stmt.value is not None:
                self._check_dataclass_field(cls, stmt)

    def _check_dataclass_field(self, cls: ast.ClassDef,
                               stmt: ast.AnnAssign):
        val = stmt.value
        # field(default_factory=...) is the sanctioned form
        if isinstance(val, ast.Call) and \
                (_dotted(val.func) or ())[-1:] == ("field",):
            for kw in val.keywords:
                if kw.arg == "default" and _is_mutable_default(kw.value):
                    self._emit(stmt, "shared-mutable-dataclass",
                               f"dataclass field on `{cls.name}` uses "
                               f"field(default=<mutable>); every "
                               f"instance shares one object — use "
                               f"default_factory")
            return
        if _is_mutable_default(val):
            self._emit(stmt, "shared-mutable-dataclass",
                       f"dataclass field on `{cls.name}` defaults to a "
                       f"mutable literal shared by every instance; use "
                       f"field(default_factory=...)")
            return
        # a bare Name as default for a container-annotated field aliases
        # one module-level object into every instance
        ann = ast.unparse(stmt.annotation) if stmt.annotation else ""
        container = any(t in ann for t in
                        ("List", "Dict", "Set", "list[", "dict[", "set["))
        if container and isinstance(val, ast.Name):
            self._emit(stmt, "shared-mutable-dataclass",
                       f"dataclass field on `{cls.name}` defaults to "
                       f"module-level `{val.id}`; every instance shares "
                       f"that object — use field(default_factory=...)")

    def visit_Assign(self, node: ast.Assign):
        if self._fn_stack:
            # np.asarray / device_get is the sanctioned sync point: its
            # result is a host array, not a traced value
            materialized = isinstance(node.value, ast.Call) and \
                _dotted(node.value.func) in {
                    ("np", "asarray"), ("np", "array"),
                    ("numpy", "asarray"), ("numpy", "array"),
                    ("jax", "device_get")}
            for tgt in node.targets:
                d = _dotted(tgt)
                if d and len(d) == 1:
                    if materialized:
                        self._traced_vars().discard(d[0])
                    elif self._is_traced_expr(node.value):
                        self._traced_vars().add(d[0])
        self.generic_visit(node)

    def visit_If(self, node: ast.If):
        if self._in_traced() and self._is_traced_expr(node.test):
            self._emit(node, "traced-if",
                       "Python `if` on a traced value inside a traced "
                       "region: use jnp.where / lax.cond / pl.when")
        self.generic_visit(node)

    def visit_Expr(self, node: ast.Expr):
        if isinstance(node.value, ast.IfExp):
            self._emit(node, "side-effect-cond",
                       "statement-position conditional expression hides "
                       "a side effect; write the `if` statement out")
        self.generic_visit(node)

    def _in_async(self) -> bool:
        """Directly inside an ``async def`` body (a sync ``def`` nested
        in a coroutine runs wherever it is *called*, so only the
        innermost frame decides)."""
        return bool(self._fn_stack) and isinstance(
            self._fn_stack[-1]["node"], ast.AsyncFunctionDef)

    def visit_Call(self, node: ast.Call):
        fn = _dotted(node.func)
        in_traced = self._in_traced()
        hot = in_traced or self._in_decode_path()

        if not self._raw_log_exempt and fn is not None:
            if fn == ("print",):
                self._emit(node, "raw-log",
                           "print() in library code bypasses the tracer "
                           "and telemetry; structured paths only")
            elif len(fn) == 2 and fn[0] in _LOGGER_NAMES \
                    and fn[1] in _LOG_LEVEL_METHODS:
                self._emit(node, "raw-log",
                           f"ad-hoc {'.'.join(fn)}() in library code; "
                           f"route through the tracer/telemetry layer")

        if fn in _ASYNC_BLOCKING_CALLS and self._in_async():
            name = self._fn_stack[-1]["node"].name
            self._emit(node, "async-blocking",
                       f"{'.'.join(fn)}() inside `async def {name}` "
                       f"blocks the event loop (pump, SSE streams, and "
                       f"all handlers share it); use the awaitable "
                       f"equivalent or run_in_executor")

        # .item() on anything, in any hot region
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and hot:
            self._emit(node, "host-sync",
                       ".item() forces a blocking device->host transfer")

        # np.asarray / np.array / jax.device_get in hot regions
        if fn in {("np", "asarray"), ("np", "array"),
                  ("numpy", "asarray"), ("numpy", "array"),
                  ("jax", "device_get")} and hot:
            where = ("a traced region" if in_traced
                     else "the decode host path")
            self._emit(node, "host-sync",
                       f"{'.'.join(fn)} inside {where} synchronizes the "
                       f"device stream")

        # float()/int() on non-literal args
        if fn in {("float",), ("int",), ("bool",)} and node.args:
            arg = node.args[0]
            literal = isinstance(arg, ast.Constant) or \
                isinstance(arg, (ast.Num, ast.Str))
            if in_traced and not literal:
                self._emit(node, "host-sync",
                           f"{fn[0]}() on a traced value raises (or "
                           f"syncs) under jit; use .astype / "
                           f"lax.convert_element_type")
            elif not in_traced and self._loop_depth > \
                    (self._fn_stack[-1]["loops"] if self._fn_stack
                     else 0) and isinstance(arg, ast.Subscript):
                base = _dotted(arg.value)
                if base and base[-1] in self._traced_vars():
                    self._emit(node, "host-sync-loop",
                               f"{fn[0]}({ast.unparse(arg)}) inside a "
                               f"host loop issues one blocking transfer "
                               f"per element; np.asarray the array once "
                               f"before the loop")

        # raw pallas_call without interpret resolution in the same fn
        if fn in {("pl", "pallas_call"), ("pallas_call",)}:
            if not self._enclosing_resolves_interpret():
                self._emit(node, "raw-pallas-call",
                           "pl.pallas_call without resolving interpret "
                           "through kernels.default_interpret(); TPU "
                           "runs may silently interpret (or CPU CI "
                           "silently compile)")
        self.generic_visit(node)

    def _enclosing_resolves_interpret(self) -> bool:
        if not self._fn_stack:
            return False
        for frame in reversed(self._fn_stack):
            for sub in ast.walk(frame["node"]):
                if isinstance(sub, ast.Call):
                    f = _dotted(sub.func)
                    if f and f[-1] in ("resolve_interpret",
                                       "default_interpret"):
                        return True
        return False


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    linter = Linter(path, source)
    linter.visit(linter.tree)
    return apply_suppressions(linter.findings, suppressions(source))


def lint_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (skipping this package: the
    analyzers legitimately name the hazards they search for)."""
    findings: List[Finding] = []
    skip = os.path.join("repro", "analysis")
    for dirpath, _dirnames, filenames in sorted(os.walk(root)):
        if skip in dirpath:
            continue
        for name in sorted(filenames):
            if name.endswith(".py"):
                findings.extend(lint_file(os.path.join(dirpath, name)))
    return findings
