"""repro.analysis — static analysis + model checking for the repro tree.

Three passes, one CLI (``python -m repro.analysis``), exit-code gated so
CI can require it:

* ``linter``   — custom AST lint over ``src/repro`` for JAX tracing
  hazards (host↔device syncs inside jit/scan regions, Python ``if`` on
  traced values, ``pl.pallas_call`` sites bypassing
  ``kernels.default_interpret()``) and Python sharing hazards (mutable
  default arguments, shared-mutable class attributes / dataclass
  fields, side-effecting conditional-expression statements).
* ``vmem``     — static resource analyzer: extracts BlockSpec / scratch
  / grid shapes from every Pallas kernel entry point and symbolically
  evaluates worst-case per-core VMEM bytes over the configured
  (bucket rank, block_t, d_model) space, checked against the v5e
  roofline constants in ``repro.launch.mesh``.
* ``protocol`` — an exhaustive-interleaving model checker (BFS, no
  external deps) driving the REAL ``AdapterStore`` / ``RoutingTable``
  implementations through fetch / rebalance / drain / retire
  interleavings and asserting the cluster's safety + liveness
  invariants (GC never frees an in-flight transfer's source, no route
  to a retired server, drains terminate, link occupancy consistent,
  tier residency matches the index).

Suppressions: a ``# analysis: ignore[rule]`` comment on the offending
line (or the line directly above it) silences that rule there; a bare
``# analysis: ignore`` silences every rule for the line. Intentional
hits must carry a one-line reason after the marker.

The whole package is import-light on purpose: no jax, no numpy — it
must run in a bare CI venv before the heavyweight deps are installed.
"""
from __future__ import annotations

import dataclasses
import enum
import re
from typing import Dict, List, Optional, Set

IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[([a-z0-9_,\- ]+)\])?")

ALL_RULES = "*"


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis result, pointing at a file/line."""
    path: str
    line: int
    rule: str
    message: str
    severity: Severity = Severity.ERROR
    col: int = 0

    def format(self, style: str = "text") -> str:
        if style == "github":
            level = ("error" if self.severity is Severity.ERROR
                     else "warning")
            return (f"::{level} file={self.path},line={self.line},"
                    f"col={self.col},title={self.rule}::{self.message}")
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.message}")

    def as_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "severity": self.severity.value,
                "message": self.message}


def suppressions(source: str) -> Dict[int, Set[str]]:
    """Map line number (1-based) -> set of suppressed rule names (the
    sentinel ``ALL_RULES`` suppresses everything). A marker on a
    comment-only line also covers the next line, so long findings can
    carry their reason above the code they annotate."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = IGNORE_RE.search(text)
        if not m:
            continue
        rules = ({r.strip() for r in m.group(1).split(",")}
                 if m.group(1) else {ALL_RULES})
        out.setdefault(i, set()).update(rules)
        if text.lstrip().startswith("#"):       # standalone marker line
            out.setdefault(i + 1, set()).update(rules)
    return out


def apply_suppressions(findings: List[Finding],
                       supp: Dict[int, Set[str]]) -> List[Finding]:
    kept = []
    for f in findings:
        rules = supp.get(f.line, set())
        if ALL_RULES in rules or f.rule in rules:
            continue
        kept.append(f)
    return kept


def format_findings(findings: List[Finding], style: str = "text") -> str:
    return "\n".join(f.format(style) for f in findings)


def has_errors(findings: List[Finding]) -> bool:
    return any(f.severity is Severity.ERROR for f in findings)
