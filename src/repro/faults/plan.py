"""Deterministic fault schedules (the chaos plane's injection side).

A ``FaultPlan`` is a sorted list of ``FaultEvent``s on the host's clock
— the sim's virtual clock or the engine facade's wall clock; the same
plan replays identically on either substrate (and across runs: random
plans are seeded). Kinds:

* ``crash_server`` / ``restore_server`` — fail-stop a server (its HBM
  and host tiers vanish, in-flight work strands until recovery) and
  bring it back empty;
* ``link_down`` / ``link_up`` / ``link_degrade`` — flap or slow a
  peer's egress link in the ``NetworkModel`` (``arg`` is the wire-time
  multiplier for degrade);
* ``stall_fetch`` — freeze one in-flight ``AdapterStore`` transfer (or
  slow it by ``arg`` seconds) so the fetch timeout/retry path fires;
* ``disconnect_client`` — drop one live gateway SSE stream mid-flight
  (gateway hosts only; other hosts ignore it).
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import List, Optional, Sequence

KIND_CRASH = "crash_server"
KIND_RESTORE = "restore_server"
KIND_LINK_DOWN = "link_down"
KIND_LINK_UP = "link_up"
KIND_LINK_DEGRADE = "link_degrade"
KIND_STALL_FETCH = "stall_fetch"
KIND_DISCONNECT = "disconnect_client"

KINDS = (KIND_CRASH, KIND_RESTORE, KIND_LINK_DOWN, KIND_LINK_UP,
         KIND_LINK_DEGRADE, KIND_STALL_FETCH, KIND_DISCONNECT)


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    time: float
    kind: str
    target: int = -1     # server id / link id; -1: any (host picks)
    arg: float = 0.0     # degrade factor / stall seconds (0 = freeze)
    note: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """An ordered, replayable fault schedule with a consume cursor."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(events)
        self._cursor = 0

    def __len__(self) -> int:
        return len(self.events)

    def add(self, event: FaultEvent) -> "FaultPlan":
        if self._cursor:
            raise RuntimeError("fault plan already partially consumed")
        self.events.append(event)
        self.events.sort()
        return self

    def due(self, now: float) -> List[FaultEvent]:
        """Consume and return every event scheduled at or before
        ``now`` (each event fires exactly once)."""
        out: List[FaultEvent] = []
        while (self._cursor < len(self.events)
               and self.events[self._cursor].time <= now + 1e-12):
            out.append(self.events[self._cursor])
            self._cursor += 1
        return out

    def next_time(self) -> Optional[float]:
        if self._cursor >= len(self.events):
            return None
        return self.events[self._cursor].time

    def remaining(self) -> int:
        return len(self.events) - self._cursor

    def reset(self) -> None:
        self._cursor = 0

    # -- serialization (launch/serve.py --fault-plan) -------------------
    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(e) for e in self.events],
                          indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([FaultEvent(**e) for e in json.loads(text)])

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path, "r", encoding="utf-8") as f:
            return cls.from_json(f.read())

    # -- scripted scenarios (chaos harness) -----------------------------
    @classmethod
    def kill_one(cls, t_kill: float, server: int,
                 t_restore: Optional[float] = None) -> "FaultPlan":
        """The canonical chaos scenario: fail-stop one server (and
        optionally bring it back)."""
        evs = [FaultEvent(t_kill, KIND_CRASH, server)]
        if t_restore is not None:
            evs.append(FaultEvent(t_restore, KIND_RESTORE, server))
        return cls(evs)

    @classmethod
    def link_flap(cls, t_down: float, server: int,
                  t_up: float) -> "FaultPlan":
        return cls([FaultEvent(t_down, KIND_LINK_DOWN, server),
                    FaultEvent(t_up, KIND_LINK_UP, server)])

    @classmethod
    def stall(cls, t: float, server: int = -1,
              extra: float = 0.0) -> "FaultPlan":
        """Freeze (or slow) whatever transfer is in flight at ``t``."""
        return cls([FaultEvent(t, KIND_STALL_FETCH, server, extra)])

    @classmethod
    def random_plan(cls, seed: int, horizon: float, n_servers: int,
                    rate: float = 0.2,
                    kinds: Sequence[str] = (KIND_CRASH, KIND_RESTORE,
                                            KIND_LINK_DOWN, KIND_LINK_UP,
                                            KIND_STALL_FETCH)
                    ) -> "FaultPlan":
        """A seeded Poisson fault storm. Crash/restore and down/up are
        paired per target so the cluster always heals: every crash gets
        a restore and every link-down a link-up inside the horizon."""
        rng = random.Random(seed)
        evs: List[FaultEvent] = []
        t = 0.0
        while True:
            t += rng.expovariate(rate)
            if t >= horizon:
                break
            kind = rng.choice(list(kinds))
            target = rng.randrange(n_servers)
            if kind in (KIND_RESTORE, KIND_LINK_UP):
                continue            # pairs are emitted with their cause
            if kind == KIND_CRASH:
                evs.append(FaultEvent(t, KIND_CRASH, target))
                heal = min(horizon, t + rng.uniform(0.2, 1.0)
                           * (horizon - t))
                evs.append(FaultEvent(heal, KIND_RESTORE, target))
            elif kind == KIND_LINK_DOWN:
                evs.append(FaultEvent(t, KIND_LINK_DOWN, target))
                up = min(horizon, t + rng.uniform(0.05, 0.5)
                         * (horizon - t))
                evs.append(FaultEvent(up, KIND_LINK_UP, target))
            elif kind == KIND_LINK_DEGRADE:
                evs.append(FaultEvent(t, KIND_LINK_DEGRADE, target,
                                      rng.uniform(2.0, 8.0)))
            else:
                evs.append(FaultEvent(t, kind, target))
        return cls(evs)
