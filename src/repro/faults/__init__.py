"""Chaos plane: fault injection, failure detection, loss-free recovery.

Spans both substrates — the discrete-event ``ClusterSimulator`` and the
real-engine ``LoRAServeCluster`` facade consume the same seeded
``FaultPlan`` via a ``FaultInjector``, detect crashes with the same
heartbeat ``FailureDetector``, and re-dispatch in-flight work with the
same exactly-once continuation helpers.
"""
from .detector import FailureDetector
from .injector import FaultInjector
from .plan import (KIND_CRASH, KIND_DISCONNECT, KIND_LINK_DEGRADE,
                   KIND_LINK_DOWN, KIND_LINK_UP, KIND_RESTORE,
                   KIND_STALL_FETCH, KINDS, FaultEvent, FaultPlan)
from .recovery import (RecoveryRecord, delivered_tokens,
                       make_continuation, merge_continuation,
                       remaining_tokens)

__all__ = [
    "FaultEvent", "FaultPlan", "FaultInjector", "FailureDetector",
    "RecoveryRecord", "delivered_tokens", "make_continuation",
    "merge_continuation", "remaining_tokens", "KINDS", "KIND_CRASH",
    "KIND_RESTORE", "KIND_LINK_DOWN", "KIND_LINK_UP",
    "KIND_LINK_DEGRADE", "KIND_STALL_FETCH", "KIND_DISCONNECT",
]
