"""Heartbeat failure detector (the chaos plane's detection side).

Each live server beats once per host poll; a server silent for longer
than ``suspect_after`` is *suspected*, and one silent for ``window`` is
*confirmed dead* — at which point the host runs crash recovery
(``ClusterOrchestrator.fail_server`` + request re-dispatch).

The host beats every alive server and *then* calls ``check`` in the
same poll, so a virtual-clock jump can never outrun the beats of a
healthy server: false positives are structurally impossible — only a
server the host stopped beating (crashed in the backend) can be
confirmed.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class FailureDetector:
    def __init__(self, window: float = 0.5,
                 suspect_after: Optional[float] = None):
        if window <= 0:
            raise ValueError("detector window must be > 0")
        self.window = window
        self.suspect_after = (suspect_after if suspect_after is not None
                              else window / 2.0)
        self._last_beat: Dict[int, float] = {}
        self._confirmed: set = set()
        # telemetry
        self.confirmed_count = 0

    # -- host-facing ------------------------------------------------------
    def beat(self, server_id: int, now: float) -> None:
        if server_id in self._confirmed:
            return
        prev = self._last_beat.get(server_id, -float("inf"))
        self._last_beat[server_id] = max(prev, now)

    def remove(self, server_id: int) -> None:
        """Forget a server (retired, or recovery handled elsewhere)."""
        self._last_beat.pop(server_id, None)
        self._confirmed.discard(server_id)

    def restore(self, server_id: int, now: float) -> None:
        """A crashed server came back: start beating it afresh."""
        self._confirmed.discard(server_id)
        self._last_beat[server_id] = now

    def check(self, now: float) -> List[int]:
        """Newly confirmed-dead servers (silent >= ``window``). Each id
        is reported exactly once."""
        dead: List[int] = []
        for sid, t in sorted(self._last_beat.items()):
            if sid in self._confirmed:
                continue
            if now - t >= self.window - 1e-12:
                self._confirmed.add(sid)
                self.confirmed_count += 1
                dead.append(sid)
        return dead

    def suspects(self, now: float) -> List[int]:
        return [sid for sid, t in sorted(self._last_beat.items())
                if sid not in self._confirmed
                and now - t >= self.suspect_after - 1e-12]

    def confirmed(self) -> List[int]:
        return sorted(self._confirmed)

    def next_deadline(self, now: float) -> Optional[float]:
        """Earliest future time a tracked server could be confirmed —
        the host's event loop must wake by then for virtual clocks to
        reach detection."""
        times = [t + self.window for sid, t in self._last_beat.items()
                 if sid not in self._confirmed]
        if not times:
            return None
        return max(min(times), now)
