"""Exactly-once request re-dispatch (the chaos plane's recovery side).

When a server dies mid-decode, its in-flight requests are re-issued on
a survivor *from the last client-visible token*: a **continuation**
request carries the same ``req_id``, the already-generated tokens
folded into its prompt (real engine: re-prefill of prompt + generated
context, so greedy decode continues the identical sequence; sim:
``prompt_len`` grows by the delivered count), and an output budget of
only the remaining tokens. The host keeps streaming positions keyed by
``req_id``, so the client-visible stream is the concatenation —
no token is ever lost or duplicated.

``merge_continuation`` folds the finished continuation back into the
original request object, because hosts track completion by object
identity (``LoRAServeCluster._report``'s ``id(r)`` set).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.request import Phase, ServeRequest


def delivered_tokens(req: ServeRequest) -> int:
    """Tokens of ``req`` that exist host-side (>= the client-visible
    watermark): concrete outputs on the real engine, the decode counter
    in the sim."""
    if req.prompt is not None:
        return len(req.output)
    return req.decoded


def remaining_tokens(req: ServeRequest) -> int:
    return max(0, req.output_len - delivered_tokens(req))


def make_continuation(req: ServeRequest, now: float) -> ServeRequest:
    """Build the re-dispatch request for ``req``'s undelivered suffix.
    Same ``req_id`` (streams are keyed by it); fresh lifecycle."""
    done = delivered_tokens(req)
    if req.prompt is not None:
        prompt: List[int] = list(req.prompt) + list(req.output)
        return ServeRequest(req_id=req.req_id,
                            adapter_id=req.adapter_id, rank=req.rank,
                            prompt_len=len(prompt),
                            output_len=remaining_tokens(req),
                            arrival=now, prompt=prompt)
    return ServeRequest(req_id=req.req_id, adapter_id=req.adapter_id,
                        rank=req.rank, prompt_len=req.prompt_len + done,
                        output_len=remaining_tokens(req), arrival=now)


def merge_continuation(orig: ServeRequest, cont: ServeRequest) -> None:
    """Fold a finished continuation back into the original request so
    the host's identity-keyed bookkeeping sees one completed request
    with the full output and end-to-end timestamps."""
    assert cont.req_id == orig.req_id, "continuation req_id mismatch"
    base = delivered_tokens(orig)
    if orig.prompt is not None:
        orig.output = list(orig.output) + list(cont.output)
    orig.decoded = base + cont.decoded
    orig.server = cont.server
    orig.finish = cont.finish
    orig.t_finish = cont.t_finish
    orig.phase = cont.phase
    orig.prefill_done = (orig.prefill_done if orig.prefill_done >= 0
                         else cont.prefill_done)
    if orig.t_first_token is None:
        orig.t_first_token = cont.t_first_token


@dataclasses.dataclass
class RecoveryRecord:
    """Audit record of one crash recovery (chaos harness + flight
    recorder payload)."""
    server: int
    detected_at: float
    recovered_at: float
    redispatched: int
    orphaned_adapters: int

    @property
    def recovery_time(self) -> float:
        return self.recovered_at - self.detected_at
