"""Fault injector: applies a ``FaultPlan``'s due events to a host.

The host is anything exposing ``apply_fault(event, now) -> bool`` —
``LoRAServeCluster`` (engine facade, wall or virtual clock),
``ClusterSimulator`` (virtual clock), and ``ServeGateway`` (asyncio
loop, for ``disconnect_client``). The injector owns the schedule
cursor and the applied/skipped log; the host owns the semantics.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from .plan import FaultEvent, FaultPlan


class FaultInjector:
    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.applied: List[Tuple[float, FaultEvent]] = []
        self.skipped: List[Tuple[float, FaultEvent]] = []

    def poll(self, now: float, host) -> List[FaultEvent]:
        """Fire every due event against ``host``. Events the host
        reports as inapplicable (e.g. stalling when nothing is in
        flight, crashing an already-dead server) are logged as skipped,
        not errors — chaos schedules are written blind to state."""
        fired: List[FaultEvent] = []
        for ev in self.plan.due(now):
            if host.apply_fault(ev, now):
                self.applied.append((now, ev))
                fired.append(ev)
            else:
                self.skipped.append((now, ev))
        return fired

    def next_time(self) -> Optional[float]:
        return self.plan.next_time()

    def done(self) -> bool:
        return self.plan.remaining() == 0
