"""Synthetic LM data pipeline: deterministic, seeded, infinite stream of
(tokens, labels) batches with a learnable structure (piecewise-repeating
n-gram process), so small-model training shows a real loss curve without
external datasets.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    ngram: int = 3


class SyntheticLM:
    """Markov chain over the vocab with sparse transitions — compressible
    structure a model can learn (loss drops well below uniform entropy)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        k = min(8, V)   # successors per state
        self.successors = rng.integers(0, V, size=(V, k))
        self.weights = rng.dirichlet(np.ones(k), size=V)

    def _sample_row(self, rng, n: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty(n + 1, dtype=np.int32)
        s = rng.integers(0, V)
        for i in range(n + 1):
            out[i] = s
            nxt = rng.choice(self.successors.shape[1], p=self.weights[s])
            s = self.successors[s, nxt]
        return out

    def batches(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.cfg.seed + 1)
        B, S = self.cfg.batch_size, self.cfg.seq_len
        while True:
            rows = np.stack([self._sample_row(rng, S) for _ in range(B)])
            yield rows[:, :-1].astype(np.int32), rows[:, 1:].astype(np.int32)
