"""LoRAServeCluster: one serving facade over either execution substrate.

Owns the paper's control plane (``ClusterOrchestrator``: placement
policy, phi-weighted routing table, tiered adapter store, demand
estimator) and drives a ``ServingBackend`` (simulated or real-JAX) on a
shared clock:

* arrivals are phi-routed (Fig 11 steps 1-2) and the adapter's data
  path comes back as a ``FetchPlan`` from the tiered ``AdapterStore``
  (steps 3-4): a hit, an asynchronous migrate fetch the request waits
  out, or — with ``access_mode="remote-read"`` — an immediate serve
  reading weights from a peer's copy over GDR while the local copy
  warms in the background;
* every ``rebalance_period`` seconds the demand window closes and
  ``end_of_timestep`` re-places adapters (steps 6-7) *while requests are
  in flight*: the routing table and store are re-seeded mid-run, idle
  adapters are evicted from server banks, subsequent requests follow
  the updated phi, and with ``prefetch=True`` newly-placed copies start
  warming immediately instead of migrating lazily on first hit;
* the loop polls the store each tick so fetch completions install
  copies, promote remote-read serves, and push prefetched adapters into
  backend banks;
* completions stream back as ``ServeResult`` records through one
  ``MetricsCollector`` regardless of backend.

The cluster API is **incremental**: requests arrive one at a time via
``submit(request)``, the loop body is ``poll(now)`` (store completions,
due rebalances/controller ticks, one backend step, completion/timeout/
token events out), and ``drain()`` finishes whatever is in flight.
``run(trace)`` — the batch replay every benchmark uses — is implemented
on top of exactly those three calls, so a live gateway
(``repro.server``) and a trace replay exercise the same control plane.

Adapters have a runtime lifecycle too: ``register_adapter`` makes a new
adapter servable mid-run (placed on the emptiest server, folded into
subsequent rebalances), and ``unregister_adapter`` starts a loss-free
retire — routing stops immediately, in-flight requests finish, then the
copies leave the banks and the store.

This is the unified serving API the launcher, gateway, examples, and
benchmarks build on.
"""
from __future__ import annotations

import copy
import dataclasses
import math
import time
from typing import Dict, List, Optional, Set, Tuple

from repro.core import ClusterOrchestrator
from repro.core.request import ServeRequest
from repro.core.routing import UnknownAdapterError
from repro.core.types import AdapterInfo, Placement, servers_to_adapters

from .backend import ServingBackend
from .metrics import MetricsCollector, percentile


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Per-request outcome, identical for sim and real backends."""
    req_id: int
    adapter_id: str
    rank: int
    server: int
    arrival: float
    finished: bool
    ttft: Optional[float]
    tbt: Optional[float]
    fetch_latency: float
    n_output: int


@dataclasses.dataclass(frozen=True)
class ClusterEvent:
    """One observable outcome of a ``poll`` tick.

    ``kind`` is ``"token"`` (``tokens`` holds the newly decoded token
    ids; ``None`` entries for the simulated substrate, which models
    token *counts*, not values), ``"finish"`` (request completed;
    ``tokens`` carries any tokens not yet surfaced), or ``"timeout"``.
    """
    kind: str
    req: ServeRequest
    tokens: Tuple = ()
    now: float = 0.0


@dataclasses.dataclass
class ClusterReport:
    results: List[ServeResult]
    summary: dict
    rebalances: int                    # control-loop timesteps fired
    placements: List[Placement]        # history; >1 entry => re-placed
    per_server_counts: List[int]
    timed_out: int
    fetches: int
    fetch_bytes: int
    max_adapters_per_server: int
    total_adapter_bytes: int
    memory_profile: List[dict]
    warmup: float = 0.0
    bank_mode: str = "padded"          # bank layout the backend ran with
    mesh_shape: Optional[tuple] = None  # (dp, tp) engine mesh, if sharded
    in_progress: int = 0               # unfinished at snapshot time
    # adapter data-plane telemetry
    access_mode: str = "migrate"       # migrate | remote-read
    remote_reads: int = 0              # misses served via peer GDR reads
    prefetches: int = 0                # rebalance-driven proactive warms
    coalesced_fetches: int = 0         # duplicate fetches joined in flight
    # adapter lifecycle (runtime register/unregister)
    registered: int = 0
    unregistered: int = 0
    # control-plane telemetry (controller runs only)
    scale_ups: int = 0
    drains: int = 0
    retires: int = 0
    controller_rebalances: int = 0     # out-of-band (drift/SLO) ones
    gpu_seconds: float = 0.0           # per-server provision->retire
    final_servers: int = 0             # active fleet size at end of run
    drift_events: List = dataclasses.field(default_factory=list)
    controller_actions: List = dataclasses.field(default_factory=list)
    # observability (tracer-attached runs only)
    cost_drift: dict = dataclasses.field(default_factory=dict)
    trace_spans: int = 0
    flight_dumps: int = 0
    # chaos plane (repro.faults)
    server_failures: int = 0           # injected crashes
    recoveries: int = 0                # detected + recovered crashes
    redispatched: int = 0              # continuation requests issued
    cancelled: int = 0                 # client-cancelled requests
    fetch_retries: int = 0             # transfer attempts relaunched
    fetch_timeouts: int = 0            # attempts that blew their deadline
    breaker_opens: int = 0             # circuit-breaker open transitions
    recovery_records: List = dataclasses.field(default_factory=list)

    def _eligible(self) -> List[ServeResult]:
        return [r for r in self.results
                if r.finished and r.arrival >= self.warmup]

    def _ttfts(self) -> List[float]:
        return [r.ttft for r in self._eligible() if r.ttft is not None]

    # percentile helpers are snapshot-safe: an empty or still-warming
    # window returns NaN (not inf, not an exception) so a mid-flight
    # /metrics scrape renders cleanly
    def p50_ttft(self) -> float:
        t = self._ttfts()
        return percentile(t, 50) if t else float("nan")

    def p95_ttft(self) -> float:
        t = self._ttfts()
        return percentile(t, 95) if t else float("nan")

    def mean_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible() if r.tbt and r.tbt > 0]
        return sum(ts) / len(ts) if ts else 0.0

    def p95_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible() if r.tbt and r.tbt > 0]
        return percentile(ts, 95) if ts else 0.0

    def completed(self) -> int:
        return sum(1 for r in self.results if r.finished)

    def placement_changed(self) -> bool:
        return len(self.placements) > 1

    def meets_slo(self, slo_ttft: float) -> bool:
        p95 = self.p95_ttft()
        return self.timed_out == 0 and not math.isnan(p95) \
            and p95 <= slo_ttft

    def slo_attainment(self, slo_ttft: float) -> float:
        """Fraction of eligible requests with TTFT inside the target;
        unfinished/dropped requests count as misses."""
        elig = [r for r in self.results if r.arrival >= self.warmup]
        if not elig:
            return 1.0
        ok = sum(1 for r in elig
                 if r.finished and r.ttft is not None
                 and r.ttft <= slo_ttft)
        return ok / len(elig)


class LoRAServeCluster:
    """Incremental cluster serving: ``submit`` / ``poll`` / ``drain``,
    with the one-shot batch ``run(trace)`` implemented on top."""

    def __init__(self, backend: ServingBackend,
                 adapters: List[AdapterInfo], *,
                 policy: str = "loraserve", network=None,
                 rebalance_period: float = 15.0, warmup: float = 0.0,
                 seed: int = 0, operating_points=None, server_model=None,
                 access_mode: str = "migrate", prefetch: bool = False,
                 controller=None, track_tokens: bool = False,
                 telemetry_window: float = 30.0,
                 tracer=None, flight_recorder=None,
                 fault_plan=None, detector_window: float = 0.5,
                 durable_ssd: bool = False, retry_policy=None):
        if operating_points is None:
            from repro.cluster.costmodel import (ServerModel,
                                                 profile_operating_points)
            server_model = server_model or ServerModel()
            operating_points = profile_operating_points(
                server_model, {a.rank for a in adapters})
        self.backend = backend
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.rebalance_period = rebalance_period
        self.warmup = warmup
        self.access_mode = access_mode
        self._server_model = server_model   # for runtime-registered ranks
        # closed-loop control plane (repro.controlplane): may rebalance
        # out of band, provision servers, and drain them mid-run
        self.controller = controller
        if controller is not None:
            # hand it the capacity model for Algorithm-1 drain gating
            if controller.operating_points is None:
                controller.operating_points = dict(operating_points)
            if not controller.adapter_ranks:
                controller.adapter_ranks = {a.adapter_id: a.rank
                                            for a in adapters}
        self.orch = ClusterOrchestrator(
            backend.n_servers, adapters, operating_points, policy=policy,
            network=network, seed=seed, access_mode=access_mode,
            prefetch=prefetch, sync_store=False, retry=retry_policy,
            durable_ssd=durable_ssd)
        self.metrics = MetricsCollector()
        # always-on live telemetry window (the gateway's /metrics feed);
        # lazy import keeps repro.serving importable without dragging
        # the whole control plane in at module-import time
        from repro.controlplane.telemetry import TelemetryHub
        self.hub = TelemetryHub(window=telemetry_window)
        self.placements: List[Placement] = [
            copy.deepcopy(self.orch.placement)]
        self.rebalances = 0
        self.controller_rebalances = 0
        self.scale_ups = 0
        self.drains = 0
        self.retires = 0
        self.registered = 0              # runtime adapter registrations
        self.unregistered = 0            # completed retires
        self._provisioned_at: Dict[int, float] = {
            i: 0.0 for i in range(backend.n_servers)}
        self._retired_at: Dict[int, float] = {}
        self.per_server_counts = [0] * backend.n_servers
        self.routed: Dict[int, int] = {}       # req_id -> server
        self._submitted: List[ServeRequest] = []
        self._finished: List[ServeRequest] = []
        self._timed_out: List[ServeRequest] = []
        self._retiring: Set[str] = set()       # adapters mid-unregister
        # per-token streaming: watermark of surfaced tokens per request
        self.track_tokens = track_tokens
        self._stream_pos: Dict[int, int] = {}
        # chaos plane (repro.faults): optional scripted injector, an
        # always-armed heartbeat detector (beat-then-check per poll, so
        # false positives are structurally impossible), and
        # exactly-once continuation bookkeeping for re-dispatch
        from repro.faults import FailureDetector, FaultInjector
        self.injector = (FaultInjector(fault_plan)
                         if fault_plan is not None else None)
        self.detector = FailureDetector(window=detector_window)
        self._crashed: Set[int] = set()        # crashed, not yet recovered
        self._recovered: Set[int] = set()      # recovery ran (still down)
        self._failed_at: Dict[int, float] = {}
        self._cont_orig: Dict[int, ServeRequest] = {}   # req_id -> orig
        self._stream_base: Dict[int, int] = {}  # continuation offset
        self._pending_events: List[ClusterEvent] = []   # recovery-emitted
        self.pending_disconnects: List[int] = []   # gateway fault queue
        self.server_failures = 0
        self.recoveries = 0
        self.redispatched = 0
        self.cancelled = 0
        self.recovery_records: List = []
        self._ran = False
        self._started = False
        self._closed = False
        self._now = 0.0
        self._last_reb = 0.0
        self._next_reb = float("inf")
        self._next_ctick = float("inf")
        self._end_time = 0.0
        # -- observability wiring (before _seed_backend so lazily built
        # engines inherit the tracer) --------------------------------------
        self.tracer = tracer
        self.flight_recorder = flight_recorder
        self.cost_drift = None
        self._slo_bad = False
        self._tracer_adv = None
        self._record_spans = None
        if tracer is not None:
            from repro.cluster.costmodel import ServerModel
            from repro.obs import CostModelDrift, record_request_spans
            self._record_spans = record_request_spans
            model = (server_model
                     or getattr(backend, "model", None) or ServerModel())
            self.cost_drift = CostModelDrift(model)
            tracer.add_listener(self.cost_drift.observe)
            if flight_recorder is not None:
                tracer.add_listener(flight_recorder.observe)
            if hasattr(backend, "set_tracer"):
                backend.set_tracer(tracer)
            self.orch.store.tracer = tracer
            # virtual substrate: keep the tracer's event clock at the
            # facade's notion of now (cheap no-op for wall clocks)
            self._tracer_adv = getattr(tracer.clock, "advance", None)
        self._seed_backend()
        # running peaks across rebalances (the store GCs lazily, so the
        # end-of-run state understates what a server actually held)
        self._max_adapters = self.orch.store.max_adapters_per_server()
        self._total_bytes = self.orch.store.total_bytes()

    # -- placement -> backend sync --------------------------------------
    def _seed_backend(self) -> None:
        for sid, aids in servers_to_adapters(self.orch.placement).items():
            self.backend.load_adapters(
                sid, {aid: self.meta[aid].rank for aid in aids})

    # -- incremental lifecycle -------------------------------------------
    def start(self) -> None:
        """Anchor the clocks and arm the periodic control loops. Called
        implicitly by the first ``submit``/``poll``/``run``."""
        if self._started:
            return
        self._started = True
        self.backend.start()
        self._wall0 = time.monotonic()
        self._now = 0.0
        self._last_reb = 0.0
        self._next_reb = (self.rebalance_period
                          if self.orch.policy.dynamic else float("inf"))
        self._next_ctick = (self.controller.config.tick_period
                            if self.controller is not None
                            else float("inf"))

    def clock(self) -> float:
        """Current time on the cluster clock: the backend's wall clock
        when it has one, otherwise wall seconds since ``start()`` (a
        virtual backend driven live advances in real time)."""
        if not self._started:
            return 0.0
        if self.backend.realtime:
            return self.backend.wall_now()
        return time.monotonic() - self._wall0

    def pending(self) -> int:
        return self.backend.pending()

    def idle(self) -> bool:
        """No requests in flight, no drains or adapter retires pending."""
        return (self.backend.pending() == 0 and not self.orch.draining
                and not self._retiring)

    # -- request path (Fig 11 steps 1-4) --------------------------------
    def submit(self, req: ServeRequest,
               now: Optional[float] = None) -> int:
        """Admit one request: phi-route it, plan its adapter's data
        path, and hand it to the backend. Returns the chosen server.
        Raises ``UnknownAdapterError`` for unregistered (or retiring)
        adapters."""
        self.start()
        if now is None:
            now = self.clock()
        self._dispatch(req, now)
        self._submitted.append(req)
        return self.routed[req.req_id]

    def _dispatch(self, req: ServeRequest, now: float) -> None:
        aid = req.adapter_id
        if req.rank == 0 and aid in self.meta:
            req.rank = self.meta[aid].rank
        if aid in self._retiring:
            raise UnknownAdapterError(aid)
        if self.orch.policy.replicate_all:
            if aid not in self.meta:
                raise UnknownAdapterError(aid)
            sid = min(self.orch.placeable_servers(),
                      key=lambda i: self.backend.server_load(i, now))
            req.fetch_latency = 0.0
            self.backend.load_adapters(sid, {aid: req.rank})
        else:
            sid, plan = self.orch.route_plan(
                aid, tokens=req.prompt_len + req.output_len, now=now)
            req.apply_fetch_plan(plan, now)
            if plan.hit or plan.blocking:
                self.backend.load_adapters(sid, {aid: req.rank})
            else:
                # serve immediately from the peer copy; the warm fetch
                # promotes it at plan.eta
                self.backend.load_adapter_remote(sid, aid, req.rank,
                                                 plan.read_peer)
        if self.tracer is not None:
            # zero-width instant: the routing decision itself
            self.tracer.record("route", now, now, cat="gateway",
                               track="control", req_id=req.req_id,
                               attrs={"server": sid, "adapter_id": aid})
        self.backend.submit(sid, req, now)
        self.per_server_counts[sid] += 1
        self.routed[req.req_id] = sid
        self.hub.observe_arrival(aid, sid,
                                 req.prompt_len + req.output_len, now)
        if self.controller is not None:
            self.controller.observe_arrival(
                aid, sid, req.prompt_len + req.output_len, now)

    def _poll_store(self, now: float) -> None:
        """Drain adapter-store fetch completions: install prefetched
        and drain-migrated copies in backend banks and promote
        remote-read serves. The promote is unconditional (a no-op
        discard for non-remote copies) because a remote-read serve may
        have coalesced onto a transfer that started as a prefetch or
        migrate fetch."""
        for plan in self.orch.store.poll(now):
            aid = plan.adapter_id
            if plan.mode in ("prefetch", "drain"):
                self.backend.load_adapters(
                    plan.dest, {aid: self.meta[aid].rank})
            self.backend.promote_adapter(plan.dest, aid)

    # -- chaos plane (repro.faults) ---------------------------------------
    def apply_fault(self, ev, now: float) -> bool:
        """``FaultInjector`` host hook: apply one due fault event.
        Returns False for events that don't apply to the current state
        (chaos schedules are written blind to it)."""
        from repro.faults import (KIND_CRASH, KIND_DISCONNECT,
                                  KIND_LINK_DEGRADE, KIND_LINK_DOWN,
                                  KIND_LINK_UP, KIND_RESTORE,
                                  KIND_STALL_FETCH)
        net = self.orch.store.network
        if ev.kind == KIND_CRASH:
            return self.inject_crash(ev.target, now)
        if ev.kind == KIND_RESTORE:
            return self.inject_restore(ev.target, now)
        if ev.kind == KIND_LINK_DOWN:
            if net is None:
                return False
            net.set_link_down(ev.target)
            return True
        if ev.kind == KIND_LINK_UP:
            if net is None:
                return False
            net.set_link_up(ev.target)
            return True
        if ev.kind == KIND_LINK_DEGRADE:
            if net is None:
                return False
            net.degrade_link(ev.target, max(1.0, ev.arg))
            return True
        if ev.kind == KIND_STALL_FETCH:
            return self.inject_stall(ev.target, ev.arg)
        if ev.kind == KIND_DISCONNECT:
            # gateway-level fault: queue it for the SSE front end (the
            # pump drains these and severs the matching live stream)
            self.pending_disconnects.append(int(ev.target))
            return True
        return False

    def inject_crash(self, sid: int, now: Optional[float] = None) -> bool:
        """Fail-stop server ``sid``: execution freezes, heartbeats stop,
        and the detector confirms it dead one window later (recovery
        runs then). No-op for unknown/retired/already-dead servers."""
        if now is None:
            now = self._now
        if (sid < 0 or sid >= self.backend.n_servers
                or sid in self._retired_at or sid in self._crashed
                or sid in self._recovered):
            return False
        # final beat at the crash instant: the detector's silence window
        # starts now (covers crashes injected before the first poll)
        self.detector.beat(sid, now)
        self.backend.fail_server(sid)
        self._crashed.add(sid)
        self._failed_at[sid] = now
        self.server_failures += 1
        if self.flight_recorder is not None:
            self.flight_recorder.dump("fault-crash", now, {"server": sid})
        return True

    def inject_restore(self, sid: int,
                       now: Optional[float] = None) -> bool:
        """Bring a crashed server back. If recovery already ran it
        rejoins the fleet empty (placement re-warms it); if the crash
        was never detected (a sub-window flap) the stranded work simply
        resumes."""
        if now is None:
            now = self._now
        if sid not in self._crashed and sid not in self._recovered:
            return False
        self.backend.restore_server(sid)
        if sid in self._recovered:
            self._recovered.discard(sid)
            self.orch.restore_server(sid, now)
            self._sync_banks(self.orch.placement)
        self._crashed.discard(sid)
        self.detector.restore(sid, now)
        if self.flight_recorder is not None:
            self.flight_recorder.dump("fault-restore", now,
                                      {"server": sid})
        return True

    def inject_stall(self, target: int = -1,
                     extra: float = 0.0) -> bool:
        """Freeze (``extra == 0``) or slow one in-flight transfer
        touching server ``target`` (any transfer when -1)."""
        store = self.orch.store
        for (dest, aid), p in sorted(store._inflight.items()):
            if p.retry_at >= 0:
                continue
            if target >= 0 and dest != target and p.src_server != target:
                continue
            return store.stall_transfer(
                dest, aid, extra if extra > 0 else float("inf"))
        return False

    def _beat_and_check(self, now: float) -> None:
        """Heartbeat every alive server, then confirm the silent ones —
        beat-then-check inside one poll means a virtual-clock jump can
        never outrun a healthy server's beats."""
        for sid in range(self.backend.n_servers):
            if sid in self._retired_at:
                # scale-in, not a crash: silence is expected — stop
                # watching so the detector never falsely confirms it
                self.detector.remove(sid)
                continue
            if sid in self._recovered:
                continue
            if self.backend.server_alive(sid):
                self.detector.beat(sid, now)
        for sid in self.detector.check(now):
            if sid in self.orch.active:
                self._recover_server(sid, now)

    def _recover_server(self, sid: int, now: float) -> None:
        """Confirmed-dead recovery: collect the stranded requests, drop
        the server from placement/routing (orphaned adapters re-warm on
        survivors), and re-dispatch every stranded request from its
        last client-visible token."""
        from repro.faults import RecoveryRecord
        detected = now
        stranded = self.backend.drain_failed(sid)
        plans = self.orch.fail_server(sid, now=now)
        self._crashed.discard(sid)
        self._recovered.add(sid)
        if self.controller is not None and \
                hasattr(self.controller, "observe_failure"):
            self.controller.observe_failure(sid, now)
        redone = 0
        for req in sorted(stranded, key=lambda r: r.req_id):
            if self._redispatch(req, now):
                redone += 1
        self.recoveries += 1
        rec = RecoveryRecord(server=sid, detected_at=detected,
                             recovered_at=now, redispatched=redone,
                             orphaned_adapters=len(plans))
        self.recovery_records.append(rec)
        if self.flight_recorder is not None:
            self.flight_recorder.dump(
                "fault-recover", now,
                {"server": sid, "redispatched": redone,
                 "stranded": len(stranded),
                 "recovery_plans": len(plans),
                 "crashed_at": self._failed_at.get(sid, now)})

    def _redispatch(self, req: ServeRequest, now: float) -> bool:
        """Exactly-once re-dispatch of one stranded request: surface
        any host-side tokens the client has not seen yet, then submit a
        continuation for the remaining budget under the same
        ``req_id``. Requests that already had every token are finalized
        directly."""
        from repro.faults import (delivered_tokens, make_continuation,
                                  remaining_tokens)
        if req.req_id in self._cont_orig:
            # a continuation itself stranded: re-continue the original
            orig = self._cont_orig.pop(req.req_id)
            from repro.faults import merge_continuation
            merge_continuation(orig, req)
            self._stream_base.pop(req.req_id, None)
            req = orig
            req.finish = -1.0
            req.t_finish = None
        if self.track_tokens:
            toks = self._new_tokens(req)
        else:
            self._stream_pos[req.req_id] = delivered_tokens(req)
            toks = ()
        if toks:
            self._pending_events.append(
                ClusterEvent("token", req, toks, now))
        if remaining_tokens(req) <= 0:
            # every token was generated; only the completion was lost
            from repro.core.request import Phase
            req.finish = now
            req.t_finish = now
            req.phase = Phase.DONE
            self.metrics.record(req)
            self.hub.observe_completion(req, now)
            self._finished.append(req)
            self._stream_pos.pop(req.req_id, None)
            self._stream_base.pop(req.req_id, None)
            self._pending_events.append(
                ClusterEvent("finish", req, (), now))
            return False
        cont = make_continuation(req, now)
        self._cont_orig[req.req_id] = req
        self._stream_base[req.req_id] = delivered_tokens(req)
        try:
            self._dispatch(cont, now)
        except UnknownAdapterError:
            # adapter retired mid-crash: surface a timeout, not silence
            self._cont_orig.pop(req.req_id, None)
            self._stream_base.pop(req.req_id, None)
            self._timed_out.append(req)
            self.hub.observe_timeout(now)
            self._stream_pos.pop(req.req_id, None)
            self._pending_events.append(
                ClusterEvent("timeout", req, (), now))
            return False
        self.redispatched += 1
        return True

    def take_disconnects(self) -> List[int]:
        """Drain queued ``disconnect_client`` fault targets (consumed
        by the gateway's pump, which severs the matching stream)."""
        out, self.pending_disconnects = self.pending_disconnects, []
        return out

    def cancel_request(self, req_id: int) -> bool:
        """Abort a live request (client went away): free its backend
        slot/queue entry and drop its streaming state. Returns True if
        the request was live."""
        req = self.backend.cancel_request(req_id)
        if req is None:
            return False
        self.cancelled += 1
        self._stream_pos.pop(req_id, None)
        self._stream_base.pop(req_id, None)
        self._cont_orig.pop(req_id, None)
        return True

    # -- runtime adapter lifecycle ----------------------------------------
    def register_adapter(self, info: AdapterInfo,
                         now: Optional[float] = None) -> int:
        """Make a new adapter servable mid-run: place it on the
        emptiest live server, seed the store/routing entries, and load
        it into that server's bank. Subsequent rebalances fold it into
        the demand-driven placement. Returns the initial server id."""
        if now is None:
            now = self._now
        if info.adapter_id in self.meta:
            raise ValueError(f"adapter {info.adapter_id!r} is already "
                             f"registered")
        if info.rank not in self.orch.operating_points:
            from repro.cluster.costmodel import (ServerModel,
                                                 profile_operating_points)
            pts = profile_operating_points(
                self._server_model or ServerModel(), {info.rank})
            self.orch.operating_points.update(pts)
            if self.controller is not None \
                    and self.controller.operating_points is not None:
                self.controller.operating_points.update(pts)
        sid = self.orch.register_adapter(info, now=now)
        self.meta[info.adapter_id] = info
        self.backend.load_adapters(sid, {info.adapter_id: info.rank})
        if self.controller is not None:
            self.controller.adapter_ranks[info.adapter_id] = info.rank
        self._sync_banks(self.orch.placement)   # records the new entry
        self.registered += 1
        return sid

    def unregister_adapter(self, adapter_id: str,
                           now: Optional[float] = None) -> None:
        """Start a loss-free adapter retire: routing stops immediately
        (new requests raise ``UnknownAdapterError``), in-flight requests
        run to completion, then ``poll`` evicts the copies from backend
        banks and purges the store. Raises ``UnknownAdapterError`` for
        adapters that aren't registered (or are already retiring)."""
        if adapter_id not in self.meta or adapter_id in self._retiring:
            raise UnknownAdapterError(adapter_id)
        if now is None:
            now = self._now
        self.orch.begin_retire_adapter(adapter_id)
        self._retiring.add(adapter_id)
        # idle adapters leave at once; busy ones on a later poll
        self._finish_retiring(now)

    def adapter_entries(self) -> List[dict]:
        """Live adapter table (the gateway's ``GET /v1/adapters``):
        rank, phi-weighted placement, per-server tier residency, and
        whether a loss-free retire is in progress."""
        store = self.orch.store
        out = []
        for aid in sorted(self.meta):
            info = self.meta[aid]
            entry = self.orch.placement.get(aid, {})
            servers = {}
            for sid in sorted(set(entry) | store.index.get(aid, set())):
                servers[sid] = {
                    "phi": round(entry.get(sid, 0.0), 6),
                    "tier": store.tier(sid, aid),
                }
            out.append({
                "adapter_id": aid,
                "rank": info.rank,
                "nbytes": info.nbytes,
                "servers": servers,
                "draining": aid in self._retiring,
            })
        return out

    def _finish_retiring(self, now: float) -> None:
        """Complete retires whose adapters have gone quiet: no live
        requests reference them and no store transfer is moving them."""
        if not self._retiring:
            return
        live = None
        for aid in sorted(self._retiring):
            if self.orch.store.inflight_count(aid):
                continue
            if live is None:
                live = {r.adapter_id for r in self.backend.live_requests()}
            if aid in live:
                continue
            for sid in range(self.backend.n_servers):
                if sid in self._retired_at:
                    continue
                if aid in self.backend.hosted_adapters(sid):
                    # may refuse (e.g. a server's last adapter keeps its
                    # bank shape); the stale bank row is harmless and
                    # the store/routing state below is authoritative
                    self.backend.evict_adapter(sid, aid)
            self.orch.finish_retire_adapter(aid)
            self._retiring.discard(aid)
            self.meta.pop(aid, None)
            self.unregistered += 1

    # -- control path (Fig 11 steps 6-7), mid-flight --------------------
    def _sync_banks(self, placement: Placement) -> None:
        """Sync backend banks down to the placement (evictions only —
        newly placed adapters load lazily on their first routed
        request). Runs at *every* timestep, not only when the placement
        changed: an eviction refused while the adapter was in flight
        must be retried once that traffic drains."""
        prev = self.placements[-1]
        if placement != prev:
            self.placements.append(copy.deepcopy(placement))
        want = servers_to_adapters(placement)
        for sid in range(self.backend.n_servers):
            if sid in self._retired_at:
                continue
            wanted = set(want.get(sid, []))
            for aid in list(self.backend.hosted_adapters(sid)):
                if aid not in wanted and aid not in self._retiring:
                    self.backend.evict_adapter(sid, aid)
        self._max_adapters = max(self._max_adapters,
                                 self.orch.store.max_adapters_per_server())
        self._total_bytes = max(self._total_bytes,
                                self.orch.store.total_bytes())

    def _rebalance(self, period: float, now: float,
                   periodic: bool = True) -> None:
        new = self.orch.end_of_timestep(max(period, 1e-9), now=now)
        if periodic:
            self.rebalances += 1
        self._sync_banks(new)

    # -- controller actions (controlplane tick) --------------------------
    def _control_tick(self, now: float) -> None:
        from repro.controlplane import ClusterState
        ctrl = self.controller
        orch = self.orch
        drained = [sid for sid in sorted(orch.draining)
                   if orch.drain_complete(sid)
                   and self.backend.server_load(sid, now) == 0]
        live = [s for s in range(self.backend.n_servers)
                if s not in self._retired_at]
        state = ClusterState(
            now=now,
            active=list(orch.placeable_servers()),
            draining=sorted(orch.draining),
            drained=drained,
            queue_depth={s: self.backend.queue_depth(s) for s in live},
            utilization={s: self.backend.utilization(s, now)
                         for s in live})
        actions = ctrl.tick(state)
        for a in actions:
            if a.kind == "rebalance":
                self.controller_rebalances += 1
                # skip if a periodic rebalance already ran this instant:
                # re-observing a just-cleared window would feed the
                # demand estimator a spurious zero-tps sample
                if now - self._last_reb > 1e-9:
                    self._rebalance(now - self._last_reb, now,
                                    periodic=False)
                    self._last_reb = now
            elif a.kind == "scale-up":
                self.scale_ups += 1
                sid = self.orch.add_server(now)
                bid = self.backend.add_server()
                assert sid == bid, "store/backend server ids diverged"
                self._provisioned_at[sid] = now
                self.per_server_counts.append(0)
                self._sync_banks(self.orch.placement)
            elif a.kind == "drain":
                self.drains += 1
                self.orch.begin_drain(a.server, now=now)
                self._sync_banks(self.orch.placement)
            elif a.kind == "retire":
                self.retires += 1
                self.orch.retire_server(a.server)
                self.backend.retire_server(a.server)
                self._retired_at[a.server] = now
        rec = self.flight_recorder
        if rec is not None:
            inputs = getattr(ctrl, "last_inputs", {})
            # scale decisions and fresh SLO violations each snapshot the
            # span ring with the controller's decision inputs as audit
            for a in actions:
                if a.kind in ("scale-up", "drain"):
                    rec.dump(a.kind, now,
                             {**dataclasses.asdict(a), **inputs})
            violated = bool(inputs.get("violated", False))
            if violated and not self._slo_bad:
                rec.dump("slo-violation", now, dict(inputs))
            self._slo_bad = violated

    # -- token surfacing ---------------------------------------------------
    def _new_tokens(self, req: ServeRequest) -> Tuple:
        """Tokens decoded since the last poll. Real-engine requests
        surface actual token ids from ``req.output``; simulated ones
        surface ``None`` placeholders (the sim models counts, not
        values) at the same cadence."""
        pos = self._stream_pos.get(req.req_id, 0)
        # a continuation's tokens continue the original stream: its
        # counters restart at zero, so offset by the delivered base
        base = self._stream_base.get(req.req_id, 0)
        if req.output:
            cur = base + len(req.output)
            toks = tuple(req.output[pos - base:cur - base])
        else:
            cur = base + req.decoded
            toks = (None,) * max(0, cur - pos)
        if cur > pos:
            self._stream_pos[req.req_id] = cur
        return toks

    # -- the loop body ----------------------------------------------------
    def poll(self, now: Optional[float] = None) -> List[ClusterEvent]:
        """One control-loop tick at ``now``: complete due adapter
        transfers, fire due rebalances and controller ticks, advance
        every backend server once, and return what happened — finish
        and timeout events always, per-token events when the cluster
        was built with ``track_tokens=True``."""
        self.start()
        if now is None:
            now = self.clock()
        if self._tracer_adv is not None:
            self._tracer_adv(now)
        events: List[ClusterEvent] = []
        ctrl = self.controller
        # chaos plane first: due faults land, then heartbeats + the
        # confirmed-dead check (recovery re-dispatches synchronously and
        # queues its token/finish events on _pending_events)
        if self.injector is not None:
            self.injector.poll(now, self)
        self._beat_and_check(now)
        if self._pending_events:
            events.extend(self._pending_events)
            self._pending_events = []
        self._poll_store(now)
        if self.orch.policy.dynamic and now + 1e-12 >= self._next_reb:
            self._rebalance(now - self._last_reb, now)
            self._last_reb = now
            self._next_reb = now + self.rebalance_period
        if ctrl is not None and now + 1e-12 >= self._next_ctick:
            self._control_tick(now)
            self._next_ctick = now + ctrl.config.tick_period
        self.backend.step(now)
        if self.track_tokens:
            for req in self.backend.live_requests():
                toks = self._new_tokens(req)
                if toks:
                    events.append(ClusterEvent("token", req, toks, now))
        for req in self.backend.drain_completed():
            orig = self._cont_orig.pop(req.req_id, None)
            if orig is not None and orig is not req:
                # a finished continuation reports as its original:
                # one request, full output, end-to-end timestamps
                from repro.faults import merge_continuation
                self._stream_base.pop(req.req_id, None)
                merge_continuation(orig, req)
                req = orig
            done_at = req.finish if req.finish >= 0 else now
            self.metrics.record(req)
            self.hub.observe_completion(req, done_at)
            self._finished.append(req)
            if self._record_spans is not None:
                self._record_spans(self.tracer, req)
            if ctrl is not None:
                ctrl.observe_completion(req, done_at)
            toks = self._new_tokens(req) if self.track_tokens else ()
            self._stream_pos.pop(req.req_id, None)
            events.append(ClusterEvent("finish", req, toks, now))
        for req in self.backend.drain_timed_out():
            orig = self._cont_orig.pop(req.req_id, None)
            if orig is not None and orig is not req:
                self._stream_base.pop(req.req_id, None)
                req = orig
            self._timed_out.append(req)
            self.hub.observe_timeout(now)
            if ctrl is not None:
                ctrl.observe_timeout(now)
            self._stream_pos.pop(req.req_id, None)
            if self.flight_recorder is not None:
                self.flight_recorder.dump(
                    "timeout", now,
                    {"req_id": req.req_id,
                     "adapter_id": req.adapter_id,
                     "server": req.server, "arrival": req.arrival})
            events.append(ClusterEvent("timeout", req, (), now))
        self._finish_retiring(now)
        self._now = max(self._now, now)
        self._end_time = max(self._end_time, self._now)
        return events

    def _next_time(self, now: float, arrivals_left: bool,
                   next_arrival: Optional[float] = None
                   ) -> Optional[float]:
        """Earliest future instant anything can happen (virtual-clock
        drivers jump to it); None when the cluster is eternally idle."""
        cands = []
        if next_arrival is not None:
            cands.append(next_arrival)
        t = self.backend.next_event_time(now)
        if t is not None:
            cands.append(t)
        t = self.orch.store.next_event_time(now)
        if t is not None:
            cands.append(t)
        if self.orch.policy.dynamic and (arrivals_left
                                         or self.backend.pending()):
            cands.append(self._next_reb)
        if self.controller is not None and (arrivals_left
                                            or self.backend.pending()
                                            or self.orch.draining):
            cands.append(self._next_ctick)
        if self.injector is not None:
            t = self.injector.next_time()
            if t is not None:
                cands.append(max(t, now))
        if self._crashed:
            # a crashed server's confirmation deadline — virtual clocks
            # must reach it for detection (and recovery) to fire
            t = self.detector.next_deadline(now)
            if t is not None:
                cands.append(t)
        if not cands:
            return None
        return min(cands)

    # -- drain ------------------------------------------------------------
    def drain(self, max_steps: int = 10_000_000) -> List[ClusterEvent]:
        """Finish everything in flight — queued requests, store
        transfers, server drains, adapter retires — without admitting
        new work. Returns every event observed on the way out."""
        self.start()
        events: List[ClusterEvent] = []
        now = self._now
        for _ in range(max_steps):
            if self.backend.realtime:
                now = self.backend.wall_now()
            events.extend(self.poll(now))
            if self.idle():
                break
            if self.backend.realtime:
                time.sleep(0.001)
            else:
                nxt = self._next_time(now, arrivals_left=False)
                if nxt is None:
                    break
                now = max(now, nxt)
        # drain trailing transfers (warm fetches/prefetches still in
        # flight when the last request finished) so the report's bank
        # and remote-residency state is consistent
        self._poll_store(float("inf"))
        self._end_time = max(self._end_time, now)
        return events

    def close(self) -> None:
        """Release backend execution resources (engine banks) after a
        drain. The report must be snapshotted first — retired servers
        report empty memory profiles."""
        if self._closed:
            return
        self._closed = True
        self._poll_store(float("inf"))
        for sid in range(self.backend.n_servers):
            if sid in self._retired_at:
                continue
            self.backend.retire_server(sid)

    # -- batch replay (implemented on submit/poll) -------------------------
    def run(self, trace: List[ServeRequest], *,
            max_steps: int = 10_000_000) -> ClusterReport:
        if self._ran:
            raise RuntimeError("LoRAServeCluster is one-shot; build a "
                               "fresh instance per run")
        self._ran = True
        trace = sorted(trace, key=lambda r: r.arrival)
        n = len(trace)
        self.start()
        now = 0.0
        i = 0
        for _ in range(max_steps):
            self._poll_store(now)
            while i < n and trace[i].arrival <= now + 1e-12:
                self.submit(trace[i], now)
                i += 1
            self.poll(now)
            if i >= n and self.backend.pending() == 0 \
                    and not self.orch.draining:
                break
            if self.backend.realtime:
                if self.backend.pending() == 0 and i < n:
                    time.sleep(max(0.0, min(
                        trace[i].arrival - self.backend.wall_now(), 0.01)))
                now = self.backend.wall_now()
            else:
                nxt = self._next_time(
                    now, i < n, trace[i].arrival if i < n else None)
                if nxt is None:
                    break           # nothing can ever happen again
                now = max(now, nxt)
        self._poll_store(float("inf"))
        self._end_time = now
        return self._report(trace)

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> ClusterReport:
        """Mid-flight report over everything submitted so far —
        unfinished requests appear with ``finished=False`` and none of
        the percentile helpers raise on the partial window. This is
        what feeds a live ``/metrics`` scrape; it does not require (or
        wait for) the run to complete."""
        return self._report(list(self._submitted))

    def report(self) -> ClusterReport:
        """Final report over every submitted request."""
        return self._report(list(self._submitted))

    def _report(self, reqs: List[ServeRequest]) -> ClusterReport:
        if self.tracer is not None:
            flush = getattr(self.backend, "flush_spans", None)
            if flush is not None:
                flush()     # staged (coalesced) decode spans
        done_ids = {id(r) for r in self._finished}
        results = []
        for r in reqs:
            finished = id(r) in done_ids
            results.append(ServeResult(
                req_id=r.req_id, adapter_id=r.adapter_id, rank=r.rank,
                server=r.server, arrival=r.arrival, finished=finished,
                ttft=r.ttft if finished else None,
                tbt=r.tbt if finished else None,
                fetch_latency=r.fetch_latency,
                n_output=len(r.output) if r.output else r.decoded))
        store = self.orch.store
        if self.orch.policy.replicate_all:
            max_adapters = len(self.adapters)
            total_bytes = sum(a.nbytes for a in self.adapters) \
                * self.backend.n_servers
        else:
            max_adapters = max(self._max_adapters,
                               store.max_adapters_per_server())
            total_bytes = max(self._total_bytes, store.total_bytes())
        end = max(self._end_time, self._now)
        gpu_seconds = sum(
            self._retired_at.get(sid, end) - t0
            for sid, t0 in self._provisioned_at.items())
        return ClusterReport(
            results=results,
            summary=self.metrics.summary(),
            rebalances=self.rebalances,
            placements=self.placements,
            per_server_counts=list(self.per_server_counts),
            timed_out=len(self._timed_out),
            fetches=store.fetches,
            fetch_bytes=store.fetch_bytes,
            max_adapters_per_server=max_adapters,
            total_adapter_bytes=total_bytes,
            memory_profile=self.backend.memory_profile(),
            warmup=self.warmup,
            bank_mode=getattr(self.backend, "bank_mode", "padded"),
            mesh_shape=getattr(self.backend, "mesh_shape", None),
            in_progress=sum(1 for r in results if not r.finished),
            access_mode=self.access_mode,
            remote_reads=store.remote_reads,
            prefetches=store.prefetches,
            coalesced_fetches=store.coalesced,
            registered=self.registered,
            unregistered=self.unregistered,
            scale_ups=self.scale_ups,
            drains=self.drains,
            retires=self.retires,
            controller_rebalances=self.controller_rebalances,
            gpu_seconds=gpu_seconds,
            final_servers=len(self.orch.placeable_servers()),
            drift_events=(list(self.controller.detector.events)
                          if self.controller is not None else []),
            controller_actions=(list(self.controller.actions)
                                if self.controller is not None else []),
            cost_drift=(self.cost_drift.summary()
                        if self.cost_drift is not None else {}),
            trace_spans=(self.tracer.n_spans
                         if self.tracer is not None else 0),
            flight_dumps=(self.flight_recorder.n_dumps
                          if self.flight_recorder is not None else 0),
            server_failures=self.server_failures,
            recoveries=self.recoveries,
            redispatched=self.redispatched,
            cancelled=self.cancelled,
            fetch_retries=store.fetch_retries,
            fetch_timeouts=store.fetch_timeouts,
            breaker_opens=sum(b.opens for b in store.breakers.values()),
            recovery_records=list(self.recovery_records),
        )
