"""LoRAServeCluster: one serving facade over either execution substrate.

Owns the paper's control plane (``ClusterOrchestrator``: placement
policy, phi-weighted routing table, distributed adapter pool, demand
estimator) and drives a ``ServingBackend`` (simulated or real-JAX) on a
shared clock:

* arrivals are phi-routed (Fig 11 steps 1-2) and the adapter is pulled
  through the distributed pool + the backend's ``load_adapters`` before
  submission (steps 3-4);
* every ``rebalance_period`` seconds the demand window closes and
  ``end_of_timestep`` re-places adapters (steps 6-7) *while requests are
  in flight*: the routing table and pool are re-seeded mid-run, idle
  adapters are evicted from server banks, and subsequent requests follow
  the updated phi;
* completions stream back as ``ServeResult`` records through one
  ``MetricsCollector`` regardless of backend.

This is the unified serving API the launcher, examples, and benchmarks
build on.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, List, Optional

from repro.core import ClusterOrchestrator
from repro.core.request import ServeRequest
from repro.core.types import AdapterInfo, Placement, servers_to_adapters

from .backend import ServingBackend
from .metrics import MetricsCollector, percentile


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Per-request outcome, identical for sim and real backends."""
    req_id: int
    adapter_id: str
    rank: int
    server: int
    arrival: float
    finished: bool
    ttft: Optional[float]
    tbt: Optional[float]
    fetch_latency: float
    n_output: int


@dataclasses.dataclass
class ClusterReport:
    results: List[ServeResult]
    summary: dict
    rebalances: int                    # control-loop timesteps fired
    placements: List[Placement]        # history; >1 entry => re-placed
    per_server_counts: List[int]
    timed_out: int
    fetches: int
    fetch_bytes: int
    max_adapters_per_server: int
    total_adapter_bytes: int
    memory_profile: List[dict]
    warmup: float = 0.0
    bank_mode: str = "padded"          # bank layout the backend ran with

    def _eligible(self) -> List[ServeResult]:
        return [r for r in self.results
                if r.finished and r.arrival >= self.warmup]

    def _ttfts(self) -> List[float]:
        return [r.ttft for r in self._eligible() if r.ttft is not None]

    def p50_ttft(self) -> float:
        t = self._ttfts()
        return percentile(t, 50) if t else float("inf")

    def p95_ttft(self) -> float:
        t = self._ttfts()
        return percentile(t, 95) if t else float("inf")

    def mean_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible() if r.tbt and r.tbt > 0]
        return sum(ts) / len(ts) if ts else 0.0

    def p95_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible() if r.tbt and r.tbt > 0]
        return percentile(ts, 95) if ts else 0.0

    def completed(self) -> int:
        return sum(1 for r in self.results if r.finished)

    def placement_changed(self) -> bool:
        return len(self.placements) > 1

    def meets_slo(self, slo_ttft: float) -> bool:
        return self.timed_out == 0 and self.p95_ttft() <= slo_ttft


class LoRAServeCluster:
    """One-shot cluster run: construct, ``run(trace)``, read the report."""

    def __init__(self, backend: ServingBackend,
                 adapters: List[AdapterInfo], *,
                 policy: str = "loraserve", network=None,
                 rebalance_period: float = 15.0, warmup: float = 0.0,
                 seed: int = 0, operating_points=None, server_model=None):
        if operating_points is None:
            from repro.cluster.costmodel import (ServerModel,
                                                 profile_operating_points)
            operating_points = profile_operating_points(
                server_model or ServerModel(), {a.rank for a in adapters})
        self.backend = backend
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.rebalance_period = rebalance_period
        self.warmup = warmup
        self.orch = ClusterOrchestrator(
            backend.n_servers, adapters, operating_points, policy=policy,
            network=network, seed=seed)
        self.metrics = MetricsCollector()
        self.placements: List[Placement] = [
            copy.deepcopy(self.orch.placement)]
        self.rebalances = 0
        self.per_server_counts = [0] * backend.n_servers
        self.routed: Dict[int, int] = {}       # req_id -> server
        self._finished: List[ServeRequest] = []
        self._timed_out: List[ServeRequest] = []
        self._ran = False
        self._seed_backend()
        # running peaks across rebalances (the pool GCs lazily, so the
        # end-of-run state understates what a server actually held)
        self._max_adapters = self.orch.pool.max_adapters_per_server()
        self._total_bytes = self.orch.pool.total_bytes()

    # -- placement -> backend sync --------------------------------------
    def _seed_backend(self) -> None:
        for sid, aids in servers_to_adapters(self.orch.placement).items():
            self.backend.load_adapters(
                sid, {aid: self.meta[aid].rank for aid in aids})

    # -- request path (Fig 11 steps 1-4) --------------------------------
    def _dispatch(self, req: ServeRequest, now: float) -> None:
        aid = req.adapter_id
        if req.rank == 0 and aid in self.meta:
            req.rank = self.meta[aid].rank
        if self.orch.policy.replicate_all:
            sid = min(range(self.backend.n_servers),
                      key=lambda i: self.backend.server_load(i, now))
            fetch = 0.0
        else:
            sid, fetch = self.orch.route(
                aid, tokens=req.prompt_len + req.output_len)
        req.fetch_latency = fetch
        self.backend.load_adapters(sid, {aid: req.rank})
        self.backend.submit(sid, req, now)
        self.per_server_counts[sid] += 1
        self.routed[req.req_id] = sid

    # -- control path (Fig 11 steps 6-7), mid-flight --------------------
    def _rebalance(self, period: float) -> None:
        prev = self.placements[-1]
        new = self.orch.end_of_timestep(max(period, 1e-9))
        self.rebalances += 1
        if new != prev:
            self.placements.append(copy.deepcopy(new))
        # sync backend banks to the placement at *every* timestep, not
        # only when it changed: an eviction refused while the adapter
        # was in flight must be retried once that traffic drains
        want = servers_to_adapters(new)
        for sid in range(self.backend.n_servers):
            wanted = set(want.get(sid, []))
            for aid in list(self.backend.hosted_adapters(sid)):
                if aid not in wanted:
                    self.backend.evict_adapter(sid, aid)
        # newly placed adapters load lazily on their first routed request
        self._max_adapters = max(self._max_adapters,
                                 self.orch.pool.max_adapters_per_server())
        self._total_bytes = max(self._total_bytes,
                                self.orch.pool.total_bytes())

    # -- run loop --------------------------------------------------------
    def run(self, trace: List[ServeRequest], *,
            max_steps: int = 10_000_000) -> ClusterReport:
        if self._ran:
            raise RuntimeError("LoRAServeCluster is one-shot; build a "
                               "fresh instance per run")
        self._ran = True
        trace = sorted(trace, key=lambda r: r.arrival)
        n = len(trace)
        dynamic = self.orch.policy.dynamic
        self.backend.start()
        now = 0.0
        last_reb = 0.0
        next_reb = self.rebalance_period if dynamic else float("inf")
        i = 0
        for _ in range(max_steps):
            while i < n and trace[i].arrival <= now + 1e-12:
                self._dispatch(trace[i], now)
                i += 1
            if dynamic and now + 1e-12 >= next_reb:
                self._rebalance(now - last_reb)
                last_reb = now
                next_reb = now + self.rebalance_period
            self.backend.step(now)
            for req in self.backend.drain_completed():
                self.metrics.record(req)
                self._finished.append(req)
            self._timed_out.extend(self.backend.drain_timed_out())
            if i >= n and self.backend.pending() == 0:
                break
            if self.backend.realtime:
                if self.backend.pending() == 0 and i < n:
                    time.sleep(max(0.0, min(
                        trace[i].arrival - self.backend.wall_now(), 0.01)))
                now = self.backend.wall_now()
            else:
                cands = []
                if i < n:
                    cands.append(trace[i].arrival)
                t = self.backend.next_event_time(now)
                if t is not None:
                    cands.append(t)
                if dynamic and (i < n or self.backend.pending()):
                    cands.append(next_reb)
                if not cands:
                    break           # nothing can ever happen again
                now = max(now, min(cands))
        return self._report(trace)

    def _report(self, trace: List[ServeRequest]) -> ClusterReport:
        done_ids = {id(r) for r in self._finished}
        results = []
        for r in trace:
            finished = id(r) in done_ids
            results.append(ServeResult(
                req_id=r.req_id, adapter_id=r.adapter_id, rank=r.rank,
                server=r.server, arrival=r.arrival, finished=finished,
                ttft=r.ttft if finished else None,
                tbt=r.tbt if finished else None,
                fetch_latency=r.fetch_latency,
                n_output=len(r.output) if r.output else r.decoded))
        pool = self.orch.pool
        if self.orch.policy.replicate_all:
            max_adapters = len(self.adapters)
            total_bytes = sum(a.nbytes for a in self.adapters) \
                * self.backend.n_servers
        else:
            max_adapters = max(self._max_adapters,
                               pool.max_adapters_per_server())
            total_bytes = max(self._total_bytes, pool.total_bytes())
        return ClusterReport(
            results=results,
            summary=self.metrics.summary(),
            rebalances=self.rebalances,
            placements=self.placements,
            per_server_counts=list(self.per_server_counts),
            timed_out=len(self._timed_out),
            fetches=pool.fetches,
            fetch_bytes=pool.fetch_bytes,
            max_adapters_per_server=max_adapters,
            total_adapter_bytes=total_bytes,
            memory_profile=self.backend.memory_profile(),
            warmup=self.warmup,
            bank_mode=getattr(self.backend, "bank_mode", "padded"),
        )
