"""Trace-driven driver for a ServingEngine: replays (arrival, request)
streams against wall-clock time, collecting TTFT/TBT."""
from __future__ import annotations

import time
from typing import Iterable, List

from .engine import ServingEngine
from .request import Request


def replay(engine: ServingEngine, requests: List[Request],
           speedup: float = 1.0, max_iters: int = 1_000_000) -> dict:
    """Feed `requests` (with .arrival in seconds) into the engine in real
    time (optionally compressed by `speedup`), stepping the engine
    continuously. Returns metrics summary."""
    pending = sorted(requests, key=lambda r: r.arrival)
    t0 = time.monotonic()
    i = 0
    iters = 0
    while (i < len(pending) or engine.queue or engine.active) \
            and iters < max_iters:
        now = (time.monotonic() - t0) * speedup
        while i < len(pending) and pending[i].arrival <= now:
            r = pending[i]
            r.arrival = t0 + r.arrival / speedup
            engine.submit(r)
            i += 1
        engine.step()
        iters += 1
    return engine.metrics.summary()
