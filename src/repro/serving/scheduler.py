"""Trace-driven driver for a ServingEngine: replays (arrival, request)
streams against wall-clock time, collecting TTFT/TBT.

Replay timing is kept local: instead of rebasing ``r.arrival`` to
wall-clock in place (which corrupted requests for any second use), the
engine is temporarily driven by a *trace-relative* clock — ``now`` is
seconds since replay start, scaled by ``speedup`` — so the engine's
timestamps land in the same domain as the untouched arrivals.
"""
from __future__ import annotations

import time
import warnings
from typing import List

from .engine import ServingEngine
from .request import ServeRequest


def replay(engine: ServingEngine, requests: List[ServeRequest],
           speedup: float = 1.0, max_iters: int = 1_000_000) -> dict:
    """Feed `requests` (with .arrival in seconds) into the engine in real
    time (optionally compressed by `speedup`), stepping the engine
    continuously. Returns the metrics summary plus an ``exhausted`` key:
    True when the iteration budget ran out with requests still pending
    (a truncated replay must not masquerade as a complete one). Does not
    mutate arrivals."""
    pending = sorted(requests, key=lambda r: r.arrival)
    t0 = time.monotonic()
    old_clock = engine._clock
    engine._clock = lambda: (time.monotonic() - t0) * speedup
    i = 0
    iters = 0
    try:
        while (i < len(pending) or engine.queue or engine.active) \
                and iters < max_iters:
            now = (time.monotonic() - t0) * speedup
            while i < len(pending) and pending[i].arrival <= now:
                engine.submit(pending[i])
                i += 1
            engine.step()
            iters += 1
    finally:
        engine._clock = old_clock
    summary = engine.metrics.summary()
    left = (len(pending) - i) + len(engine.queue) + engine.active
    summary["exhausted"] = left > 0
    if summary["exhausted"]:
        warnings.warn(
            f"replay stopped at max_iters={max_iters} with {left} "
            f"request(s) still pending — metrics cover a truncated run",
            RuntimeWarning, stacklevel=2)
    return summary
