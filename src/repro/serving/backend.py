"""One backend protocol for both execution substrates.

``ServingBackend`` is the contract ``LoRAServeCluster`` drives: submit a
request to a server, advance all servers on a shared clock, drain
completion events, and introspect per-server load and adapter memory.
Two implementations:

* ``SimBackend`` — wraps the discrete-event ``SimServer`` pool and the
  calibrated ``ServerModel`` cost model; time is virtual and the facade
  jumps the clock to ``next_event_time``.
* ``EngineBackend`` — wraps real-JAX ``ServingEngine`` instances, one
  per server, each built *lazily from the adapter subset placed on it*
  (so a server hosting ranks {8, 16} pays a 16-wide bank, not the global
  max). Time is wall-clock seconds since run start.

Both speak the unified ``ServeRequest`` lifecycle type and honor
``load_adapters`` / ``evict_adapter`` so the control loop can re-place
adapters while requests are in flight.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List, Optional, Protocol, runtime_checkable

from repro.core.request import ServeRequest


@runtime_checkable
class ServingBackend(Protocol):
    """What a cluster execution substrate must provide."""

    n_servers: int
    realtime: bool    # True: wall clock (poll); False: virtual (jump)

    def start(self) -> None:
        """Called once when a run begins (anchors realtime clocks)."""
        ...

    def submit(self, server_id: int, req: ServeRequest,
               now: float) -> None: ...

    def step(self, now: float) -> None:
        """Advance every server that has runnable work at ``now``."""
        ...

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest future time anything can happen (virtual backends);
        None when idle or realtime."""
        ...

    def wall_now(self) -> float:
        """Current shared-clock time (realtime backends only)."""
        ...

    def drain_completed(self) -> List[ServeRequest]: ...

    def drain_timed_out(self) -> List[ServeRequest]: ...

    def live_requests(self) -> List[ServeRequest]:
        """Every request currently queued or running (not yet drained).
        Feeds per-token streaming (watermark diffs between steps) and
        adapter-retire quiescence checks."""
        ...

    def pending(self) -> int: ...

    def server_load(self, server_id: int, now: float) -> float: ...

    def queue_depth(self, server_id: int) -> float:
        """Waiting (not-yet-admitted) requests — the controller's
        backlog signal."""
        ...

    def utilization(self, server_id: int, now: float) -> float:
        """Busy fraction (or occupancy proxy) in [0, 1] since the last
        call — gates control-plane drains."""
        ...

    def load_adapters(self, server_id: int,
                      adapter_ranks: Dict[str, int]) -> None: ...

    def load_adapter_remote(self, server_id: int, adapter_id: str,
                            rank: int, peer_server: int) -> None:
        """Make the adapter servable on ``server_id`` by reading its
        weights from ``peer_server``'s copy (GDR remote read) instead of
        loading locally; the copy stays marked remote until promoted."""
        ...

    def promote_adapter(self, server_id: int, adapter_id: str) -> None:
        """Background warm fetch landed: the remote-read copy is now a
        first-class local one."""
        ...

    def evict_adapter(self, server_id: int, adapter_id: str) -> bool: ...

    def hosted_adapters(self, server_id: int) -> Dict[str, int]: ...

    def add_server(self) -> int:
        """Provision one more (empty) server; returns its id. Ids are
        stable — a retired server's id is never reused."""
        ...

    def retire_server(self, server_id: int) -> None:
        """Release a drained server's execution resources. The server
        must have no queued or running work."""
        ...

    def fail_server(self, server_id: int) -> None:
        """Fail-stop: the server freezes mid-flight — queued and
        running requests strand (recoverable via ``drain_failed``), and
        ``step`` never advances it again until restored."""
        ...

    def drain_failed(self, server_id: int) -> List[ServeRequest]:
        """Collect every request stranded on a failed server (queued,
        running, and anything routed to it during the crash-to-detection
        window) and release its execution resources. The requests are
        no longer live; the caller re-dispatches their continuations."""
        ...

    def restore_server(self, server_id: int) -> None:
        """Bring a failed server back, empty (adapters re-load via the
        normal placement path)."""
        ...

    def server_alive(self, server_id: int) -> bool: ...

    def cancel_request(self, req_id: int) -> Optional[ServeRequest]:
        """Abort a live request wherever it sits (queue or batch slot),
        freeing its slot/KV pages. Returns the request, or None if it
        is not live (already finished or unknown)."""
        ...

    def memory_profile(self) -> List[Dict[str, float]]:
        """Per-server {n_adapters, max_rank, adapter_bytes, bank_mode,
        n_remote}."""
        ...


# ----------------------------------------------------------------------
class SimBackend:
    """Discrete-event substrate over ``SimServer`` + ``ServerModel``."""

    realtime = False

    def __init__(self, n_servers: int, server_model=None,
                 timeout: float = 120.0,
                 adapter_nbytes: Optional[Dict[str, int]] = None,
                 bank_mode: str = "padded", decode_block: int = 1,
                 mesh_shape: Optional[tuple] = None):
        from repro.cluster.costmodel import ServerModel
        from repro.cluster.server import SimServer
        self.n_servers = n_servers
        self.bank_mode = bank_mode
        self.decode_block = decode_block
        self.mesh_shape = mesh_shape
        if server_model is None:
            # mesh-sharded servers: tp follows the mesh's "model" extent
            # and iteration times include the explicit ICI terms
            server_model = ServerModel(mesh_shape=mesh_shape,
                                       tp=mesh_shape[-1]) \
                if mesh_shape else ServerModel()
        self.model = server_model
        self.servers = [SimServer(i, self.model, bank_mode=bank_mode,
                                  decode_block=decode_block)
                        for i in range(n_servers)]
        self.timeout = timeout
        self._nbytes = adapter_nbytes or {}
        self._hosted: List[Dict[str, int]] = [{} for _ in range(n_servers)]
        self._remote: List[set] = [set() for _ in range(n_servers)]
        self._inflight: List[ServeRequest] = []
        self._completed: List[ServeRequest] = []
        self._timed_out: List[ServeRequest] = []
        self._util_prev: Dict[int, tuple] = {}
        self.failed: set = set()
        self.tracer = None

    def set_tracer(self, tracer) -> None:
        """Attach an ``obs.Tracer``; servers emit iteration spans on the
        virtual clock (applies to servers added later too)."""
        self.tracer = tracer
        for s in self.servers:
            s.tracer = tracer

    def start(self) -> None:
        pass

    def flush_spans(self) -> None:
        """Emit any staged (coalesced) decode spans — called before a
        report/snapshot reads the tracer, so span totals and drift
        cover every iteration executed so far."""
        for s in self.servers:
            s.flush_spans()

    def submit(self, server_id: int, req: ServeRequest,
               now: float) -> None:
        req.server = server_id
        req.ready = now + req.fetch_latency
        self.servers[server_id].enqueue(req)
        self._inflight.append(req)

    def step(self, now: float) -> None:
        for sid, s in enumerate(self.servers):
            if sid in self.failed:
                continue   # fail-stop: stranded work neither runs
            for r in list(s.waiting):   # nor times out — it recovers
                if now - r.arrival > self.timeout:
                    s.waiting.remove(r)
                    self._inflight.remove(r)
                    self._timed_out.append(r)
            if s.busy_until <= now + 1e-12 and s.has_work(now):
                s.step(now)
            s.finished.clear()   # completions flow via _completed here
        still = []
        for r in self._inflight:
            (self._completed if r.finish >= 0 else still).append(r)
        self._inflight = still

    def next_event_time(self, now: float) -> Optional[float]:
        ts = [t for sid, s in enumerate(self.servers)
              if sid not in self.failed
              for t in (s.next_event_time(now),) if t is not None]
        return min(ts) if ts else None

    def wall_now(self) -> float:
        raise RuntimeError("SimBackend has no wall clock; virtual time "
                           "is driven by the cluster facade")

    def drain_completed(self) -> List[ServeRequest]:
        done, self._completed = self._completed, []
        return done

    def drain_timed_out(self) -> List[ServeRequest]:
        out, self._timed_out = self._timed_out, []
        return out

    def live_requests(self) -> List[ServeRequest]:
        return list(self._inflight)

    def pending(self) -> int:
        return len(self._inflight)

    def server_load(self, server_id: int, now: float) -> float:
        return self.servers[server_id].estimated_work(now)

    def queue_depth(self, server_id: int) -> float:
        return float(len(self.servers[server_id].waiting))

    def utilization(self, server_id: int, now: float) -> float:
        """Busy fraction since the previous call for this server."""
        s = self.servers[server_id]
        t0, b0 = self._util_prev.get(server_id, (0.0, 0.0))
        self._util_prev[server_id] = (now, s.busy_time)
        if now <= t0:
            return 0.0
        return min(1.0, max(0.0, (s.busy_time - b0) / (now - t0)))

    def load_adapters(self, server_id: int,
                      adapter_ranks: Dict[str, int]) -> None:
        self._hosted[server_id].update(adapter_ranks)
        self._remote[server_id] -= set(adapter_ranks)

    def load_adapter_remote(self, server_id: int, adapter_id: str,
                            rank: int, peer_server: int) -> None:
        # virtual substrate: the cost model charges the GDR streaming
        # tax via req.remote_penalty; here we just track residency
        self._hosted[server_id][adapter_id] = rank
        self._remote[server_id].add(adapter_id)

    def promote_adapter(self, server_id: int, adapter_id: str) -> None:
        self._remote[server_id].discard(adapter_id)

    def evict_adapter(self, server_id: int, adapter_id: str) -> bool:
        # refuse while the adapter still has requests on this server
        if any(r.adapter_id == adapter_id and r.server == server_id
               for r in self._inflight):
            return False
        self._remote[server_id].discard(adapter_id)
        return self._hosted[server_id].pop(adapter_id, None) is not None

    def hosted_adapters(self, server_id: int) -> Dict[str, int]:
        return dict(self._hosted[server_id])

    def add_server(self) -> int:
        from repro.cluster.server import SimServer
        sid = self.n_servers
        self.n_servers += 1
        self.servers.append(SimServer(sid, self.model,
                                      bank_mode=self.bank_mode,
                                      decode_block=self.decode_block,
                                      tracer=self.tracer))
        self._hosted.append({})
        self._remote.append(set())
        return sid

    def retire_server(self, server_id: int) -> None:
        s = self.servers[server_id]
        if s.waiting or s.running:
            raise RuntimeError(f"retire of sim server {server_id} with "
                               f"work still queued")
        self._hosted[server_id].clear()
        self._remote[server_id].clear()

    # -- fault plane ----------------------------------------------------
    def fail_server(self, server_id: int) -> None:
        self.failed.add(server_id)

    def drain_failed(self, server_id: int) -> List[ServeRequest]:
        s = self.servers[server_id]
        stranded = list(s.waiting) + list(s.running)
        s.waiting.clear()
        s.running.clear()
        s.finished.clear()
        s.busy_until = 0.0
        gone = {id(r) for r in stranded}
        self._inflight = [r for r in self._inflight
                          if id(r) not in gone]
        self._hosted[server_id].clear()
        self._remote[server_id].clear()
        return stranded

    def restore_server(self, server_id: int) -> None:
        self.failed.discard(server_id)
        self._util_prev.pop(server_id, None)

    def server_alive(self, server_id: int) -> bool:
        return server_id not in self.failed

    def cancel_request(self, req_id: int) -> Optional[ServeRequest]:
        for r in self._inflight:
            if r.req_id == req_id:
                s = self.servers[r.server]
                s.waiting[:] = [q for q in s.waiting if q is not r]
                s.running[:] = [q for q in s.running if q is not r]
                self._inflight = [q for q in self._inflight
                                  if q is not r]
                return r
        return None

    def memory_profile(self) -> List[Dict[str, float]]:
        out = []
        for sid, hosted in enumerate(self._hosted):
            out.append({
                "n_adapters": len(hosted),
                "max_rank": max(hosted.values()) if hosted else 0,
                "adapter_bytes": sum(self._nbytes.get(a, 0)
                                     for a in hosted),
                "bank_mode": self.bank_mode,
                "n_remote": len(self._remote[sid]),
            })
        return out


# ----------------------------------------------------------------------
class EngineBackend:
    """Real-JAX substrate: one placement-aware ``ServingEngine`` per
    server, created lazily with the adapter subset first loaded onto it.

    The shared clock is wall-clock seconds since ``start()``; request
    arrivals are interpreted in that same relative domain. Simulated
    adapter-fetch latency from the pool is recorded on the request (it
    cannot be injected into real execution time).
    """

    realtime = True

    def __init__(self, cfg, params, n_servers: int, *,
                 max_batch: int = 4, max_len: int = 64, seed: int = 0,
                 timeout: float = 120.0, page_pool_factory=None,
                 bank_mode: str = "padded", decode_block: int = 1,
                 lora_kernel: str = "einsum",
                 mesh_shape: Optional[tuple] = None):
        from .engine import ServingEngine
        self._engine_cls = ServingEngine
        self.cfg = cfg
        self.params = params
        self.n_servers = n_servers
        self.bank_mode = bank_mode
        self.decode_block = decode_block
        self.lora_kernel = lora_kernel
        # mesh-sharded engines: every server's engine runs over its own
        # (dp, tp) mesh built from the process's devices. None keeps the
        # single-device engines unchanged.
        self.mesh_shape = mesh_shape
        self._mesh = None
        if mesh_shape is not None:
            from repro.launch.mesh import make_engine_mesh
            self._mesh = make_engine_mesh(*mesh_shape)
        self.max_batch = max_batch
        self.max_len = max_len
        self.seed = seed
        self.timeout = timeout
        self._page_pool_factory = page_pool_factory
        self.engines: List[Optional[object]] = [None] * n_servers
        self._remote: List[set] = [set() for _ in range(n_servers)]
        self._t0 = time.monotonic()
        self._timed_out: List[ServeRequest] = []
        self.failed: set = set()
        self.tracer = None

    def set_tracer(self, tracer) -> None:
        """Attach an ``obs.Tracer``; engines (built lazily) emit
        iteration spans on the shared wall clock."""
        self.tracer = tracer
        for eng in self.engines:
            if eng is not None:
                eng.tracer = tracer

    # -- clock ----------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.monotonic()

    def wall_now(self) -> float:
        return time.monotonic() - self._t0

    def next_event_time(self, now: float) -> Optional[float]:
        return None

    # -- request path ---------------------------------------------------
    def submit(self, server_id: int, req: ServeRequest,
               now: float) -> None:
        eng = self.engines[server_id]
        if eng is None:
            raise RuntimeError(f"server {server_id} has no adapters "
                               f"loaded; call load_adapters first")
        req.server = server_id
        req.ready = now + req.fetch_latency
        if req.prompt is None:
            # length-only (simulator-style) request: synthesize a
            # deterministic prompt so sim traces replay on real engines
            rng = random.Random(req.req_id)
            plen = max(1, min(req.prompt_len,
                              self.max_len - req.output_len - 1))
            req.prompt = [rng.randrange(1, self.cfg.vocab_size)
                          for _ in range(plen)]
        eng.submit(req)

    def step(self, now: float) -> None:
        for sid, eng in enumerate(self.engines):
            if eng is None or sid in self.failed:
                continue   # fail-stop: stranded work freezes until
            # recovery; drop queued (not-yet-admitted) requests past
            # the timeout, mirroring SimBackend's waiting-queue drops
            for r in list(eng.queue):
                if now - r.arrival > self.timeout:
                    eng.queue.remove(r)
                    self._timed_out.append(r)
            if eng.queue or eng.active:
                eng.step()

    def drain_completed(self) -> List[ServeRequest]:
        out: List[ServeRequest] = []
        for sid, eng in enumerate(self.engines):
            if eng is not None and sid not in self.failed:
                out.extend(eng.drain_completed())
        return out

    def drain_timed_out(self) -> List[ServeRequest]:
        out, self._timed_out = self._timed_out, []
        return out

    def live_requests(self) -> List[ServeRequest]:
        out: List[ServeRequest] = []
        for eng in self.engines:
            if eng is None:
                continue
            out.extend(eng.queue)
            out.extend(r for r in eng.slots if r is not None)
        return out

    def pending(self) -> int:
        return sum(len(e.queue) + e.active
                   for e in self.engines if e is not None)

    def server_load(self, server_id: int, now: float) -> float:
        eng = self.engines[server_id]
        return 0.0 if eng is None else float(len(eng.queue) + eng.active)

    def queue_depth(self, server_id: int) -> float:
        eng = self.engines[server_id]
        return 0.0 if eng is None else float(len(eng.queue))

    def utilization(self, server_id: int, now: float) -> float:
        """Instantaneous batch occupancy — the closest cheap proxy for
        busy fraction on a real engine."""
        eng = self.engines[server_id]
        if eng is None:
            return 0.0
        return min(1.0, eng.active / max(1, self.max_batch))

    # -- placement path -------------------------------------------------
    def load_adapters(self, server_id: int,
                      adapter_ranks: Dict[str, int]) -> None:
        if not adapter_ranks:
            return
        if self.engines[server_id] is None:
            pool = (self._page_pool_factory()
                    if self._page_pool_factory else None)
            self.engines[server_id] = self._engine_cls(
                self.cfg, self.params, dict(adapter_ranks),
                max_batch=self.max_batch, max_len=self.max_len,
                seed=self.seed, bank_mode=self.bank_mode,
                decode_block=self.decode_block,
                lora_kernel=self.lora_kernel, mesh=self._mesh,
                page_pool=pool, clock=self.wall_now,
                tracer=self.tracer, server_id=server_id)
        else:
            self.engines[server_id].load_adapters(adapter_ranks)

    def load_adapter_remote(self, server_id: int, adapter_id: str,
                            rank: int, peer_server: int) -> None:
        """GDR remote read on the real substrate: the adapter's weights
        are pulled out of the *peer engine's* bank and installed into
        this server's bank without local materialization. Falls back to
        a local load when the peer copy is unavailable."""
        weights = None
        if 0 <= peer_server < self.n_servers:
            peer = self.engines[peer_server]
            if peer is not None and adapter_id in peer.adapter_ranks:
                weights = peer.adapter_weights(adapter_id)
        eng = self.engines[server_id]
        if eng is None:
            self.load_adapters(server_id, {adapter_id: rank})
            eng = self.engines[server_id]
            if weights is not None:
                eng.install_adapter(adapter_id, rank, weights)
        else:
            eng.install_adapter(adapter_id, rank, weights)
        if weights is not None:
            self._remote[server_id].add(adapter_id)

    def promote_adapter(self, server_id: int, adapter_id: str) -> None:
        self._remote[server_id].discard(adapter_id)

    def evict_adapter(self, server_id: int, adapter_id: str) -> bool:
        eng = self.engines[server_id]
        if eng is None:
            return False
        if eng.evict_adapter(adapter_id):
            self._remote[server_id].discard(adapter_id)
            return True
        return False

    def hosted_adapters(self, server_id: int) -> Dict[str, int]:
        eng = self.engines[server_id]
        return {} if eng is None else dict(eng.adapter_ranks)

    def add_server(self) -> int:
        sid = self.n_servers
        self.n_servers += 1
        self.engines.append(None)   # engine builds lazily on first load
        self._remote.append(set())
        return sid

    def retire_server(self, server_id: int) -> None:
        eng = self.engines[server_id]
        if eng is not None and (eng.queue or eng.active):
            raise RuntimeError(f"retire of engine {server_id} with "
                               f"work still queued")
        self.engines[server_id] = None   # frees the bank
        self._remote[server_id].clear()

    # -- fault plane ----------------------------------------------------
    def fail_server(self, server_id: int) -> None:
        self.failed.add(server_id)

    def drain_failed(self, server_id: int) -> List[ServeRequest]:
        eng = self.engines[server_id]
        if eng is None:
            return []
        stranded = list(eng.queue) + [r for r in eng.slots
                                      if r is not None]
        # a crashed engine's bank, KV cache, and queue all die with it
        self.engines[server_id] = None
        self._remote[server_id].clear()
        return stranded

    def restore_server(self, server_id: int) -> None:
        self.failed.discard(server_id)   # engine rebuilds on next load

    def server_alive(self, server_id: int) -> bool:
        return server_id not in self.failed

    def cancel_request(self, req_id: int) -> Optional[ServeRequest]:
        for eng in self.engines:
            if eng is None:
                continue
            r = eng.cancel(req_id)
            if r is not None:
                return r
        return None

    def memory_profile(self) -> List[Dict[str, float]]:
        from repro.lora.adapter import bank_nbytes
        out = []
        for sid, eng in enumerate(self.engines):
            if eng is None:
                out.append({"n_adapters": 0, "max_rank": 0,
                            "adapter_bytes": 0,
                            "bank_mode": self.bank_mode,
                            "n_remote": 0})
            else:
                out.append({"n_adapters": len(eng.adapter_ids),
                            "max_rank": eng.max_rank,
                            "adapter_bytes": bank_nbytes(eng.bank),
                            "bank_mode": eng.bank_mode,
                            "n_remote": len(self._remote[sid])})
        return out
