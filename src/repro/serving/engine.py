"""Single-server JAX serving engine: slot-based continuous batching with
heterogeneous LoRA adapters applied through the batched bank (the real
compute path — co-batched requests genuinely pay the bank's max rank, so
the paper's interference is physically measurable here, not just modeled).

Prefill runs per-request (B=1, exact length — no padding pollution for
SSM state); decode runs one jitted step for the whole slot batch. Each
slot row carries its own cache position; free slots drop their writes
(out-of-bounds scatter semantics).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.lora.adapter import init_bank
from repro.models import model as M

from .metrics import MetricsCollector
from .paging import UnifiedPagePool
from .request import Phase, Request


class ServingEngine:
    def __init__(self, cfg, params, adapter_ranks: Dict[str, int],
                 *, max_batch: int = 8, max_len: int = 512,
                 seed: int = 0, scaling: float = 1.0,
                 page_pool: Optional[UnifiedPagePool] = None):
        self.cfg = cfg
        self.page_pool = page_pool
        self.params = params
        self.adapter_ids = sorted(adapter_ranks)
        self.ranks = [adapter_ranks[a] for a in self.adapter_ids]
        self.max_rank = max(self.ranks)          # bank padding = max rank
        self.max_batch = max_batch
        self.max_len = max_len
        n_layers = 1 if cfg.family == "hybrid" else cfg.n_layers
        self.bank = init_bank(cfg, self.ranks, jax.random.PRNGKey(seed),
                              n_layers=n_layers)
        enc_len = (cfg.encoder.n_frames if cfg.encoder
                   else (cfg.n_frontend_tokens or None))
        self.cache = M.init_cache(cfg, max_batch, max_len,
                                  jnp.float32, enc_len=enc_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_adapter = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.metrics = MetricsCollector()
        self.queue: List[Request] = []
        self._iter = 0

        cfgc = cfg

        def _decode(params, cache, tokens, bank, idx):
            return M.decode_step(cfgc, params, cache, tokens, bank=bank,
                                 lora_idx=idx)

        self._decode = jax.jit(_decode, donate_argnums=(1,))

        def _merge(cache, cache1, slot, pos):
            out = {}
            for k, v in cache.items():
                if k == "pos":
                    out[k] = v.at[slot].set(pos)
                else:
                    out[k] = jax.lax.dynamic_update_index_in_dim(
                        v, cache1[k][:, 0].astype(v.dtype), slot, axis=1)
            return out

        self._merge = jax.jit(_merge, donate_argnums=(0,))
        self._prefill_cache = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _adapter_index(self, adapter_id: str) -> int:
        return self.adapter_ids.index(adapter_id)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            cfg = self.cfg

            def _prefill(params, tokens, bank, idx, frontend=None):
                return M.prefill(cfg, params, tokens, frontend=frontend,
                                 bank=bank, lora_idx=idx,
                                 cache_len=self.max_len,
                                 cache_dtype=jnp.float32)

            self._prefill_cache[length] = jax.jit(_prefill)
        return self._prefill_cache[length]

    def _admit(self, now: float) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            aidx = self._adapter_index(req.adapter_id)
            if self.page_pool is not None:
                # unified paging: KV pages for the sequence + the
                # adapter's pages (paged in on first use, pinned while
                # co-batched)
                self.page_pool.alloc_kv(f"req{req.req_id}",
                                        len(req.prompt))
                self.page_pool.ensure_adapter(
                    req.adapter_id,
                    self.ranks[aidx] * 4 * 2 * self.cfg.d_model *
                    (1 if self.cfg.family == "hybrid"
                     else self.cfg.n_layers))
                self.page_pool.pin_adapter(req.adapter_id)
            toks = jnp.asarray([req.prompt], jnp.int32)
            frontend = None
            if self.cfg.family == "vlm":
                frontend = jnp.zeros(
                    (1, self.cfg.n_frontend_tokens, self.cfg.d_model))
            if self.cfg.family == "audio":
                frontend = jnp.zeros(
                    (1, self.cfg.encoder.n_frames, self.cfg.d_model))
            fn = self._prefill_fn(len(req.prompt))
            if frontend is not None:
                logits, cache1 = fn(self.params, toks, self.bank,
                                    jnp.asarray([aidx], jnp.int32),
                                    frontend)
            else:
                logits, cache1 = fn(self.params, toks, self.bank,
                                    jnp.asarray([aidx], jnp.int32))
            first = int(jnp.argmax(logits[0]))
            self.cache = self._merge(self.cache, cache1, slot,
                                     len(req.prompt))
            self.slot_adapter = self.slot_adapter.at[slot].set(aidx)
            self.last_token = self.last_token.at[slot].set(first)
            req.phase = Phase.DECODE
            req.slot = slot
            req.output.append(first)
            req.t_first_token = time.monotonic()
            self.slots[slot] = req

    def _decode_once(self) -> None:
        if not any(s is not None for s in self.slots):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_token, self.bank,
            self.slot_adapter)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_token = nxt
        now = time.monotonic()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            req.output.append(int(nxt[slot]))
            if self.page_pool is not None:
                self.page_pool.grow_kv(f"req{req.req_id}",
                                       len(req.prompt) + len(req.output))
            done = len(req.output) >= req.max_new_tokens
            if done or len(req.prompt) + len(req.output) >= self.max_len:
                req.phase = Phase.DONE
                req.t_finish = now
                self.metrics.record(req)
                self.slots[slot] = None
                if self.page_pool is not None:
                    self.page_pool.free_kv(f"req{req.req_id}")
                    if not any(r is not None and
                               r.adapter_id == req.adapter_id
                               for r in self.slots):
                        self.page_pool.pin_adapter(req.adapter_id, False)
        self._iter += 1

    def step(self) -> None:
        """One engine iteration: admit then decode (prefill-prioritized)."""
        self._admit(time.monotonic())
        self._decode_once()

    def run_until_drained(self, max_iters: int = 100_000) -> dict:
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.metrics.summary()

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)
