"""Single-server JAX serving engine: slot-based continuous batching with
heterogeneous LoRA adapters applied through the batched bank (the real
compute path — co-batched requests genuinely pay the bank's max rank, so
the paper's interference is physically measurable here, not just modeled).

Prefill runs per-request (B=1, exact length — no padding pollution for
SSM state); decode runs one jitted step for the whole slot batch. Each
slot row carries its own cache position; free slots drop their writes
(out-of-bounds scatter semantics).

The engine is *placement-aware*: its bank holds only the adapters the
orchestrator placed (or fetched) onto this server, padded to that
subset's max rank — not the global one. ``load_adapters`` /
``evict_adapter`` rebuild the bank mid-flight, remapping the adapter
indices of co-batched slots, so a cluster rebalance can reshape a
server's bank while requests are decoding.

``bank_mode`` selects the bank layout (``repro.lora.bank.LoRABank``):
``"padded"`` (default, max-rank padding — the paper-faithful baseline)
or ``"bucketed"`` (power-of-two rank buckets, each at its own rank).
Both produce token-identical outputs; they differ only in compute cost,
which makes padded-vs-bucketed A/Bs meaningful on this real engine.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.lora.adapter import Adapter
from repro.lora.bank import build_bank
from repro.models import model as M

from .metrics import MetricsCollector
from .paging import UnifiedPagePool
from .request import Phase, ServeRequest

Request = ServeRequest


class ServingEngine:
    def __init__(self, cfg, params, adapter_ranks: Dict[str, int],
                 *, max_batch: int = 8, max_len: int = 512,
                 seed: int = 0, scaling: float = 1.0,
                 bank_mode: str = "padded",
                 page_pool: Optional[UnifiedPagePool] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.bank_mode = bank_mode
        self.page_pool = page_pool
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._clock = clock
        self._bank_key = jax.random.PRNGKey(seed)
        self.slots: List[Optional[ServeRequest]] = [None] * max_batch
        self.slot_adapter = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.metrics = MetricsCollector()
        self.queue: List[ServeRequest] = []
        self.completed: List[ServeRequest] = []
        self._iter = 0
        self.bank_rebuilds = 0

        self.adapter_ranks: Dict[str, int] = {}
        self._rebuild_bank(dict(adapter_ranks))
        self.bank_rebuilds = 0          # the initial build doesn't count

        enc_len = (cfg.encoder.n_frames if cfg.encoder
                   else (cfg.n_frontend_tokens or None))
        self.cache = M.init_cache(cfg, max_batch, max_len,
                                  jnp.float32, enc_len=enc_len)

        cfgc = cfg

        def _decode(params, cache, tokens, bank, idx):
            return M.decode_step(cfgc, params, cache, tokens, bank=bank,
                                 lora_idx=idx)

        self._decode = jax.jit(_decode, donate_argnums=(1,))

        def _merge(cache, cache1, slot, pos):
            out = {}
            for k, v in cache.items():
                if k == "pos":
                    out[k] = v.at[slot].set(pos)
                else:
                    out[k] = jax.lax.dynamic_update_index_in_dim(
                        v, cache1[k][:, 0].astype(v.dtype), slot, axis=1)
            return out

        self._merge = jax.jit(_merge, donate_argnums=(0,))
        self._prefill_cache = {}

    # -- placement-aware bank management --------------------------------
    def _rebuild_bank(self, adapter_ranks: Dict[str, int]) -> None:
        self.adapter_ranks = adapter_ranks
        n_layers = 1 if self.cfg.family == "hybrid" else self.cfg.n_layers
        self.lora_bank = build_bank(self.cfg, adapter_ranks, self._bank_key,
                                    mode=self.bank_mode, n_layers=n_layers)
        self.adapter_ids = list(self.lora_bank.adapter_ids)
        self.ranks = list(self.lora_bank.ranks)
        self.max_rank = self.lora_bank.max_rank  # padding = subset max
        self.bank = self.lora_bank.data
        self.bank_rebuilds += 1
        # remap adapter indices of co-batched slots to the new bank layout
        idx = [self.adapter_ids.index(r.adapter_id) if r is not None else 0
               for r in self.slots]
        self.slot_adapter = jnp.asarray(idx, jnp.int32)
        self._slot_lora = self.lora_bank.lora_idx(self.slot_adapter)

    def load_adapters(self, adapter_ranks: Dict[str, int]) -> bool:
        """Add adapters to this server's bank (placement update or pool
        fetch). Returns True if the bank was rebuilt."""
        new = {aid: r for aid, r in adapter_ranks.items()
               if aid not in self.adapter_ranks}
        if not new:
            return False
        self._rebuild_bank({**self.adapter_ranks, **new})
        return True

    # -- GDR remote-read data plane --------------------------------------
    def adapter_weights(self, adapter_id: str):
        """Serve one adapter's unpadded weights to a peer (what a GDR
        remote read against this server's bank returns)."""
        return self.lora_bank.get_adapter(adapter_id)

    def install_adapter(self, adapter_id: str, rank: int,
                        weights=None) -> bool:
        """Make ``adapter_id`` servable using weights read from a peer's
        bank instead of (re)materializing them locally: the bank is
        reshaped to make room, then the adapter's rows are overwritten
        with the peer bytes. With ``weights=None`` this degrades to a
        plain ``load_adapters`` (local materialization). Returns True if
        the bank was rebuilt."""
        added = self.load_adapters({adapter_id: rank})
        if weights is not None:
            self.lora_bank = self.lora_bank.set_adapter(adapter_id,
                                                        weights)
            self.bank = self.lora_bank.data
        return added

    def evict_adapter(self, adapter_id: str) -> bool:
        """Drop an adapter from the bank. Refuses (returns False) while
        the adapter still has queued or co-batched requests, or if it is
        the server's last adapter."""
        if adapter_id not in self.adapter_ranks:
            return False
        if len(self.adapter_ranks) == 1:
            return False
        if any(r is not None and r.adapter_id == adapter_id
               for r in self.slots):
            return False
        if any(q.adapter_id == adapter_id for q in self.queue):
            return False
        self._rebuild_bank({aid: r for aid, r in self.adapter_ranks.items()
                            if aid != adapter_id})
        return True

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        if req.adapter_id not in self.adapter_ranks:
            raise KeyError(f"adapter {req.adapter_id!r} is not loaded on "
                           f"this server (hosted: {self.adapter_ids})")
        self.queue.append(req)

    def _adapter_index(self, adapter_id: str) -> int:
        return self.adapter_ids.index(adapter_id)

    def _prefill_fn(self, length: int):
        # keyed by (prompt length, bank layout signature): bank reshapes
        # after a rebalance retrigger tracing for that shape only; the
        # bucketed signature is the tuple of (bucket rank, count) pairs
        key = (length,) + self.lora_bank.signature
        if key not in self._prefill_cache:
            cfg = self.cfg

            def _prefill(params, tokens, bank, idx, frontend=None):
                return M.prefill(cfg, params, tokens, frontend=frontend,
                                 bank=bank, lora_idx=idx,
                                 cache_len=self.max_len,
                                 cache_dtype=jnp.float32)

            self._prefill_cache[key] = jax.jit(_prefill)
        return self._prefill_cache[key]

    def _admit(self, now: float) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            aidx = self._adapter_index(req.adapter_id)
            if self.page_pool is not None:
                # unified paging: KV pages for the sequence + the
                # adapter's pages (paged in on first use, pinned while
                # co-batched)
                self.page_pool.alloc_kv(f"req{req.req_id}",
                                        len(req.prompt))
                # footprint from the same formula the cluster/placement
                # accounting uses, not an ad-hoc per-target guess; hybrid
                # banks hold a single shared-attn LoRA layer, so the
                # per-layer share is what this server actually pages in
                nbytes = Adapter(req.adapter_id,
                                 self.ranks[aidx]).nbytes(self.cfg)
                if self.cfg.family == "hybrid":
                    nbytes = max(1, nbytes // self.cfg.n_layers)
                self.page_pool.ensure_adapter(req.adapter_id, nbytes)
                self.page_pool.pin_adapter(req.adapter_id)
            toks = jnp.asarray([req.prompt], jnp.int32)
            frontend = None
            if self.cfg.family == "vlm":
                frontend = jnp.zeros(
                    (1, self.cfg.n_frontend_tokens, self.cfg.d_model))
            if self.cfg.family == "audio":
                frontend = jnp.zeros(
                    (1, self.cfg.encoder.n_frames, self.cfg.d_model))
            fn = self._prefill_fn(len(req.prompt))
            lidx = self.lora_bank.lora_idx(jnp.asarray([aidx], jnp.int32))
            if frontend is not None:
                logits, cache1 = fn(self.params, toks, self.bank, lidx,
                                    frontend)
            else:
                logits, cache1 = fn(self.params, toks, self.bank, lidx)
            first = int(jnp.argmax(logits[0]))
            self.cache = self._merge(self.cache, cache1, slot,
                                     len(req.prompt))
            self.slot_adapter = self.slot_adapter.at[slot].set(aidx)
            self._slot_lora = self.lora_bank.lora_idx(self.slot_adapter)
            self.last_token = self.last_token.at[slot].set(first)
            req.phase = Phase.DECODE
            req.slot = slot
            req.output.append(first)
            t = self._clock()
            req.t_first_token = t
            req.prefill_done = t
            self.slots[slot] = req

    def _decode_once(self) -> None:
        if not any(s is not None for s in self.slots):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_token, self.bank,
            self._slot_lora)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_token = nxt
        now = self._clock()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            req.output.append(int(nxt[slot]))
            if self.page_pool is not None:
                self.page_pool.grow_kv(f"req{req.req_id}",
                                       len(req.prompt) + len(req.output))
            done = len(req.output) >= req.max_new_tokens
            if done or len(req.prompt) + len(req.output) >= self.max_len:
                req.phase = Phase.DONE
                req.t_finish = now
                req.finish = now
                self.metrics.record(req)
                self.completed.append(req)
                self.slots[slot] = None
                if self.page_pool is not None:
                    self.page_pool.free_kv(f"req{req.req_id}")
                    if not any(r is not None and
                               r.adapter_id == req.adapter_id
                               for r in self.slots):
                        self.page_pool.pin_adapter(req.adapter_id, False)
        self._iter += 1

    def step(self) -> None:
        """One engine iteration: admit then decode (prefill-prioritized)."""
        self._admit(self._clock())
        self._decode_once()

    def drain_completed(self) -> List[ServeRequest]:
        done, self.completed = self.completed, []
        return done

    def run_until_drained(self, max_iters: int = 100_000) -> dict:
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.metrics.summary()

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)
