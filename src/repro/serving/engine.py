"""Single-server JAX serving engine: slot-based continuous batching with
heterogeneous LoRA adapters applied through the batched bank (the real
compute path — co-batched requests genuinely pay the bank's max rank, so
the paper's interference is physically measurable here, not just modeled).

Prefill admission is batched: queued prompts of the SAME length are
packed into one prefill call (exact length — no padding pollution for
SSM state) and their cache rows scattered into slots in one fused merge.
Decode runs one jitted step for the whole slot batch; ``decode_steps(k)``
fuses k of them into a single host dispatch (``jax.lax.scan`` over the
decode step with on-device argmax and per-slot remaining-token
bookkeeping, cache donated through the scan), so decode costs one host
round-trip per k tokens instead of per token. Each slot row carries its
own cache position; free slots drop their writes (out-of-bounds scatter
semantics).

The engine is *placement-aware*: its bank holds only the adapters the
orchestrator placed (or fetched) onto this server, padded to that
subset's max rank — not the global one. ``load_adapters`` /
``evict_adapter`` rebuild the bank mid-flight, remapping the adapter
indices of co-batched slots, so a cluster rebalance can reshape a
server's bank while requests are decoding.

``bank_mode`` selects the bank layout (``repro.lora.bank.LoRABank``):
``"padded"`` (default, max-rank padding — the paper-faithful baseline)
or ``"bucketed"`` (power-of-two rank buckets, each at its own rank).
Both produce token-identical outputs; they differ only in compute cost,
which makes padded-vs-bucketed A/Bs meaningful on this real engine.

``mesh`` (a ("data", "model") Mesh, e.g. ``launch.mesh.make_engine_mesh``)
turns on the mesh-sharded serving mode: base weights, activations, and
the KV cache shard over the mesh, LoRA banks co-shard along
d_model/d_out so the SGMV kernels run per-shard with a single rank-r
psum (``serving.sharding``), and every jitted call traces under the
mesh + axis env. Token streams are identical to the single-device
engine — sharding changes placement and collectives, not numerics
(argmax decoding absorbs the psum reassociation rounding).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.lora.adapter import Adapter
from repro.lora.bank import build_bank
from repro.models import model as M

from .metrics import MetricsCollector
from .paging import UnifiedPagePool
from .request import Phase, ServeRequest

Request = ServeRequest


class ServingEngine:
    def __init__(self, cfg, params, adapter_ranks: Dict[str, int],
                 *, max_batch: int = 8, max_len: int = 512,
                 seed: int = 0, scaling: float = 1.0,
                 bank_mode: str = "padded", decode_block: int = 1,
                 lora_kernel: str = "einsum", mesh=None,
                 page_pool: Optional[UnifiedPagePool] = None,
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None, server_id: int = 0):
        from .sharding import make_engine_sharding
        self.cfg = cfg
        # obs.Tracer: per-iteration spans (prefill groups, decode
        # dispatches) stamped on the engine clock, carrying the batch
        # shape so the drift meter can price them with ServerModel
        self.tracer = tracer
        self._track = f"server:{server_id}"
        self.bank_mode = bank_mode
        self.decode_block = decode_block
        self.lora_kernel = lora_kernel
        self.page_pool = page_pool
        # mesh-sharded mode: a ("data","model") Mesh shards base
        # weights, KV cache, activations, and (co-sharded) LoRA banks;
        # None keeps the legacy single-device engine byte-for-byte
        self.sharding = make_engine_sharding(mesh, cfg, max_batch)
        if self.sharding is not None:
            params = self.sharding.shard_params(params)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._clock = clock
        self._bank_key = jax.random.PRNGKey(seed)
        self.slots: List[Optional[ServeRequest]] = [None] * max_batch
        self.slot_adapter = jnp.zeros((max_batch,), jnp.int32)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.metrics = MetricsCollector()
        self.queue: List[ServeRequest] = []
        self.completed: List[ServeRequest] = []
        self._iter = 0
        self.bank_rebuilds = 0
        # host-dispatch telemetry (bench_kernels: dispatches per token)
        self.decode_dispatches = 0
        self.prefill_dispatches = 0
        self.tokens_decoded = 0

        self.adapter_ranks: Dict[str, int] = {}
        self._rebuild_bank(dict(adapter_ranks))
        self.bank_rebuilds = 0          # the initial build doesn't count

        enc_len = (cfg.encoder.n_frames if cfg.encoder
                   else (cfg.n_frontend_tokens or None))
        self.cache = M.init_cache(cfg, max_batch, max_len,
                                  jnp.float32, enc_len=enc_len)
        if self.sharding is not None:
            self.cache = self.sharding.shard_cache(self.cache)

        cfgc = cfg
        kern = lora_kernel

        def _decode(params, cache, tokens, bank, idx):
            return M.decode_step(cfgc, params, cache, tokens, bank=bank,
                                 lora_idx=idx, lora_kernel=kern)

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._decode_k_cache = {}

        def _merge_many(cache, cache1, slots, pos):
            # scatter n freshly-prefilled rows (batch axis 1 everywhere
            # but "pos") into their slots in one fused update
            out = {}
            for k, v in cache.items():
                if k == "pos":
                    out[k] = v.at[slots].set(pos)
                else:
                    out[k] = v.at[:, slots].set(cache1[k].astype(v.dtype))
            return out

        self._merge_many = jax.jit(_merge_many, donate_argnums=(0,))
        self._prefill_cache = {}

    def _ctx(self):
        """Mesh + axis-env context every jitted call runs under (tracing
        picks up the sharding constraints); a no-op when unsharded."""
        import contextlib
        if self.sharding is None:
            return contextlib.nullcontext()
        return self.sharding.ctx()

    # -- placement-aware bank management --------------------------------
    def _rebuild_bank(self, adapter_ranks: Dict[str, int]) -> None:
        self.adapter_ranks = adapter_ranks
        n_layers = 1 if self.cfg.family == "hybrid" else self.cfg.n_layers
        self.lora_bank = build_bank(self.cfg, adapter_ranks, self._bank_key,
                                    mode=self.bank_mode, n_layers=n_layers)
        if self.sharding is not None:
            # re-apply the co-sharded layout on every rebuild: placement
            # changes (install/evict/rebalance) reshape the bank but must
            # not silently de-shard it
            import dataclasses
            self.lora_bank = dataclasses.replace(
                self.lora_bank,
                data=self.sharding.shard_bank(self.lora_bank.data))
        self.adapter_ids = list(self.lora_bank.adapter_ids)
        # O(1) id -> bank-row lookups on the admit path (rebuilt here, the
        # only place the layout changes)
        self._adapter_idx = {aid: i
                             for i, aid in enumerate(self.adapter_ids)}
        self.ranks = list(self.lora_bank.ranks)
        self.max_rank = self.lora_bank.max_rank  # padding = subset max
        self.bank = self.lora_bank.data
        self.bank_rebuilds += 1
        # remap adapter indices of co-batched slots to the new bank layout
        idx = [self._adapter_idx[r.adapter_id] if r is not None else 0
               for r in self.slots]
        self.slot_adapter = jnp.asarray(idx, jnp.int32)
        self._slot_lora = self.lora_bank.lora_idx(self.slot_adapter)

    def load_adapters(self, adapter_ranks: Dict[str, int]) -> bool:
        """Add adapters to this server's bank (placement update or pool
        fetch). Returns True if the bank was rebuilt."""
        new = {aid: r for aid, r in adapter_ranks.items()
               if aid not in self.adapter_ranks}
        if not new:
            return False
        self._rebuild_bank({**self.adapter_ranks, **new})
        return True

    # -- GDR remote-read data plane --------------------------------------
    def adapter_weights(self, adapter_id: str):
        """Serve one adapter's unpadded weights to a peer (what a GDR
        remote read against this server's bank returns)."""
        return self.lora_bank.get_adapter(adapter_id)

    def install_adapter(self, adapter_id: str, rank: int,
                        weights=None) -> bool:
        """Make ``adapter_id`` servable using weights read from a peer's
        bank instead of (re)materializing them locally: the bank is
        reshaped to make room, then the adapter's rows are overwritten
        with the peer bytes. With ``weights=None`` this degrades to a
        plain ``load_adapters`` (local materialization). Returns True if
        the bank was rebuilt."""
        added = self.load_adapters({adapter_id: rank})
        if weights is not None:
            self.lora_bank = self.lora_bank.set_adapter(adapter_id,
                                                        weights)
            if self.sharding is not None:
                # scatter of the peer rows de-constrains the layout;
                # re-pin the co-sharded placement
                import dataclasses
                self.lora_bank = dataclasses.replace(
                    self.lora_bank,
                    data=self.sharding.shard_bank(self.lora_bank.data))
            self.bank = self.lora_bank.data
        return added

    def evict_adapter(self, adapter_id: str) -> bool:
        """Drop an adapter from the bank. Refuses (returns False) while
        the adapter still has queued or co-batched requests, or if it is
        the server's last adapter."""
        if adapter_id not in self.adapter_ranks:
            return False
        if len(self.adapter_ranks) == 1:
            return False
        if any(r is not None and r.adapter_id == adapter_id
               for r in self.slots):
            return False
        if any(q.adapter_id == adapter_id for q in self.queue):
            return False
        self._rebuild_bank({aid: r for aid, r in self.adapter_ranks.items()
                            if aid != adapter_id})
        return True

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        if req.adapter_id not in self.adapter_ranks:
            raise KeyError(f"adapter {req.adapter_id!r} is not loaded on "
                           f"this server (hosted: {self.adapter_ids})")
        self.queue.append(req)

    def _adapter_index(self, adapter_id: str) -> int:
        return self._adapter_idx[adapter_id]

    def _prefill_fn(self, length: int):
        # keyed by (prompt length, bank layout signature): bank reshapes
        # after a rebalance retrigger tracing for that shape only; the
        # bucketed signature is the tuple of (bucket rank, count) pairs
        key = (length,) + self.lora_bank.signature
        if key not in self._prefill_cache:
            cfg = self.cfg
            kern = self.lora_kernel

            def _prefill(params, tokens, bank, idx, frontend=None):
                return M.prefill(cfg, params, tokens, frontend=frontend,
                                 bank=bank, lora_idx=idx,
                                 cache_len=self.max_len,
                                 cache_dtype=jnp.float32,
                                 lora_kernel=kern)

            self._prefill_cache[key] = jax.jit(_prefill)
        return self._prefill_cache[key]

    def _admit(self, now: float) -> None:
        free = [s for s in range(self.max_batch) if self.slots[s] is None]
        if not free or not self.queue:
            return
        take = self.queue[:len(free)]
        del self.queue[:len(take)]
        # batched prefill admission: FIFO-assign slots, then pack the
        # admitted prompts into same-length groups — one prefill call
        # per group (B = group size, exact length: no padding pollution
        # for SSM state) instead of B=1 each
        groups: Dict[int, list] = {}
        for req in take:
            slot = free.pop(0)
            groups.setdefault(len(req.prompt), []).append((slot, req))
        for length, grp in groups.items():
            self._prefill_group(length, grp)
        # slot -> (bucket, local) bank indices recomputed ONCE per admit
        # pass, not once per admitted slot
        self._slot_lora = self.lora_bank.lora_idx(self.slot_adapter)

    def _batch_shape_attrs(self, reqs, value) -> dict:
        """Span attrs describing a batch's rank shape: ``max_rank`` plus,
        in bucketed mode, per-rank-bucket sums of ``value(req)`` — the
        exact inputs the bucketed cost-model methods take."""
        from repro.lora.bank import rank_bucket
        ranks = [self.adapter_ranks[r.adapter_id] for r in reqs]
        attrs = {"max_rank": max(ranks), "bank_mode": self.bank_mode}
        if self.bank_mode == "bucketed":
            buckets: Dict[int, int] = {}
            for r, req in zip(ranks, reqs):
                b = rank_bucket(max(1, r))
                buckets[b] = buckets.get(b, 0) + value(req)
            attrs["buckets"] = buckets
        return attrs

    def _prefill_group(self, length: int, grp) -> None:
        t0 = self._clock()
        n = len(grp)
        aidx = []
        for slot, req in grp:
            ai = self._adapter_idx[req.adapter_id]
            aidx.append(ai)
            if self.page_pool is not None:
                # unified paging: KV pages for the sequence + the
                # adapter's pages (paged in on first use, pinned while
                # co-batched)
                self.page_pool.alloc_kv(f"req{req.req_id}", length)
                # footprint from the same formula the cluster/placement
                # accounting uses, not an ad-hoc per-target guess; hybrid
                # banks hold a single shared-attn LoRA layer, so the
                # per-layer share is what this server actually pages in
                nbytes = Adapter(req.adapter_id,
                                 self.ranks[ai]).nbytes(self.cfg)
                if self.cfg.family == "hybrid":
                    nbytes = max(1, nbytes // self.cfg.n_layers)
                self.page_pool.ensure_adapter(req.adapter_id, nbytes)
                self.page_pool.pin_adapter(req.adapter_id)
        toks = jnp.asarray([req.prompt for _, req in grp], jnp.int32)
        frontend = None
        if self.cfg.family == "vlm":
            frontend = jnp.zeros(
                (n, self.cfg.n_frontend_tokens, self.cfg.d_model))
        if self.cfg.family == "audio":
            frontend = jnp.zeros(
                (n, self.cfg.encoder.n_frames, self.cfg.d_model))
        fn = self._prefill_fn(length)
        lidx = self.lora_bank.lora_idx(jnp.asarray(aidx, jnp.int32))
        with self._ctx():
            if frontend is not None:
                logits, cache1 = fn(self.params, toks, self.bank, lidx,
                                    frontend)
            else:
                logits, cache1 = fn(self.params, toks, self.bank, lidx)
        self.prefill_dispatches += 1
        firsts = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        slots = jnp.asarray([slot for slot, _ in grp], jnp.int32)
        with self._ctx():
            self.cache = self._merge_many(self.cache, cache1, slots,
                                          jnp.full((n,), length,
                                                   jnp.int32))
        self.slot_adapter = self.slot_adapter.at[slots].set(
            jnp.asarray(aidx, jnp.int32))
        self.last_token = self.last_token.at[slots].set(
            jnp.asarray(firsts))
        t = self._clock()
        for i, (slot, req) in enumerate(grp):
            req.phase = Phase.DECODE
            req.slot = slot
            req.output.append(int(firsts[i]))
            req.t_first_token = t
            req.prefill_start = t0
            req.prefill_done = t
            self.slots[slot] = req
        if self.tracer is not None:
            reqs = [req for _, req in grp]
            attrs = self._batch_shape_attrs(reqs, lambda r: length)
            attrs.update(tokens=n * length, batch=n)
            self.tracer.record("prefill", t0, t, cat="iteration",
                               track=self._track, attrs=attrs)

    def _finish_token(self, slot: int, req: ServeRequest, token: int,
                      now: float) -> None:
        """Record one decoded token for a slot; free the slot if done."""
        req.output.append(token)
        self.tokens_decoded += 1
        if self.page_pool is not None:
            self.page_pool.grow_kv(f"req{req.req_id}",
                                   len(req.prompt) + len(req.output))
        done = len(req.output) >= req.max_new_tokens
        if done or len(req.prompt) + len(req.output) >= self.max_len:
            req.phase = Phase.DONE
            req.t_finish = now
            req.finish = now
            self.metrics.record(req)
            self.completed.append(req)
            self.slots[slot] = None
            if self.page_pool is not None:
                self.page_pool.free_kv(f"req{req.req_id}")
                if not any(r is not None and
                           r.adapter_id == req.adapter_id
                           for r in self.slots):
                    self.page_pool.pin_adapter(req.adapter_id, False)

    def _decode_once(self) -> None:
        if not any(s is not None for s in self.slots):
            return
        t0 = self._clock()
        active = [r for r in self.slots if r is not None]
        with self._ctx():
            logits, self.cache = self._decode(
                self.params, self.cache, self.last_token, self.bank,
                self._slot_lora)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_token = nxt
        self.decode_dispatches += 1
        # analysis: ignore[host-sync] the iteration's single sync point
        nxt_np = np.asarray(nxt)
        now = self._clock()
        if self.tracer is not None:
            attrs = self._batch_shape_attrs(active, lambda r: 1)
            attrs.update(batch=len(active), steps=1, iters=1)
            self.tracer.record("decode", t0, now, cat="iteration",
                               track=self._track, attrs=attrs)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self._finish_token(slot, req, int(nxt_np[slot]), now)
        self._iter += 1

    # -- multi-token decode steps ---------------------------------------
    def _decode_k_fn(self, k: int):
        """jitted k-step fused decode, cached per k (and retraced per
        bank signature by jit itself)."""
        if k not in self._decode_k_cache:
            cfg = self.cfg
            kern = self.lora_kernel

            def _decode_k(params, cache, tokens, bank, idx, steps_left):
                def body(carry, _):
                    cache, tok, left = carry
                    logits, cache = M.decode_step(cfg, params, cache, tok,
                                                  bank=bank, lora_idx=idx,
                                                  lora_kernel=kern)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    active = left > 0
                    # rows past their budget freeze: their cache keeps
                    # advancing (writes are dropped on host) but the
                    # emitted token repeats and is discarded
                    nxt = jnp.where(active, nxt, tok)
                    return (cache, nxt, left - active.astype(left.dtype)), \
                        nxt

                (cache, tok, left), toks = jax.lax.scan(
                    body, (cache, tokens, steps_left), None, length=k)
                return cache, tok, toks

            self._decode_k_cache[k] = jax.jit(_decode_k,
                                              donate_argnums=(1,))
        return self._decode_k_cache[k]

    def decode_steps(self, k: int) -> int:
        """Run ``k`` decode iterations in ONE host dispatch: a
        ``lax.scan`` over the fused decode step with on-device argmax and
        per-slot remaining-token bookkeeping, cache donated through the
        scan. Returns the number of fused iterations run. Token streams
        are identical to ``k`` single ``step()`` calls; only admission
        granularity (every k tokens instead of every token) and finish-
        timestamp granularity are coarser."""
        if not any(s is not None for s in self.slots):
            return 0
        t0 = self._clock()
        left = [0] * self.max_batch
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            # mirror _decode_once: an active slot always decodes at
            # least one more token, then finishes on whichever budget
            # (max_new_tokens or max_len) it crosses first
            left[slot] = max(1, min(req.max_new_tokens - len(req.output),
                                    self.max_len - len(req.prompt)
                                    - len(req.output)))
        # always dispatch the full k-step scan (rows past their budget
        # freeze on device): one trace per (k, bank signature) instead
        # of retracing for every distinct tail length
        fn = self._decode_k_fn(k)
        with self._ctx():
            self.cache, self.last_token, toks = fn(
                self.params, self.cache, self.last_token, self.bank,
                self._slot_lora, jnp.asarray(left, jnp.int32))
        self.decode_dispatches += 1
        # analysis: ignore[host-sync] ONE sync per k tokens, by design
        toks_np = np.asarray(toks)
        now = self._clock()
        if self.tracer is not None:
            active = [r for r in self.slots if r is not None]
            attrs = self._batch_shape_attrs(active, lambda r: 1)
            attrs.update(batch=len(active), steps=k, iters=k)
            self.tracer.record("decode", t0, now, cat="iteration",
                               track=self._track, attrs=attrs)
        for step in range(k):
            for slot, req in enumerate(self.slots):
                if req is None or step >= left[slot]:
                    continue
                self._finish_token(slot, req, int(toks_np[step, slot]),
                                   now)
        self._iter += k
        return k

    def step(self) -> None:
        """One engine iteration: admit then decode (prefill-prioritized).
        With ``decode_block > 1`` each step decodes up to that many
        tokens per slot in a single fused host dispatch."""
        self._admit(self._clock())
        if self.decode_block > 1:
            self.decode_steps(self.decode_block)
        else:
            self._decode_once()

    def drain_completed(self) -> List[ServeRequest]:
        done, self.completed = self.completed, []
        return done

    def cancel(self, req_id: int) -> Optional[ServeRequest]:
        """Abort a live request: drop it from the queue, or free its
        batch slot (and KV pages, and the adapter pin if it was the
        last co-batched user). Returns the request, or None if it is
        not live on this engine."""
        for r in self.queue:
            if r.req_id == req_id:
                self.queue = [q for q in self.queue if q is not r]
                return r
        for slot, r in enumerate(self.slots):
            if r is not None and r.req_id == req_id:
                self.slots[slot] = None
                if self.page_pool is not None:
                    self.page_pool.free_kv(f"req{r.req_id}")
                    if not any(q is not None
                               and q.adapter_id == r.adapter_id
                               for q in self.slots):
                        self.page_pool.pin_adapter(r.adapter_id, False)
                return r
        return None

    def run_until_drained(self, max_iters: int = 100_000) -> dict:
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.metrics.summary()

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)
