from .backend import EngineBackend, ServingBackend, SimBackend
from .cluster import (ClusterEvent, ClusterReport, LoRAServeCluster,
                      ServeResult)
from .engine import ServingEngine
from .metrics import MetricsCollector, percentile
from .request import Phase, Request, ServeRequest
from .scheduler import replay
from .paging import OutOfPages, UnifiedPagePool

__all__ = ["EngineBackend", "ServingBackend", "SimBackend",
           "ClusterEvent", "ClusterReport", "LoRAServeCluster",
           "ServeResult", "ServingEngine", "MetricsCollector",
           "percentile", "Phase", "Request", "ServeRequest", "replay",
           "OutOfPages", "UnifiedPagePool"]
