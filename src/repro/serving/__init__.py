from .engine import ServingEngine
from .metrics import MetricsCollector, percentile
from .request import Phase, Request
from .scheduler import replay
from .paging import OutOfPages, UnifiedPagePool
