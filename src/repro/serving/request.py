"""Serving request lifecycle objects.

The actual lifecycle type lives in :mod:`repro.core.request` so that the
simulator (which must stay jax-free on its hot path) and the real engine
share one request class. This module re-exports it under the historical
names used by the engine-side code and tests.
"""
from repro.core.request import Phase, Request, ServeRequest  # noqa: F401

__all__ = ["Phase", "Request", "ServeRequest"]
