"""Serving request lifecycle objects."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    req_id: int
    adapter_id: str
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    # lifecycle
    phase: Phase = Phase.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                   # engine batch slot
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        if self.t_finish is None or len(self.output) <= 1:
            return None
        return (self.t_finish - self.t_first_token) / (len(self.output) - 1)
