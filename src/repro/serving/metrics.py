"""Latency metrics: TTFT / TBT / adapter-fetch percentiles over finished
requests — one collector for both the simulated and the real backend."""
from __future__ import annotations

from typing import List


def percentile(values: List[float], p: float) -> float:
    """Linear-interpolated percentile (numpy's default method). The old
    nearest-rank ``int(...)`` floor systematically under-reported high
    percentiles on small windows (P99 of 50 samples collapsed to the
    floor rank)."""
    if not values:
        return float("nan")
    vs = sorted(values)
    pos = min(len(vs) - 1.0, max(0.0, p / 100.0 * (len(vs) - 1)))
    lo = int(pos)
    frac = pos - lo
    if frac == 0.0 or lo + 1 >= len(vs):
        return vs[lo]
    return vs[lo] * (1.0 - frac) + vs[lo + 1] * frac


class MetricsCollector:
    def __init__(self):
        self.ttfts: List[float] = []
        self.tbts: List[float] = []
        self.fetch_latencies: List[float] = []
        self.finished = 0

    def record(self, req) -> None:
        self.finished += 1
        if req.ttft is not None:
            self.ttfts.append(req.ttft)
        tbt = req.tbt
        if tbt is not None and tbt > 0:
            self.tbts.append(tbt)
        self.fetch_latencies.append(getattr(req, "fetch_latency", 0.0))

    def summary(self) -> dict:
        return {
            "finished": self.finished,
            "p50_ttft": percentile(self.ttfts, 50),
            "p95_ttft": percentile(self.ttfts, 95),
            "p99_ttft": percentile(self.ttfts, 99),
            "mean_tbt": (sum(self.tbts) / len(self.tbts)
                         if self.tbts else float("nan")),
            "p95_tbt": percentile(self.tbts, 95),
            "mean_fetch_latency": (sum(self.fetch_latencies) /
                                   len(self.fetch_latencies)
                                   if self.fetch_latencies else 0.0),
            "p95_fetch_latency": percentile(self.fetch_latencies, 95)
            if self.fetch_latencies else 0.0,
        }
