"""Latency metrics: TTFT / TBT percentiles over finished requests."""
from __future__ import annotations

from typing import Iterable, List


def percentile(values: List[float], p: float) -> float:
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(p / 100.0 * (len(vs) - 1))))
    return vs[idx]


class MetricsCollector:
    def __init__(self):
        self.ttfts: List[float] = []
        self.tbts: List[float] = []
        self.finished = 0

    def record(self, req) -> None:
        self.finished += 1
        if req.ttft is not None:
            self.ttfts.append(req.ttft)
        if req.tbt is not None:
            self.tbts.append(req.tbt)

    def summary(self) -> dict:
        return {
            "finished": self.finished,
            "p50_ttft": percentile(self.ttfts, 50),
            "p95_ttft": percentile(self.ttfts, 95),
            "p99_ttft": percentile(self.ttfts, 99),
            "mean_tbt": (sum(self.tbts) / len(self.tbts)
                         if self.tbts else float("nan")),
            "p95_tbt": percentile(self.tbts, 95),
        }
