"""Mesh-sharded serving: the sharding layer of the engine's first-class
tensor/data-parallel mode.

``EngineSharding`` binds a ("data", "model") mesh to one engine and owns
every placement decision the sharded mode needs:

* base params  — the model's PartitionSpec rules (column/row-parallel
  projections, sharded embed/lm_head), via ``launch.specs``;
* KV cache     — the serving layout rules (sequence-sharded in "opt"
  mode, kv-head-sharded in "baseline"), via ``launch.specs``;
* LoRA banks   — the CO-SHARDED scheme: every bucket's A bank is split
  along d_model and its B bank along d_out, so the fused SGMV kernels
  run per-shard on their local d/n_shards slice, the rank-r intermediate
  is reduced with ONE psum, and the expand output comes out column-
  sharded exactly like the base projection it is added to. Neither the
  full bank nor the full-width delta ever materializes on one device
  (see the per-shard reduction contract in ``repro.kernels.sgmv``);
* activations  — via the ambient axis env: ``ctx()`` enters the mesh
  and an ``axis_env(batch=..., model="model", lora="coshard")`` so
  every ``constrain`` call in the model and the LoRA paths resolves to
  real mesh axes at trace time.

Shardings are *fitted*: any dim a mesh axis does not evenly divide
falls back to replicated (``launch.specs.fit_spec``), so the same
engine code serves a 1x1 mesh (trivially single-device), a 2x4 CPU
host-device mesh in tests, and a production TPU slice.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import axis_env, param_pspecs


def _fitted(mesh, spec: P, x):
    from repro.launch.specs import fitted_ns
    return fitted_ns(mesh, spec, x)


class EngineSharding:
    """Sharding context for one ``ServingEngine`` over a (dp, tp) mesh
    with axes ("data", "model")."""

    def __init__(self, mesh, cfg, max_batch: int):
        self.mesh = mesh
        self.cfg = cfg
        self.dp = int(mesh.shape.get("data", 1))
        self.tp = int(mesh.shape.get("model", 1))
        # the engine's slot batch shards over "data" only when divisible
        # (jit argument shardings require it; constraints would too)
        self.batch_axes = ("data",) if self.dp > 1 \
            and max_batch % self.dp == 0 else ()

    # -- placement -------------------------------------------------------
    def shard_params(self, params):
        """device_put the base weights with the model's partition rules
        (column/row-parallel projections over "model")."""
        specs = param_pspecs(params)
        sh = jax.tree.map(lambda s, p: _fitted(self.mesh, s, p),
                          specs, params,
                          is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(params, sh)

    def shard_cache(self, cache):
        """device_put the KV/state cache with the serving layout rules
        (sequence-sharded over "model" in "opt" mode)."""
        from repro.launch.specs import _cache_sharding
        sh = _cache_sharding(self.mesh, self.cfg, cache,
                             self._cache_batch(cache))
        return jax.device_put(cache, sh)

    def _cache_batch(self, cache) -> int:
        pos = cache.get("pos")
        return int(pos.shape[0]) if pos is not None else 1

    def bank_spec(self, x, name: str) -> NamedSharding:
        """Co-sharded bank rule for one leaf: A (..., d, r) split on
        d_model, B (..., r, d_out) split on d_out."""
        nd = x.ndim
        if name == "A":
            spec = P(*([None] * (nd - 2) + ["model", None]))
        else:
            spec = P(*([None] * (nd - 1) + ["model"]))
        return _fitted(self.mesh, spec, x)

    def shard_bank(self, bank_data):
        """device_put a bank pytree (padded dict or bucketed tuple of
        dicts) with the co-sharded A/B rules. Called after every bank
        rebuild / install so mid-flight placement changes keep the
        sharded layout."""

        def leaf(path, x):
            name = None
            for e in reversed(path):
                if isinstance(e, jax.tree_util.DictKey):
                    name = str(e.key)
                    break
            return self.bank_spec(x, name or "B")

        sh = jax.tree_util.tree_map_with_path(leaf, bank_data)
        return jax.device_put(bank_data, sh)

    def replicate(self, x):
        """Small operands (tokens, indices) live replicated."""
        return jax.device_put(
            x, jax.tree.map(
                lambda v: NamedSharding(self.mesh, P(*([None] * v.ndim))),
                x))

    # -- trace context ---------------------------------------------------
    def ctx(self):
        """Context every jitted engine call runs (and traces) under: the
        physical mesh (bare-PartitionSpec constraints need it at trace
        time) plus the axis env that routes ``constrain`` calls and
        selects the co-sharded LoRA scheme."""
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(axis_env(
            batch=self.batch_axes, model="model" if self.tp > 1 else None,
            mesh=self.mesh, lora="coshard" if self.tp > 1 else None))
        return stack


def make_engine_sharding(mesh, cfg, max_batch: int):
    """None-propagating factory: a missing/trivial mesh means the engine
    runs exactly as before (no device_put, no axis env)."""
    if mesh is None:
        return None
    return EngineSharding(mesh, cfg, max_batch)
