"""Unified paging (S-LoRA, paper §II-B.2): one page pool in GPU memory
backs BOTH the KV cache blocks and the active LoRA adapter slices, so
thousands of adapters can coexist with long sequences without a static
partition. This is the per-server memory substrate underneath the
orchestrator's placement decisions — the placement controls *which*
adapters a server needs, unified paging controls *how* they share HBM
with the KV cache.

Semantics implemented:
  * fixed pool of pages (page = `page_tokens` KV slots = `page_bytes`);
  * KV sequences allocate ceil(len/page_tokens) pages, grow page-by-page
    during decode;
  * adapters allocate ceil(adapter_bytes/page_bytes) pages on first use
    (paged in from host), and are LRU-evicted when the pool is under
    pressure from KV growth — never while pinned (actively co-batched);
  * fragmentation-free by construction (page granularity), stats exposed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class _Alloc:
    pages: List[int]
    kind: str                    # "kv" | "adapter"
    pinned: bool = False
    last_use: int = 0


class UnifiedPagePool:
    def __init__(self, n_pages: int, page_tokens: int = 16,
                 page_bytes: int = 2 << 20):
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.page_bytes = page_bytes
        self._free: List[int] = list(range(n_pages))
        self._allocs: Dict[str, _Alloc] = {}
        self._clock = 0
        # telemetry
        self.adapter_page_ins = 0
        self.adapter_evictions = 0

    # -- internals -------------------------------------------------------
    def _take(self, n: int, for_kind: str) -> List[int]:
        while len(self._free) < n:
            if not self._evict_one(prefer_not=for_kind):
                raise OutOfPages(
                    f"need {n} pages, {len(self._free)} free, nothing "
                    f"evictable")
        pages = self._free[:n]
        del self._free[:n]
        return pages

    def _evict_one(self, prefer_not: str) -> bool:
        """LRU-evict an unpinned adapter (KV blocks are never evicted —
        they hold live sequence state)."""
        cands = [(a.last_use, key) for key, a in self._allocs.items()
                 if a.kind == "adapter" and not a.pinned]
        if not cands:
            return False
        _, key = min(cands)
        self.free(key)
        self.adapter_evictions += 1
        return True

    # -- KV sequences ------------------------------------------------------
    def alloc_kv(self, seq_id: str, n_tokens: int) -> None:
        assert seq_id not in self._allocs
        n = -(-n_tokens // self.page_tokens)
        self._allocs[seq_id] = _Alloc(self._take(max(1, n), "kv"), "kv")

    def grow_kv(self, seq_id: str, n_tokens: int) -> None:
        """Ensure capacity for n_tokens (decode growth)."""
        a = self._allocs[seq_id]
        need = -(-n_tokens // self.page_tokens)
        if need > len(a.pages):
            a.pages.extend(self._take(need - len(a.pages), "kv"))

    # -- adapters ----------------------------------------------------------
    def ensure_adapter(self, adapter_id: str, nbytes: int) -> bool:
        """Page the adapter in if absent. Returns True on a page-in
        (host->device transfer happened), False on a hit."""
        self._clock += 1
        key = f"adapter/{adapter_id}"
        if key in self._allocs:
            self._allocs[key].last_use = self._clock
            return False
        n = max(1, -(-nbytes // self.page_bytes))
        self._allocs[key] = _Alloc(self._take(n, "adapter"), "adapter",
                                   last_use=self._clock)
        self.adapter_page_ins += 1
        return True

    def pin_adapter(self, adapter_id: str, pinned: bool = True) -> None:
        self._allocs[f"adapter/{adapter_id}"].pinned = pinned

    def has_adapter(self, adapter_id: str) -> bool:
        return f"adapter/{adapter_id}" in self._allocs

    # -- common ------------------------------------------------------------
    def free(self, key: str) -> None:
        a = self._allocs.pop(key)
        self._free.extend(a.pages)

    def free_kv(self, seq_id: str) -> None:
        self.free(seq_id)

    # -- stats ---------------------------------------------------------------
    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def pages_by_kind(self) -> Dict[str, int]:
        out = {"kv": 0, "adapter": 0}
        for a in self._allocs.values():
            out[a.kind] += len(a.pages)
        return out

    def check_invariant(self) -> bool:
        seen: Set[int] = set(self._free)
        total = len(self._free)
        for a in self._allocs.values():
            seen.update(a.pages)
            total += len(a.pages)
        return total == self.n_pages and len(seen) == self.n_pages
