"""LORASERVE reproduction — rank- and demand-aware LoRA adapter placement
and routing for distributed LLM inference, as a full JAX framework.

Subpackages: core (the paper's contribution), controlplane (drift
detection + SLO-driven autoscaling), cluster (simulator + calibrated
cost model), serving (real JAX engine), lora, kernels (Pallas SGMV),
models (10-arch zoo), training, data, configs, launch, traces.
"""
__version__ = "1.0.0"
