"""Production mesh builders. Functions, not module-level constants, so
importing this module never touches jax device state.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis extends data parallelism across the ICI-connected superpod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_engine_mesh(dp: int = 1, tp: int = 1, *, devices=None):
    """Mesh for a single serving engine: (dp, tp) over ("data", "model"),
    built from the first dp*tp available devices. The engine shards base
    weights / KV / LoRA banks over "model" and the batch over "data";
    dp=tp=1 yields a trivial 1x1 mesh the engine treats as single-device.
    """
    import numpy as np
    from jax.sharding import Mesh
    if devices is None:
        devices = jax.devices()
    n = dp * tp
    if len(devices) < n:
        raise ValueError(
            f"mesh {dp}x{tp} needs {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(dp, tp),
                ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def batch_shard_size(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


# TPU v5e hardware constants (per chip) for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
ICI_LATENCY = 1e-6             # seconds per ICI hop (collective step)
# On-chip vector memory per core: the budget every Pallas kernel's
# double-buffered blocks + scratch must fit in (repro.analysis.vmem
# checks this statically against the kernels' BlockSpecs).
VMEM_BYTES_PER_CORE = 16 * 2**20   # ~16 MiB
