"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the
dry-run artifact directory.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_):
    out = {}
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        d = json.load(open(p))
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_s(x):
    return f"{x:.3e}"


def roofline_table(arts, mesh="16x16"):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | MODEL/HLO FLOPs |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), d in sorted(arts.items()):
        if m != mesh:
            continue
        lines.append(
            f"| {arch} | {shape} | {fmt_s(d['t_compute'])} | "
            f"{fmt_s(d['t_memory'])} | {fmt_s(d['t_collective'])} | "
            f"**{d['bottleneck']}** | {d['useful_flops_frac']:.2f} |")
    return "\n".join(lines)


def dryrun_table(arts):
    lines = [
        "| arch | shape | mesh | compile (s) | HLO GFLOPs | arg GB/dev | "
        "temp GB/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), d in sorted(arts.items()):
        mem = d["memory"]
        lines.append(
            f"| {arch} | {shape} | {m} | {d['compile_s']} | "
            f"{d['hlo_flops'] / 1e9:.0f} | "
            f"{(mem['argument_bytes'] or 0) / 1e9:.2f} | "
            f"{(mem['temp_bytes'] or 0) / 1e9:.2f} | "
            f"{d['collective_bytes'] / 1e9:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    arts = load(args.dir)
    if args.table == "roofline":
        print(roofline_table(arts))
    else:
        print(dryrun_table(arts))


if __name__ == "__main__":
    main()
