"""Serving launcher: a miniature LORASERVE cluster of real JAX engines.

Each "server" is a ServingEngine over the same (reduced) base model with
its own local adapter subset; the ClusterOrchestrator routes requests via
the paper's placement + phi-routing + distributed-pool machinery. This is
the end-to-end driver deliverable (real model execution on CPU); the
full-scale evaluation uses the calibrated simulator (benchmarks/).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama-7b-paper \
      --servers 2 --adapters 8 --requests 24 --policy loraserve
"""
from __future__ import annotations

import argparse
import random
import time

import jax

from repro.cluster import NetworkModel, ServerModel, \
    profile_operating_points
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ClusterOrchestrator
from repro.models import model as M
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b-paper")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--policy", default="loraserve",
                    choices=["loraserve", "slora-random",
                             "slora-contiguous", "toppings"])
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = random.Random(args.seed)
    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    ranks = [8, 16, 32, 64, 128]
    adapters = [AdapterInfo(f"ad{i}-r{ranks[i % 5]}", ranks[i % 5],
                            nbytes=ranks[i % 5] * 2_000_000)
                for i in range(args.adapters)]
    adapter_ranks = {a.adapter_id: a.rank for a in adapters}

    ops = profile_operating_points(ServerModel(),
                                   {a.rank for a in adapters})
    orch = ClusterOrchestrator(args.servers, adapters, ops,
                               policy=args.policy, network=NetworkModel(),
                               seed=args.seed)

    engines = [ServingEngine(cfg, params, adapter_ranks, max_batch=4,
                             max_len=args.prompt_len + args.max_new + 8)
               for _ in range(args.servers)]

    t0 = time.monotonic()
    per_server = [0] * args.servers
    fetch_total = 0.0
    for i in range(args.requests):
        aid = rng.choice(adapters).adapter_id
        sid, fetch_lat = orch.route(aid, tokens=args.prompt_len +
                                    args.max_new)
        fetch_total += fetch_lat
        per_server[sid] += 1
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in
                  range(args.prompt_len)]
        engines[sid].submit(Request(req_id=i, adapter_id=aid,
                                    prompt=prompt,
                                    max_new_tokens=args.max_new,
                                    arrival=time.monotonic()))
    for sid, eng in enumerate(engines):
        summ = eng.run_until_drained()
        print(f"server {sid}: requests={per_server[sid]} "
              f"p95_ttft={summ['p95_ttft']:.3f}s "
              f"mean_tbt={summ['mean_tbt']*1e3:.1f}ms")
    orch.end_of_timestep(time.monotonic() - t0)
    print(f"policy={args.policy} total_fetch_latency={fetch_total*1e3:.1f}ms "
          f"pool_fetches={orch.pool.fetches} "
          f"max_adapters/server={orch.pool.max_adapters_per_server()}")
    print("cluster drained OK")


if __name__ == "__main__":
    main()
