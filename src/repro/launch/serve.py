"""Serving launcher: a miniature LORASERVE cluster of real JAX engines
driven through the unified ``LoRAServeCluster`` facade.

Each "server" is a placement-aware ``ServingEngine`` over the same
(reduced) base model whose LoRA bank holds *only its placed adapter
subset* (a server hosting ranks {8, 16} pays a 16-wide bank, not the
global max). The facade owns the paper's control plane — placement +
phi-routing + distributed pool + demand estimation — and applies
``end_of_timestep`` rebalances while requests are in flight: arrivals
are spread over wall-clock time with drifting adapter popularity, so at
least one mid-run rebalance re-places adapters and re-seeds routing
before the trace drains. This is the end-to-end driver deliverable
(real model execution on CPU); the full-scale evaluation uses the
calibrated simulator (benchmarks/).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama-7b-paper \
      --servers 2 --adapters 8 --requests 24 --policy loraserve
"""
from __future__ import annotations

import argparse
import random

import jax

from repro.cluster import NetworkModel
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, POLICIES, ServeRequest
from repro.models import model as M
from repro.serving import EngineBackend, LoRAServeCluster


def build_trace(adapters, cfg, n_requests: int, prompt_len: int,
                max_new: int, duration: float, seed: int):
    """Arrivals spread over `duration` seconds with drifting popularity:
    early traffic favors low-rank adapters, late traffic high-rank —
    the workload shift that makes the dynamic policy re-place."""
    rng = random.Random(seed)
    by_rank = sorted(adapters, key=lambda a: a.rank)
    trace = []
    for i in range(n_requests):
        progress = i / max(1, n_requests - 1)
        # weight drifts from head (low ranks) to tail (high ranks)
        w = [(1.0 - progress) * (len(by_rank) - j) + progress * (j + 1)
             for j in range(len(by_rank))]
        a = rng.choices(by_rank, weights=w)[0]
        prompt = [rng.randrange(1, cfg.vocab_size)
                  for _ in range(prompt_len)]
        trace.append(ServeRequest(
            req_id=i, adapter_id=a.adapter_id, rank=a.rank,
            prompt_len=prompt_len, output_len=max_new, prompt=prompt,
            arrival=i * duration / max(1, n_requests)))
    return trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b-paper")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--policy", default="loraserve",
                    choices=sorted(POLICIES))
    ap.add_argument("--bank-mode", default="padded",
                    choices=["padded", "bucketed"],
                    help="LoRA bank layout: max-rank padded (paper "
                         "baseline) or power-of-two rank buckets")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="decode tokens per fused host dispatch "
                         "(ServingEngine.decode_steps(k); 1 = one "
                         "round-trip per token)")
    ap.add_argument("--lora-kernel", default="einsum",
                    choices=["einsum", "sgmv"],
                    help="LoRA delta execution form: gather-einsum "
                         "(any backend) or the fused Pallas SGMV "
                         "kernels (compiled on TPU, interpreted "
                         "elsewhere)")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="mesh-sharded engines: 'dp,tp' shards each "
                         "engine over a (data, model) device mesh with "
                         "co-sharded LoRA banks (needs dp*tp devices; "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--access-mode", default="migrate",
                    choices=["migrate", "remote-read"],
                    help="on a placement miss: block on the adapter "
                         "fetch (migrate) or serve immediately reading "
                         "weights from a peer's copy over GDR while the "
                         "local copy warms (remote-read)")
    ap.add_argument("--prefetch", action="store_true",
                    help="warm newly-placed adapters at each rebalance "
                         "instead of migrating lazily on first hit")
    ap.add_argument("--controller", action="store_true",
                    help="run the SLO-driven control plane: drift "
                         "detection, triggered rebalances, and server "
                         "scale-up/drain between --min-servers and "
                         "--max-servers")
    ap.add_argument("--slo-ttft", type=float, default=5.0,
                    help="TTFT target (seconds) the controller defends")
    ap.add_argument("--slo-target", type=float, default=0.95,
                    help="required fraction of requests inside the SLO")
    ap.add_argument("--min-servers", type=int, default=1)
    ap.add_argument("--max-servers", type=int, default=4)
    ap.add_argument("--tick-period", type=float, default=1.0,
                    help="controller tick (seconds)")
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="instead of replaying a trace, serve the "
                         "cluster over the streaming HTTP gateway "
                         "(OpenAI-style /v1/completions with SSE, "
                         "adapter lifecycle routes, /metrics) until "
                         "SIGTERM; port 0 picks an ephemeral port")
    ap.add_argument("--rate", type=float, default=None,
                    help="gateway: per-tenant admission rate (req/s)")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="gateway: per-tenant concurrent-request cap")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's span trace on exit: Perfetto/"
                         "Chrome-trace JSON (open in ui.perfetto.dev), "
                         "or one-span-per-line JSONL when PATH ends in "
                         ".jsonl")
    ap.add_argument("--flight-recorder", default=None, metavar="DIR",
                    help="keep a bounded ring of recent spans and dump "
                         "it (plus a controller-decision audit record) "
                         "into DIR on SLO violations, scale-up/drain "
                         "decisions, and timeouts")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="chaos plane: inject a seeded random fault "
                         "storm (server crashes/restores, link flaps, "
                         "fetch stalls) over the run; crashes are "
                         "detected by heartbeat and recovered "
                         "loss-free")
    ap.add_argument("--fault-plan", default=None, metavar="PATH",
                    help="chaos plane: replay an explicit JSON fault "
                         "schedule (see repro.faults.FaultPlan) "
                         "instead of a random storm")
    ap.add_argument("--detector-window", type=float, default=0.5,
                    help="heartbeat silence (seconds) before a server "
                         "is confirmed dead and recovery runs")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--duration", type=float, default=6.0,
                    help="seconds the trace arrivals span")
    ap.add_argument("--rebalance-period", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    ranks = [8, 16, 32, 64, 128]
    adapters = [AdapterInfo(f"ad{i}-r{ranks[i % 5]}", ranks[i % 5],
                            nbytes=ranks[i % 5] * 2_000_000)
                for i in range(args.adapters)]

    controller = None
    if args.controller:
        from repro.controlplane import (ClusterController,
                                        ControllerConfig, SLOSpec)
        controller = ClusterController(
            SLOSpec(ttft=args.slo_ttft, target=args.slo_target,
                    window=max(4 * args.tick_period, 2.0)),
            ControllerConfig(tick_period=args.tick_period,
                             min_servers=args.min_servers,
                             max_servers=args.max_servers))

    mesh_shape = None
    if args.mesh:
        dp, tp = (int(v) for v in args.mesh.split(","))
        mesh_shape = (dp, tp)
    backend = EngineBackend(cfg, params, args.servers, max_batch=4,
                            max_len=args.prompt_len + args.max_new + 8,
                            seed=args.seed, bank_mode=args.bank_mode,
                            decode_block=args.decode_block,
                            lora_kernel=args.lora_kernel,
                            mesh_shape=mesh_shape)
    tracer = recorder = None
    if args.trace_out or args.flight_recorder:
        from repro.obs import FlightRecorder, Tracer, WallClock
        tracer = Tracer(clock=WallClock())
        if args.flight_recorder:
            recorder = FlightRecorder(out_dir=args.flight_recorder)
    fault_plan = None
    if args.fault_plan:
        from repro.faults import FaultPlan
        fault_plan = FaultPlan.load(args.fault_plan)
    elif args.chaos is not None:
        from repro.faults import FaultPlan
        fault_plan = FaultPlan.random_plan(
            args.chaos, horizon=args.duration, n_servers=args.servers)
    cluster = LoRAServeCluster(
        backend, adapters, policy=args.policy, network=NetworkModel(),
        rebalance_period=args.rebalance_period, seed=args.seed,
        access_mode=args.access_mode, prefetch=args.prefetch,
        controller=controller, tracer=tracer, flight_recorder=recorder,
        fault_plan=fault_plan, detector_window=args.detector_window,
        durable_ssd=fault_plan is not None)

    def _write_trace():
        if tracer is None or not args.trace_out:
            return
        from repro.obs import write_jsonl, write_perfetto
        writer = (write_jsonl if args.trace_out.endswith(".jsonl")
                  else write_perfetto)
        n = writer(tracer, args.trace_out)
        print(f"trace: {n} spans -> {args.trace_out}")

    if args.serve:
        from .server import run_gateway
        host, _, port = args.serve.rpartition(":")
        report = run_gateway(cluster, host or "127.0.0.1", int(port),
                             rate=args.rate,
                             max_inflight=args.max_inflight)
        print(f"served={report.completed()} "
              f"timed_out={report.timed_out} "
              f"registered={report.registered} "
              f"unregistered={report.unregistered}")
        _write_trace()
        print("gateway drained OK")
        return

    trace = build_trace(adapters, cfg, args.requests, args.prompt_len,
                        args.max_new, args.duration, args.seed)
    report = cluster.run(trace)

    for sid, mem in enumerate(report.memory_profile):
        print(f"server {sid}: requests={report.per_server_counts[sid]} "
              f"bank_adapters={mem['n_adapters']} "
              f"bank_max_rank={mem['max_rank']}")
    s = report.summary
    print(f"bank_mode={report.bank_mode} mesh={report.mesh_shape}")
    print(f"policy={args.policy} finished={report.completed()}"
          f"/{len(trace)} p95_ttft={s['p95_ttft']:.3f}s "
          f"mean_tbt={s['mean_tbt'] * 1e3:.1f}ms "
          f"fetch_latency(mean)={s['mean_fetch_latency'] * 1e3:.1f}ms")
    print(f"rebalances={report.rebalances} "
          f"placement_changed={report.placement_changed()} "
          f"pool_fetches={report.fetches} "
          f"max_adapters/server={report.max_adapters_per_server}")
    print(f"access_mode={report.access_mode} "
          f"remote_reads={report.remote_reads} "
          f"prefetches={report.prefetches} "
          f"coalesced_fetches={report.coalesced_fetches}")
    if fault_plan is not None:
        print(f"chaos: failures={report.server_failures} "
              f"recoveries={report.recoveries} "
              f"redispatched={report.redispatched} "
              f"fetch_retries={report.fetch_retries} "
              f"fetch_timeouts={report.fetch_timeouts} "
              f"breaker_opens={report.breaker_opens}")
    if args.controller:
        print(f"controller: slo_attainment={report.slo_attainment(args.slo_ttft):.3f} "
              f"scale_ups={report.scale_ups} drains={report.drains} "
              f"retires={report.retires} "
              f"oob_rebalances={report.controller_rebalances} "
              f"final_servers={report.final_servers} "
              f"gpu_seconds={report.gpu_seconds:.1f} "
              f"drift_events={len(report.drift_events)}")
    if tracer is not None:
        _write_trace()
        for phase, d in sorted(report.cost_drift.items()):
            print(f"costmodel[{phase}]: n={d['count']} "
                  f"modeled={d['modeled_s']:.3f}s "
                  f"measured={d['measured_s']:.3f}s "
                  f"bias={d['bias']:+.1%} "
                  f"mare={d['mean_abs_rel_err']:.1%}")
        if recorder is not None:
            print(f"flight_recorder: dumps={recorder.n_dumps} "
                  f"-> {args.flight_recorder}")
    print("cluster drained OK")


if __name__ == "__main__":
    main()
