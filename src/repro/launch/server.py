"""Gateway launcher: run the streaming HTTP serving surface.

  PYTHONPATH=src python -m repro.launch.server --backend sim \
      --servers 2 --adapters 8 --port 8080

Builds a ``LoRAServeCluster`` over either substrate (``--backend sim``
for the discrete-event cost model driven on the wall clock, ``engine``
for real JAX execution), wraps it in ``ServeGateway``, and serves until
SIGTERM/SIGINT — which triggers the graceful drain (stop admitting,
finish in-flight, retire servers) before printing the final report.

``launch/serve.py --serve HOST:PORT`` delegates here with its
engine-backend configuration, so every replay flag (bank mode, kernels,
mesh, access mode, controller) also applies to live serving.
"""
from __future__ import annotations

import argparse
import asyncio
from typing import Optional

from repro.cluster import NetworkModel
from repro.core import AdapterInfo, POLICIES
from repro.serving import LoRAServeCluster, SimBackend


def default_adapters(n: int):
    ranks = [8, 16, 32, 64, 128]
    return [AdapterInfo(f"ad{i}-r{ranks[i % 5]}", ranks[i % 5],
                        nbytes=ranks[i % 5] * 2_000_000)
            for i in range(n)]


def build_sim_cluster(args) -> LoRAServeCluster:
    adapters = default_adapters(args.adapters)
    backend = SimBackend(
        args.servers,
        adapter_nbytes={a.adapter_id: a.nbytes for a in adapters})
    return LoRAServeCluster(
        backend, adapters, policy=args.policy,
        network=NetworkModel(args.servers),
        rebalance_period=args.rebalance_period, seed=args.seed)


def build_engine_cluster(args) -> LoRAServeCluster:
    import jax

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving import EngineBackend

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    adapters = default_adapters(args.adapters)
    backend = EngineBackend(cfg, params, args.servers, max_batch=4,
                            max_len=args.max_len, seed=args.seed)
    return LoRAServeCluster(
        backend, adapters, policy=args.policy, network=NetworkModel(),
        rebalance_period=args.rebalance_period, seed=args.seed)


def run_gateway(cluster: LoRAServeCluster, host: str, port: int, *,
                rate: Optional[float] = None,
                burst: Optional[float] = None,
                max_inflight: Optional[int] = None,
                announce=print):
    """Serve ``cluster`` on ``host:port`` until a shutdown signal lands,
    then drain gracefully and return the final ``ClusterReport``."""
    from repro.server import AdmissionController, ServeGateway

    admission = AdmissionController(rate=rate, burst=burst,
                                    max_inflight=max_inflight)
    gw = ServeGateway(cluster, host, port, admission=admission)

    async def amain():
        await gw.start()
        announce(f"listening on {gw.host}:{gw.port}")
        await gw.serve_until_stopped()

    asyncio.run(amain())
    return gw.final_report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "engine"],
                    help="execution substrate: calibrated discrete-event "
                         "cost model (sim) or real JAX engines (engine)")
    ap.add_argument("--arch", default="llama-7b-paper",
                    help="base model (engine backend)")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--policy", default="loraserve",
                    choices=sorted(POLICIES))
    ap.add_argument("--rebalance-period", type=float, default=5.0)
    ap.add_argument("--max-len", type=int, default=64,
                    help="engine sequence budget (prompt + output)")
    ap.add_argument("--rate", type=float, default=None,
                    help="per-tenant admission rate (requests/s); "
                         "unset = unlimited")
    ap.add_argument("--burst", type=float, default=None,
                    help="per-tenant token-bucket burst (default: rate)")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="per-tenant concurrent-request cap")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cluster = (build_sim_cluster(args) if args.backend == "sim"
               else build_engine_cluster(args))
    report = run_gateway(cluster, args.host, args.port, rate=args.rate,
                         burst=args.burst,
                         max_inflight=args.max_inflight)
    s = report.summary
    print(f"served={report.completed()} timed_out={report.timed_out} "
          f"registered={report.registered} "
          f"unregistered={report.unregistered} "
          f"rebalances={report.rebalances}")
    if report.completed():
        print(f"p50_ttft={report.p50_ttft():.3f}s "
              f"p95_ttft={report.p95_ttft():.3f}s "
              f"mean_tbt={(s['mean_tbt'] or 0) * 1e3:.1f}ms")
    print("gateway drained OK")


if __name__ == "__main__":
    main()
