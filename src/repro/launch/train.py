"""Training launcher: train any assigned arch (reduced or full config) on
the synthetic LM pipeline. On CPU use --smoke for the reduced config; the
full configs are exercised via the dry-run.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import model as M
from repro.training import (AdamWConfig, adamw_init, make_train_step,
                            save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10),
                          total_steps=args.steps, weight_decay=0.01)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, batch_size=args.batch))
    it = data.batches()
    t0 = time.time()
    for step in range(1, args.steps + 1):
        toks, labels = next(it)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.encoder.n_frames, cfg.d_model))
        params, opt, m = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == 1:
            tput = args.batch * args.seq * step / (time.time() - t0)
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} tok/s={tput:.0f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
