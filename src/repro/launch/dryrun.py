import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory/cost analysis and collective
traffic for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be executed as its own process (python -m repro.launch.dryrun ...):
the XLA_FLAGS line above runs before any jax import so the 512 placeholder
host devices exist. Nothing else in the repo sets this flag.
"""

import argparse
import json
import re
import signal
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, ASSIGNED_ARCH_IDS, INPUT_SHAPES, \
    get_config
from repro.models.common import axis_env

from .mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, batch_axes,
                   make_production_mesh)
from .specs import build_case, effective_config

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_BLOCK_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> int:
    """Sum the result-type bytes of an HLO instruction line (LHS types,
    before the opcode). Post-SPMD operands have no inline types, so the
    result size is the per-device traffic proxy for each collective."""
    lhs = line.split("= ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # types come first, terminated by the opcode word
    m = re.match(r"\s*(\(?[a-z0-9\[\],\{\}\s/\*_]+?\)?)\s+[a-z\-]+\(", rhs)
    head = m.group(1) if m else rhs.split("(")[0]
    return sum(_shape_bytes(t.group(1), t.group(2))
               for t in _TYPE_RE.finditer(head))


def collective_bytes(hlo_text: str):
    """Per-device collective traffic by op kind, accounting for scan/while
    trip counts (a collective inside a layer scan executes n_layers times).

    Parses the post-SPMD HLO module into computations, finds each while
    op's trip count (max s32 constant in its condition computation), and
    propagates multipliers ENTRY -> callees.
    """
    blocks = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _BLOCK_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            blocks[cur] = []
            if raw.startswith("ENTRY") or stripped.startswith("ENTRY"):
                entry = cur
            if "ENTRY" in raw.split("%")[0]:
                entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            blocks[cur].append(stripped)

    if entry is None:       # fall back: computation containing ROOT + most lines
        entry = max(blocks, key=lambda b: len(blocks[b])) if blocks else None

    # per-block collective bytes and call edges
    coll = {}
    edges = {}
    for name, lines in blocks.items():
        per_kind = {}
        out_edges = []
        for line in lines:
            cm = _COLL_RE.search(line)
            if cm and "= " in line:
                kind = cm.group(1)
                per_kind[kind] = per_kind.get(kind, 0) + _result_bytes(line)
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                consts = [int(c) for c in
                          _CONST_RE.findall("\n".join(blocks.get(cond, [])))]
                consts = [c for c in consts if 1 <= c <= 10_000_000]
                if consts:
                    trip = max(consts)
                out_edges.append((body, trip))
                out_edges.append((cond, trip))
                continue
            for tm in _CALL_RE.finditer(line):
                out_edges.append((tm.group(1), 1))
            bm = _BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    out_edges.append((b.strip().lstrip("%"), 1))
        coll[name] = per_kind
        edges[name] = out_edges

    # propagate multipliers from entry (call graph is a DAG)
    mult = {name: 0 for name in blocks}
    if entry:
        mult[entry] = 1
        order = [entry]
        seen = {entry}
        i = 0
        while i < len(order):
            b = order[i]
            i += 1
            for child, factor in edges.get(b, []):
                if child in mult:
                    mult[child] += mult[b] * factor
                    if child not in seen:
                        seen.add(child)
                        order.append(child)

    totals = {}
    for name, per_kind in coll.items():
        m = mult.get(name, 0)
        if m == 0 and per_kind:
            m = 1          # not reached by the parser's call graph: count once
        for kind, nbytes in per_kind.items():
            totals[kind] = totals.get(kind, 0) + nbytes * m
    return totals


_SHAPE_ONLY_PRIMS = {
    "broadcast_in_dim", "reshape", "transpose", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "gather", "scatter",
    "scatter-add", "convert_element_type", "iota", "squeeze", "pad",
    "select_n", "rev", "copy", "argsort", "sort", "top_k", "bitcast",
    "stop_gradient", "reduce_precision", "split", "device_put",
}


def jaxpr_flops(jaxpr) -> float:
    """Exact traced FLOPs, scan-trip-aware: 2*M*N*K per dot_general,
    `length` x body for scans, 1 FLOP/element for other compute prims.
    This is the trip-count-corrected 'HLO_FLOPs' for the roofline (XLA's
    cost_analysis visits while bodies once)."""
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            (lc, _), _ = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval
            out = eqn.outvars[0].aval
            k = 1
            for i in lc:
                k *= lhs.shape[i]
            total += 2.0 * out.size * k
        elif prim == "scan":
            total += eqn.params["length"] * jaxpr_flops(
                eqn.params["jaxpr"].jaxpr)
        elif prim == "while":
            # not used by our models; count body once conservatively
            total += jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        else:
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is not None:
                total += jaxpr_flops(getattr(inner, "jaxpr", inner))
            elif prim not in _SHAPE_ONLY_PRIMS:
                total += float(sum(
                    v.aval.size for v in eqn.outvars
                    if hasattr(v.aval, "size")))
    return total


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.n_params()
    if cfg.moe is not None:
        e = cfg.moe
        expert_p = 3 * cfg.d_model * e.d_ff_expert * cfg.n_layers
        n = n - e.n_experts * expert_p + e.top_k * expert_p
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    mult = 6 if shape.mode == "train" else 2
    return float(mult) * n * tokens


def run_case(arch: str, shape_name: str, multi_pod: bool,
             dtype=jnp.bfloat16):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    with mesh:
        with axis_env(batch=batch_axes(mesh), model="model", mesh=mesh):
            fn, args, shardings, donate = build_case(cfg, shape_name, mesh,
                                                     dtype)
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            traced = jax.make_jaxpr(fn)(*args)
            flops = jaxpr_flops(traced.jaxpr)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    arg_b = float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    out_b = float(getattr(mem, "output_size_in_bytes", 0) or 0)
    tmp_b = float(getattr(mem, "temp_size_in_bytes", 0) or 0)
    # Per-device HBM traffic proxy: every argument/output byte crosses HBM
    # once; temps are written+read (see EXPERIMENTS.md §Roofline notes).
    hbm_traffic = arg_b + out_b + 2.0 * tmp_b
    coll_total = float(sum(coll.values()))    # per-device (post-SPMD HLO)
    mf = model_flops(effective_config(cfg, shape_name), shape)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(t_compile, 1),
        "hlo_flops": flops,                       # global, trip-corrected
        "hlo_bytes": hbm_traffic,                 # per-device proxy
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll_total,
        "collectives": coll,
        "model_flops": mf,
        "useful_flops_frac": mf / flops if flops else None,
        "memory": {
            "argument_bytes": arg_b,
            "output_bytes": out_b,
            "temp_bytes": tmp_b,
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        # roofline terms (seconds):
        #   compute: global FLOPs spread over all chips at bf16 peak
        #   memory:  per-device HBM traffic at HBM bandwidth
        #   collective: per-device collective bytes over one ICI link
        "t_compute": flops / (chips * PEAK_FLOPS_BF16),
        "t_memory": hbm_traffic / HBM_BW,
        "t_collective": coll_total / ICI_BW,
    }
    terms = {"compute": result["t_compute"], "memory": result["t_memory"],
             "collective": result["t_collective"]}
    result["bottleneck"] = max(terms, key=terms.get)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all' (assigned), or comma list")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--case-timeout", type=int, default=1800,
                    help="seconds per (arch, shape, mesh) case")
    args = ap.parse_args()

    class CaseTimeout(Exception):
        pass

    def _alarm(signum, frame):
        raise CaseTimeout()

    signal.signal(signal.SIGALRM, _alarm)

    archs = (ASSIGNED_ARCH_IDS if args.arch == "all"
             else args.arch.split(","))
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"SKIP {tag} (exists)")
                    continue
                try:
                    signal.alarm(args.case_timeout)
                    res = run_case(arch, shape, mp)
                    signal.alarm(0)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    print(f"OK   {tag}: compile={res['compile_s']}s "
                          f"bottleneck={res['bottleneck']} "
                          f"tc={res['t_compute']:.3e} "
                          f"tm={res['t_memory']:.3e} "
                          f"tx={res['t_collective']:.3e}")
                except Exception as e:  # noqa: BLE001
                    signal.alarm(0)
                    failures.append((tag, repr(e)[:300]))
                    print(f"FAIL {tag}: {repr(e)[:300]}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
