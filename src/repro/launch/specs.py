"""ShapeDtypeStruct input specs + NamedSharding assignments for every
(architecture x input shape) combination — the dry-run's stand-ins
(weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, LONG_CONTEXT_WINDOW
from repro.lora.adapter import init_bank
from repro.models import model as M
from repro.models.common import param_pspecs
from repro.training.optimizer import adamw_init

from .mesh import batch_axes, batch_shard_size

# Serving dry-runs carry a live LoRA bank (the paper's workload): 8
# adapters padded to rank 64 on every server.
DRYRUN_N_ADAPTERS = 8
DRYRUN_MAX_RANK = 64


def _bs(mesh, n_rows: int):
    """Batch sharding axes if divisible, else replicate."""
    ax = batch_axes(mesh)
    size = batch_shard_size(mesh)
    return ax if ax and n_rows % size == 0 else ()


def _axis_size(mesh, ax) -> int:
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def fit_spec(mesh, spec: P, shape) -> P:
    """Drop sharding on dims the mesh axes don't evenly divide (jit
    argument shardings, unlike constraints, require divisibility)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, ax in zip(shape, dims):
        if ax is None:
            out.append(None)
        else:
            out.append(ax if size % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


def fitted_ns(mesh, spec: P, leaf) -> NamedSharding:
    return NamedSharding(mesh, fit_spec(mesh, spec, leaf.shape))


def sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype))


def abstract_bank(cfg, dtype=jnp.bfloat16):
    n_layers = 1 if cfg.family == "hybrid" else cfg.n_layers
    if cfg.family == "vlm":
        return None          # LoRA rides the serving archs; vlm self-stack
    ranks = [DRYRUN_MAX_RANK] * DRYRUN_N_ADAPTERS
    return jax.eval_shape(
        lambda: init_bank(cfg, ranks, jax.random.PRNGKey(0),
                          n_layers=n_layers, dtype=dtype))


def param_shardings(mesh, params):
    specs = param_pspecs(params)
    return jax.tree.map(lambda s, p: fitted_ns(mesh, s, p), specs, params,
                        is_leaf=lambda x: isinstance(x, P))


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _cache_sharding(mesh, cfg, cache, batch):
    from repro.models.common import SHARDING_MODE
    ba = _bs(mesh, batch)
    b = ba if ba else None
    if SHARDING_MODE == "baseline":
        kv_spec = P(None, b, None, "model", None)     # kv-head sharded
    else:
        # §Perf iter 1: shard the sequence dim over the model axis
        # (context-parallel decode) — always divisible, cuts per-device
        # cache 16x and removes the kv-head reshard storm.
        kv_spec = P(None, b, "model", None, None)
    by_key = {
        "pos": P(b),
        "k": kv_spec,
        "v": kv_spec,
        "xk": P(None, b, None, "model", None),
        "xv": P(None, b, None, "model", None),
        "c": P(None, b, None, None) if SHARDING_MODE == "baseline"
        else P(None, b, "model", None),
        "kr": P(None, b, None, None) if SHARDING_MODE == "baseline"
        else P(None, b, "model", None),
        "ssm": P(None, b, "model", None, None),
        "wkv": P(None, b, "model", None, None),
        "x_tm": P(None, b, None),
        "x_cm": P(None, b, None),
    }
    return {k: fitted_ns(mesh, by_key[k], cache[k]) for k in cache}


def _bank_sharding(mesh, bank):
    if bank is None:
        return None

    def leaf(path, x):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        if name == "A":
            return fitted_ns(mesh, P(None, None, None, "model"), x)
        return fitted_ns(mesh, P(None, None, "model", None), x)

    return jax.tree_util.tree_map_with_path(leaf, bank)


def _frontend_spec(cfg, batch, dtype=jnp.bfloat16):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), dtype)
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_frames, cfg.d_model), dtype)
    return None


def needs_window(cfg) -> bool:
    """long_500k carve-out: SSM state is O(1); everything attention-bearing
    uses the sliding-window variant."""
    return cfg.family != "ssm"


def effective_config(cfg, shape_name: str):
    if shape_name == "long_500k" and needs_window(cfg):
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


def build_case(cfg, shape_name: str, mesh, dtype=jnp.bfloat16):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings,
    donate_argnums) for jit(fn).lower(*args)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = effective_config(cfg, shape_name)
    B, S = shape.global_batch, shape.seq_len
    ba = _bs(mesh, B)
    b = ba if ba else None
    params = abstract_params(cfg, dtype)
    p_sh = param_shardings(mesh, params)
    tok_sh = _ns(mesh, b, None)

    if shape.mode == "train":
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_step import make_train_step
        opt = jax.eval_shape(lambda: adamw_init(params))
        opt_sh = {"mu": param_shardings(mesh, opt["mu"]),
                  "nu": param_shardings(mesh, opt["nu"]),
                  "step": _ns(mesh)}
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_sh = {"tokens": tok_sh, "labels": tok_sh}
        fe = _frontend_spec(cfg, B, dtype)
        if fe is not None:
            batch["frontend"] = fe
            batch_sh["frontend"] = _ns(mesh, b, None, None)
        step = make_train_step(cfg, AdamWConfig(), remat=True)
        return (step, (sds(params), sds(opt), batch),
                (p_sh, opt_sh, batch_sh), (0, 1))

    bank = abstract_bank(cfg, dtype)
    bank_sh = _bank_sharding(mesh, bank)
    idx = jax.ShapeDtypeStruct((B,), jnp.int32)
    idx_sh = _ns(mesh, b)

    if shape.mode == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        fe = _frontend_spec(cfg, B, dtype)

        if bank is not None:
            def fn(params, tokens, bank, lora_idx, frontend=None):
                return M.prefill(cfg, params, tokens, frontend=frontend,
                                 bank=bank, lora_idx=lora_idx,
                                 cache_dtype=dtype)
            args = [sds(params), tokens, sds(bank), idx]
            shs = [p_sh, tok_sh, bank_sh, idx_sh]
        else:
            def fn(params, tokens, frontend=None):
                return M.prefill(cfg, params, tokens, frontend=frontend,
                                 cache_dtype=dtype)
            args = [sds(params), tokens]
            shs = [p_sh, tok_sh]
        if fe is not None:
            args.append(fe)
            shs.append(_ns(mesh, b, None, None))
        return fn, tuple(args), tuple(shs), ()

    # decode: one new token against a seq_len cache
    cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    enc_len = (cfg.encoder.n_frames if cfg.encoder else
               (cfg.n_frontend_tokens or None))
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, cache_len, dtype, enc_len=enc_len))
    cache_sh = _cache_sharding(mesh, cfg, cache, B)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)

    if bank is not None:
        def fn(params, cache, tokens, bank, lora_idx):
            return M.decode_step(cfg, params, cache, tokens, bank=bank,
                                 lora_idx=lora_idx)
        args = (sds(params), cache, tokens, sds(bank), idx)
        shs = (p_sh, cache_sh, _ns(mesh, b), bank_sh, idx_sh)
    else:
        def fn(params, cache, tokens):
            return M.decode_step(cfg, params, cache, tokens)
        args = (sds(params), cache, tokens)
        shs = (p_sh, cache_sh, _ns(mesh, b))
    return fn, args, shs, (1,)
