"""Flight recorder: bounded ring of recent spans, dumped on anomalies.

Subscribed as a tracer listener, it keeps the last ``capacity`` spans
in a ring buffer. When the control plane hits an anomaly — SLO
violation flips on, the controller decides to scale up or drain, a
request times out — ``dump()`` snapshots the ring as a Perfetto trace
plus an *audit record* of the controller's decision inputs (drift
events, attainment window, demand estimate), so a post-mortem can see
exactly what the last seconds of traffic looked like and what numbers
the controller acted on.

Dumps are rate-limited (``min_interval`` on the recording clock) and
capped (``max_dumps``) so a sustained violation can't fill the disk.
With no ``out_dir`` the dumps stay in memory (``dumps`` list) — the
mode the tests and the sim substrate use.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import List, Optional

from .export import span_to_dict, to_perfetto
from .trace import Span


class FlightRecorder:
    def __init__(self, capacity: int = 2048, *,
                 out_dir: Optional[str] = None,
                 min_interval: float = 1.0,
                 max_dumps: int = 50):
        self.ring: deque = deque(maxlen=capacity)
        self.out_dir = out_dir
        self.min_interval = min_interval
        self.max_dumps = max_dumps
        self.dumps: List[dict] = []      # in-memory dump records
        self.suppressed = 0              # rate-limited / capped dump calls
        self._last_dump: Optional[float] = None
        self._seq = 0

    # tracer listener
    def observe(self, span: Span) -> None:
        self.ring.append(span)

    @property
    def n_dumps(self) -> int:
        return len(self.dumps)

    def dump(self, reason: str, now: float,
             audit: Optional[dict] = None) -> Optional[dict]:
        """Snapshot the ring. Returns the dump record, or None when
        rate-limited/capped."""
        if len(self.dumps) >= self.max_dumps:
            self.suppressed += 1
            return None
        if self._last_dump is not None \
                and now - self._last_dump < self.min_interval:
            self.suppressed += 1
            return None
        self._last_dump = now
        self._seq += 1
        spans = list(self.ring)
        record = {
            "seq": self._seq,
            "reason": reason,
            "time": now,
            "n_spans": len(spans),
            "audit": audit or {},
            "spans": [span_to_dict(s) for s in spans],
        }
        self.dumps.append(record)
        if self.out_dir:
            os.makedirs(self.out_dir, exist_ok=True)
            stem = os.path.join(
                self.out_dir, f"flight-{self._seq:04d}-{reason}")
            with open(stem + ".perfetto.json", "w") as f:
                json.dump(to_perfetto(spans), f)
            with open(stem + ".audit.json", "w") as f:
                json.dump({k: v for k, v in record.items()
                           if k != "spans"}, f, indent=2, default=str)
        return record
