"""Cost-model drift: per-phase modeled-vs-measured iteration error.

Every per-server ``iteration``-category span (``prefill`` / ``decode``
batches emitted by ``SimServer`` and ``ServingEngine``) is paired with
the ``ServerModel`` predicted time for that exact batch shape. The
``CostModelDrift`` listener accumulates the error per phase so
``/metrics`` and ``ClusterReport`` can expose it — a calibration
regression (wrong ``MFU_PREFILL``, stale ``ICI_BW``, a new kernel the
constants don't know about) shows up as a growing bias instead of
silently skewing routing and autoscaling decisions.

Two prediction paths:

* sim spans carry a precomputed ``attrs["predicted"]`` — the very
  pen+base value the simulator charged, so the listener is a dict
  lookup and drift is exactly 0 (the sim's time *is* the model; a
  nonzero value means the plumbing is broken).
* engine spans carry the raw batch shape (tokens / batch / max_rank /
  steps / buckets / bank_mode) and ``predict_span_seconds`` runs the
  model, so engine drift is the real modeled-vs-measured gap.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..cluster.costmodel import ServerModel
from .trace import Span

PHASES = ("prefill", "decode")


def predict_span_seconds(model: ServerModel, span: Span) -> Optional[float]:
    """ServerModel predicted seconds for one iteration span, from the
    batch-shape attrs the span carries. None when the span isn't an
    iteration or lacks the shape attrs."""
    attrs = span.attrs
    pre = attrs.get("predicted")
    if pre is not None:
        return pre
    if span.name == "prefill":
        buckets = attrs.get("buckets")
        if buckets:
            return model.prefill_time_bucketed(buckets)
        tokens = attrs.get("tokens")
        if tokens is None:
            return None
        return model.prefill_time(tokens, attrs.get("max_rank", 0))
    if span.name == "decode":
        iters = attrs.get("iters", 1)
        steps = attrs.get("steps", 1)
        buckets = attrs.get("buckets")
        if buckets:
            return iters * model.decode_time_bucketed(buckets, steps=steps)
        batch = attrs.get("batch")
        if batch is None:
            return None
        return iters * model.decode_time(
            batch, attrs.get("max_rank", 0), steps=steps)
    return None


class _PhaseStat:
    __slots__ = ("count", "modeled_s", "measured_s", "abs_err_s")

    def __init__(self):
        self.count = 0
        self.modeled_s = 0.0
        self.measured_s = 0.0
        self.abs_err_s = 0.0

    def add(self, modeled: float, measured: float) -> None:
        self.count += 1
        self.modeled_s += modeled
        self.measured_s += measured
        self.abs_err_s += abs(measured - modeled)


class CostModelDrift:
    """Tracer listener accumulating per-phase modeled-vs-measured error
    over ``iteration`` spans. ``summary()`` feeds ``ClusterReport`` and
    the Prometheus exporter."""

    def __init__(self, model: Optional[ServerModel] = None):
        self.model = model if model is not None else ServerModel()
        self.stats: Dict[str, _PhaseStat] = {}
        self.unmatched = 0               # iteration spans we couldn't price

    def observe(self, span: Span) -> None:
        if span.cat != "iteration":
            return
        # fast path: sim spans pre-pay the prediction (attrs lookup, no
        # model call), and the stat update is inlined — this listener
        # runs once per sim iteration, so every function call counts
        modeled = span.attrs.get("predicted")
        if modeled is None:
            modeled = predict_span_seconds(self.model, span)
            if modeled is None:
                self.unmatched += 1
                return
        stat = self.stats.get(span.name)
        if stat is None:
            stat = self.stats[span.name] = _PhaseStat()
        measured = span.end - span.start
        # coalesced decode spans cover `iters` iterations — count them
        # all so iterations_total stays a true per-iteration tally
        stat.count += span.attrs.get("iters", 1)
        stat.modeled_s += modeled
        stat.measured_s += measured
        err = measured - modeled
        stat.abs_err_s += err if err >= 0 else -err

    def summary(self) -> Dict[str, dict]:
        """Per-phase dict: count, modeled_s, measured_s, abs_err_s,
        bias ((measured-modeled)/modeled — signed calibration skew) and
        mean_abs_rel_err (abs_err_s/modeled_s)."""
        out: Dict[str, dict] = {}
        for phase, st in self.stats.items():
            denom = st.modeled_s if st.modeled_s > 0 else 1.0
            out[phase] = {
                "count": st.count,
                "modeled_s": st.modeled_s,
                "measured_s": st.measured_s,
                "abs_err_s": st.abs_err_s,
                "bias": (st.measured_s - st.modeled_s) / denom,
                "mean_abs_rel_err": st.abs_err_s / denom,
            }
        return out
