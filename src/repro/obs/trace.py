"""Flight-recorder tracing core: spans, clocks, and the ``Tracer``.

Every lifecycle point the serving stack already observes (gateway
receive, admission, routing, adapter fetch, prefill groups, decode
iterations, stream finish) can be recorded as a ``Span`` — a named
interval on the *cluster clock*. Both substrates feed the same span
names from the same places:

* the discrete-event simulator stamps spans on its event clock
  (``EventClock`` — virtual seconds, advanced by the host);
* the real-JAX engine stamps spans on wall-clock seconds since run
  start (``WallClock`` — the same domain ``EngineBackend.wall_now``
  serves).

Because both are "seconds since run start" behind the one ``Clock``
protocol, a sim trace and an engine trace of the same workload export
to the same Perfetto timeline shape and can be diffed span-for-span.

Recording is explicit-timestamp: callers pass ``(start, end)`` they
measured on their own clock, so the tracer never injects clock reads
into hot paths. Listeners (the flight recorder's ring buffer, the
cost-model drift meter) see every span as it is recorded.

``record_request_spans`` is the one place the per-request phase
decomposition is defined: fetch → queue → prefill → decode, clamped and
telescoping so the four child durations sum *exactly* to the root
request span (= measured TTFT + generation time) on both substrates.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Seconds since run start, on whatever substrate drives it."""

    def now(self) -> float: ...


class WallClock:
    """Wall-clock seconds since construction (the engine substrate's
    time domain — matches ``EngineBackend.wall_now``)."""

    def __init__(self):
        self._t0 = time.monotonic()

    def reset(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


class EventClock:
    """Manually-advanced virtual clock (the simulator's event-time
    domain). The host advances it; it never goes backwards."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def advance(self, t: float) -> None:
        if t > self.t:
            self.t = t

    def now(self) -> float:
        return self.t


class Span:
    """One named interval on the cluster clock.

    ``cat`` groups spans by kind: ``request`` (per-request phase
    decomposition), ``iteration`` (per-server prefill/decode batches),
    ``transfer`` (adapter-store data plane), ``gateway`` (HTTP front
    end + routing). ``track`` names the Perfetto row ("requests",
    "server:3", "store", "gateway", "control")."""

    __slots__ = ("name", "cat", "start", "end", "track", "req_id",
                 "span_id", "parent_id", "attrs")

    def __init__(self, name: str, start: float, end: float, *,
                 cat: str = "span", track: str = "",
                 req_id: Optional[int] = None, span_id: int = 0,
                 parent_id: Optional[int] = None,
                 attrs: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.start = start
        self.end = end
        self.track = track
        self.req_id = req_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs if attrs is not None else {}

    @property
    def duration(self) -> float:
        return self.end - self.start

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.start:.6f}->{self.end:.6f}, "
                f"cat={self.cat!r}, track={self.track!r}, "
                f"req={self.req_id})")


class Tracer:
    """Span sink shared by every component of one serving run.

    Keeps the full span list in memory by default (bounded by
    ``max_spans`` — oldest dropped first) and fans every span out to
    listeners (flight-recorder ring, drift meter, streaming writers).
    ``record`` is the only write path; it is deliberately allocation-
    light because the simulator calls it once per iteration."""

    def __init__(self, clock: Optional[Clock] = None, *,
                 keep_all: bool = True,
                 max_spans: Optional[int] = None):
        self.clock: Clock = clock if clock is not None else WallClock()
        self.keep_all = keep_all
        self.max_spans = max_spans
        self.spans: List[Span] = []
        self.n_spans = 0                 # total ever recorded
        self.dropped = 0                 # trimmed by max_spans
        self._listeners: List[Callable[[Span], None]] = []
        self._next_id = 1

    def now(self) -> float:
        return self.clock.now()

    def add_listener(self, fn: Callable[[Span], None]) -> None:
        if fn not in self._listeners:
            self._listeners.append(fn)

    def record(self, name: str, start: float, end: float, *,
               cat: str = "span", track: str = "",
               req_id: Optional[int] = None,
               parent: Optional[int] = None,
               attrs: Optional[dict] = None) -> Span:
        # hot path (once per sim/engine iteration): build the Span via
        # __new__ + direct slot stores instead of Span(...) — skipping
        # the __init__ call and kwarg re-binding is a ~25% saving on the
        # whole record cost, which is what keeps tracing-on inside the
        # <3% throughput budget (benchmarks/bench_obs.py)
        span = Span.__new__(Span)
        span.name = name
        span.cat = cat
        span.start = start
        span.end = end
        span.track = track
        span.req_id = req_id
        sid = self._next_id
        self._next_id = sid + 1
        span.span_id = sid
        span.parent_id = parent
        span.attrs = attrs if attrs is not None else {}
        self.n_spans += 1
        if self.keep_all:
            self.spans.append(span)
            if self.max_spans is not None \
                    and len(self.spans) > self.max_spans:
                cut = len(self.spans) - self.max_spans
                del self.spans[:cut]
                self.dropped += cut
        for fn in self._listeners:
            fn(span)
        return span

    # -- queries (tests / examples) --------------------------------------
    def by_request(self) -> Dict[int, List[Span]]:
        out: Dict[int, List[Span]] = {}
        for s in self.spans:
            if s.req_id is not None:
                out.setdefault(s.req_id, []).append(s)
        return out

    def named(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]


# -- the per-request phase decomposition ---------------------------------
REQUEST_PHASES = ("fetch", "queue", "prefill", "decode")


def record_request_spans(tracer: Tracer, req) -> Optional[Span]:
    """Emit the canonical span tree for one finished ``ServeRequest``:
    a root ``request`` span (arrival → finish) with four children —
    ``fetch`` (adapter data path), ``queue`` (admission wait),
    ``prefill``, ``decode`` — whose boundaries are clamped into the
    root so child durations telescope to *exactly* the root duration
    (= measured TTFT + generation time) on both substrates.

    Both the cluster facade and the standalone simulator call this
    one helper, which is what guarantees sim-vs-engine span-name
    parity. Returns None (and records nothing) for unfinished
    requests."""
    finish = req.finish
    if finish is None or finish < 0:
        return None
    t0 = req.arrival
    # monotone clamp: arrival <= ready <= prefill_start <= prefill_done
    # <= finish, whatever the raw stamps say (an engine admits before
    # `ready` under remote-read; a zero-output request never decodes)
    ready = min(max(req.ready, t0), finish)
    p_start = req.prefill_start if req.prefill_start >= 0 else ready
    p_start = min(max(p_start, ready), finish)
    p_done = req.prefill_done if req.prefill_done >= 0 else p_start
    p_done = min(max(p_done, p_start), finish)
    root = tracer.record(
        "request", t0, finish, cat="request", track="requests",
        req_id=req.req_id,
        attrs={"adapter_id": req.adapter_id, "rank": req.rank,
               "server": req.server, "prompt_len": req.prompt_len,
               "output_len": req.output_len})
    pid = root.span_id
    if req.remote_penalty > 0:
        fetch_mode = "remote-read"
    elif req.fetch_latency > 0:
        fetch_mode = "migrate"
    else:
        fetch_mode = "hit"
    tracer.record("fetch", t0, ready, cat="request", track="requests",
                  req_id=req.req_id, parent=pid,
                  attrs={"mode": fetch_mode,
                         "latency": req.fetch_latency})
    tracer.record("queue", ready, p_start, cat="request",
                  track="requests", req_id=req.req_id, parent=pid)
    tracer.record("prefill", p_start, p_done, cat="request",
                  track="requests", req_id=req.req_id, parent=pid,
                  attrs={"tokens": req.prompt_len})
    tracer.record("decode", p_done, finish, cat="request",
                  track="requests", req_id=req.req_id, parent=pid,
                  attrs={"tokens": max(0, req.decoded - 1)})
    return root
