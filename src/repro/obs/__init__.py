"""Span-based observability layer fed by both serving substrates.

``Tracer`` + ``Span`` + the ``Clock`` protocol are the core;
``export`` writes Perfetto/Chrome-trace JSON and JSONL;
``CostModelDrift`` tracks per-phase modeled-vs-measured iteration
error; ``FlightRecorder`` keeps a bounded ring of recent spans and
dumps it (with a controller-decision audit record) on SLO violations
and scale events.
"""
from .drift import CostModelDrift, predict_span_seconds
from .export import span_to_dict, to_perfetto, write_jsonl, write_perfetto
from .flight import FlightRecorder
from .trace import (Clock, EventClock, REQUEST_PHASES, Span, Tracer,
                    WallClock, record_request_spans)

__all__ = [
    "Clock", "CostModelDrift", "EventClock", "FlightRecorder",
    "REQUEST_PHASES", "Span", "Tracer", "WallClock",
    "predict_span_seconds", "record_request_spans", "span_to_dict",
    "to_perfetto", "write_jsonl", "write_perfetto",
]
