"""Trace export: Perfetto/Chrome-trace JSON and a compact JSONL log.

The Chrome trace event format (loadable in Perfetto / chrome://tracing)
wants complete events (``ph: "X"``) with microsecond ``ts``/``dur`` and
a ``pid``/``tid`` pair naming the row. We map tracer tracks to stable
pids so a sim trace and an engine trace of the same workload land on
the same visual layout:

* ``requests``  -> pid 1, one tid per request id
* ``gateway`` / ``control`` -> pid 2
* ``store``     -> pid 3
* ``server:N``  -> pid 10 + N

The JSONL exporter writes one self-contained dict per span — grep- and
pandas-friendly, and the format the flight recorder's audit records sit
next to.
"""
from __future__ import annotations

import json
from typing import Iterable, List

from .trace import Span, Tracer

_PID_REQUESTS = 1
_PID_CONTROL = 2
_PID_STORE = 3
_PID_SERVER_BASE = 10

_PROCESS_NAMES = {
    _PID_REQUESTS: "requests",
    _PID_CONTROL: "gateway/control",
    _PID_STORE: "adapter-store",
}


def _track_pid_tid(span: Span) -> tuple:
    track = span.track
    if track.startswith("server:"):
        try:
            n = int(track.split(":", 1)[1])
        except ValueError:
            n = 0
        return _PID_SERVER_BASE + n, 0
    if track == "store":
        return _PID_STORE, 0
    if track in ("gateway", "control"):
        return _PID_CONTROL, 0
    # requests (and anything unrecognised): one row per request
    tid = span.req_id if span.req_id is not None else 0
    return _PID_REQUESTS, tid


def span_to_dict(span: Span) -> dict:
    """Self-contained JSONL record for one span (seconds, not µs)."""
    return {
        "name": span.name,
        "cat": span.cat,
        "track": span.track,
        "start": span.start,
        "end": span.end,
        "dur": span.end - span.start,
        "req_id": span.req_id,
        "span_id": span.span_id,
        "parent_id": span.parent_id,
        "attrs": span.attrs,
    }


def to_perfetto(tracer_or_spans) -> dict:
    """Chrome-trace JSON object: ``{"traceEvents": [...]}`` with one
    ``ph:"X"`` complete event per span plus ``ph:"M"`` process-name
    metadata for every pid used."""
    events: List[dict] = []
    pids = {}
    for span in _as_spans(tracer_or_spans):
        pid, tid = _track_pid_tid(span)
        if pid not in pids:
            if pid >= _PID_SERVER_BASE:
                pids[pid] = f"server:{pid - _PID_SERVER_BASE}"
            else:
                pids[pid] = _PROCESS_NAMES.get(pid, f"pid:{pid}")
        ev = {
            "name": span.name,
            "cat": span.cat,
            "ph": "X",
            "ts": span.start * 1e6,
            "dur": max(0.0, span.end - span.start) * 1e6,
            "pid": pid,
            "tid": tid,
        }
        args = dict(span.attrs) if span.attrs else {}
        args["span_id"] = span.span_id
        if span.parent_id is not None:
            args["parent_id"] = span.parent_id
        if span.req_id is not None:
            args["req_id"] = span.req_id
        ev["args"] = args
        events.append(ev)
    meta = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": label}}
        for pid, label in sorted(pids.items())
    ]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_perfetto(tracer_or_spans, path: str) -> int:
    """Dump spans as Perfetto-loadable JSON; returns the span count."""
    spans = _as_spans(tracer_or_spans)
    doc = to_perfetto(spans)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(spans)


def write_jsonl(tracer_or_spans, path: str) -> int:
    """Dump spans as one-JSON-dict-per-line; returns the span count."""
    spans = _as_spans(tracer_or_spans)
    with open(path, "w") as f:
        for span in spans:
            f.write(json.dumps(span_to_dict(span)))
            f.write("\n")
    return len(spans)


def _as_spans(tracer_or_spans) -> List[Span]:
    if isinstance(tracer_or_spans, Tracer):
        return list(tracer_or_spans.spans)
    return list(tracer_or_spans)
