"""Pallas TPU flash attention (causal block-skip).

Addresses the §Roofline finding that prefill MODEL/HLO FLOPs sits at
~0.5: the pure-JAX flash scan computes every (q-block, kv-block) pair and
masks, paying 2x the causal FLOPs. This kernel's grid runs (B, H, nq, nk)
with the *fully-masked* kv blocks skipped via ``pl.when`` predication —
the MXU never sees them — and the online-softmax state (m, l, acc) kept
in VMEM scratch across the sequential nk dimension.

VMEM per grid step (fp32): q/k/v blocks (block_q + 2*block_k) * hd
+ scratch (block_q * (hd + 2)); at block_q = block_k = 256, hd = 128:
~0.5 MB — far under the ~16 MB/core budget, and all matmul dims are
multiples of 128 (MXU-aligned) for the production head dims.

Kernel is MHA (H == Kv); the ops wrapper handles GQA by head-group
reshape. Validated in interpret mode against the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # causal block skip: kv block strictly above the diagonal band
    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_mha(q, k, v, *, causal: bool = True, scale: float = None,
              block_q: int = 128, block_k: int = 128,
              interpret=None):
    """q: (B, H, Sq, hd); k, v: (B, H, Sk, hd). Returns (B, H, Sq, hd)."""
    interpret = resolve_interpret(interpret)
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // bq
    nk = (Sk + pad_k) // bk

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=bq,
        block_k=bk, seq_q=Sq, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]


def flash_mha_ref(q, k, v, *, causal: bool = True, scale: float = None):
    """Pure-jnp oracle: full materialized softmax attention."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
