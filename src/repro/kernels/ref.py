"""Pure-jnp oracle for the SGMV (segmented gather matrix-multiply) kernels.

Semantics match Punica's SGMV / S-LoRA's MBGMV: every token gathers the
A/B matrices of *its* adapter from a bank padded to the bank-wide max
rank, so low-rank adapters pay max-rank compute (the padding tax the
paper analyzes).
"""
from __future__ import annotations

import jax.numpy as jnp


def sgmv_ref(x, A, B, token_adapter, scaling: float = 1.0):
    """x: (T, d_in); A: (Na, d_in, r); B: (Na, r, d_out);
    token_adapter: (T,) int32. Returns (T, d_out)."""
    a = A[token_adapter]                       # (T, d_in, r)
    b = B[token_adapter]                       # (T, r, d_out)
    h = jnp.einsum("td,tdr->tr", x, a.astype(x.dtype))
    y = jnp.einsum("tr,tro->to", h, b.astype(x.dtype))
    return y * scaling


def sgmv_shrink_ref(x, A, token_adapter):
    a = A[token_adapter]
    return jnp.einsum("td,tdr->tr", x, a.astype(x.dtype))


def sgmv_expand_ref(h, B, token_adapter, scaling: float = 1.0):
    b = B[token_adapter]
    return jnp.einsum("tr,tro->to", h, b.astype(h.dtype)) * scaling
