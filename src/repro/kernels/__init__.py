"""SGMV LoRA kernels — the compute hot-spot the paper's systems (Punica /
S-LoRA) optimize with custom kernels, adapted TPU-native (DESIGN.md §3)."""
from .flash import flash_mha, flash_mha_ref
from .ops import (bgmv, prepare_segments, sgmv, sgmv_rank_bucketed,
                  sgmv_reference)
from .ref import sgmv_expand_ref, sgmv_ref, sgmv_shrink_ref
from .sgmv import sgmv_expand, sgmv_shrink

__all__ = ["sgmv", "bgmv", "sgmv_rank_bucketed", "prepare_segments",
           "sgmv_reference", "sgmv_ref", "sgmv_shrink_ref",
           "sgmv_expand_ref", "sgmv_shrink", "sgmv_expand",
           "flash_mha", "flash_mha_ref"]
