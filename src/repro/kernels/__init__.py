"""SGMV LoRA kernels — the compute hot-spot the paper's systems (Punica /
S-LoRA) optimize with custom kernels, adapted TPU-native (DESIGN.md §3)."""


def default_interpret() -> bool:
    """Pallas execution mode resolved from the JAX backend: compiled on
    TPU, interpreted elsewhere (CPU/GPU test rigs). Every kernel entry
    point defaults its ``interpret`` arg to None and resolves through
    here, so TPU runs never silently fall back to the interpreter."""
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


from .flash import flash_mha, flash_mha_ref  # noqa: E402
from .ops import (bgmv, prepare_segments, prepare_segments_bucketed,  # noqa: E402
                  sgmv, sgmv_bucketed_fused, sgmv_fused,
                  sgmv_rank_bucketed, sgmv_reference)
from .ref import sgmv_expand_ref, sgmv_ref, sgmv_shrink_ref  # noqa: E402
from .sgmv import (sgmv_expand, sgmv_fused_blocks,  # noqa: E402
                   sgmv_multibank_blocks, sgmv_shrink)

__all__ = ["sgmv", "bgmv", "sgmv_fused", "sgmv_rank_bucketed",
           "sgmv_bucketed_fused", "prepare_segments",
           "prepare_segments_bucketed", "sgmv_reference", "sgmv_ref",
           "sgmv_shrink_ref", "sgmv_expand_ref", "sgmv_shrink",
           "sgmv_expand", "sgmv_fused_blocks", "sgmv_multibank_blocks",
           "flash_mha", "flash_mha_ref", "default_interpret",
           "resolve_interpret"]
