"""jit'd wrappers around the SGMV kernels: segment preparation (sort by
adapter, pad segments to whole blocks), kernel dispatch, and scatter-back.

``sgmv`` is the full LoRA delta y = (x @ A[aid]) @ B[aid] * scaling for a
ragged multi-adapter token batch. ``bgmv`` is the decode special case
(block_t=1, one token per block — Punica's BGMV).

A beyond-paper optimization lives here too: ``sgmv_rank_bucketed``
dispatches each rank *bucket* with its own bank slice, avoiding the
max-rank padding tax the paper identifies in BGMV/MBGMV (§Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import sgmv_ref
from .sgmv import sgmv_expand, sgmv_shrink


@functools.partial(jax.jit, static_argnames=("n_adapters", "block_t"))
def prepare_segments(token_adapter, n_adapters: int, block_t: int = 16):
    """Sort tokens by adapter; give each adapter a whole number of
    ``block_t`` blocks.

    Returns (dest, block_adapter, T_pad):
      dest          : (T,) position of each (original-order) token in the
                      padded, segment-blocked layout
      block_adapter : (T_pad//block_t,) adapter id per block
    T_pad is static: T rounded up + one spare block per adapter.
    """
    T = token_adapter.shape[0]
    T_pad = padded_len(T, n_adapters, block_t)
    order = jnp.argsort(token_adapter)                   # stable
    aid_s = token_adapter[order]
    counts = jnp.bincount(token_adapter, length=n_adapters)
    padded = ((counts + block_t - 1) // block_t) * block_t
    offs = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                            jnp.cumsum(padded)[:-1]])
    rank = jnp.arange(T) - (jnp.cumsum(counts) - counts)[aid_s]
    dest_sorted = offs[aid_s] + rank                     # (T,)
    dest = jnp.zeros((T,), jnp.int32).at[order].set(
        dest_sorted.astype(jnp.int32))
    nblocks = T_pad // block_t
    block_adapter = jnp.zeros((nblocks,), jnp.int32).at[
        (dest_sorted // block_t).astype(jnp.int32)].set(
            aid_s.astype(jnp.int32))
    return dest, block_adapter


def padded_len(T: int, n_adapters: int, block_t: int) -> int:
    """Static padded token count: every adapter may waste < block_t slots."""
    return T + n_adapters * block_t


@functools.partial(jax.jit, static_argnames=("block_t", "interpret",
                                             "scaling"))
def sgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         block_t: int = 16, interpret: bool = True):
    """x: (T, d_in); A: (Na, d_in, r); B: (Na, r, d_out);
    token_adapter: (T,). Returns (T, d_out)."""
    T, d = x.shape
    Na = A.shape[0]
    dest, block_adapter = prepare_segments(token_adapter, Na, block_t)
    T_pad = padded_len(T, Na, block_t)
    x_pad = jnp.zeros((T_pad, d), x.dtype).at[dest].set(x)
    h = sgmv_shrink(x_pad, A, block_adapter, block_t=block_t,
                    interpret=interpret)
    y_pad = sgmv_expand(h, B, block_adapter, block_t=block_t,
                        interpret=interpret)
    return y_pad[dest] * scaling


def bgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         interpret: bool = True):
    """Decode-time per-token gather (Punica BGMV): block_t = 1."""
    return sgmv(x, A, B, token_adapter, scaling=scaling, block_t=1,
                interpret=interpret)


def sgmv_rank_bucketed(x, banks, token_adapter, adapter_rank_bucket,
                       *, adapter_local=None, scaling: float = 1.0,
                       block_t: int = 16, interpret: bool = True):
    """Beyond-paper optimization: group adapters into rank buckets, each
    with its own (A, B) bank pair at its *bucket* rank, so a rank-8 token
    batched with a rank-128 token pays rank-8 compute, not rank-128.

    banks: list of (A_i, B_i) per bucket; adapter_rank_bucket: (Na,) int
    mapping adapter -> bucket; adapter_local: optional (Na,) mapping
    adapter -> its row within its bucket's bank (None means every bucket
    bank is indexed by the global adapter id, i.e. full-width banks).

    Host-level dispatcher (``token_adapter`` must be concrete, like the
    engine's per-iteration slot indices): each bucket's tokens are
    *compacted* into a dense sub-batch and only that sub-batch runs
    through the SGMV kernels at the bucket's rank, then scatters back.
    Total FLOPs = sum_b T_b * (d*r_b + r_b*o) — each token pays its own
    bucket — instead of the padded bank's T * max_r * (d+o).
    """
    import numpy as np
    T, d = x.shape
    d_out = banks[0][1].shape[-1]
    tok_adapter = np.asarray(token_adapter)
    tok_bucket = np.asarray(adapter_rank_bucket)[tok_adapter]
    local = tok_adapter if adapter_local is None else \
        np.asarray(adapter_local)[tok_adapter]
    out = jnp.zeros((T, d_out), x.dtype)
    for i, (A, B) in enumerate(banks):
        sel = np.nonzero(tok_bucket == i)[0]
        if sel.size == 0:
            continue
        y = sgmv(x[sel], A, B, jnp.asarray(local[sel], jnp.int32),
                 scaling=scaling, block_t=block_t, interpret=interpret)
        out = out.at[sel].set(y.astype(out.dtype))
    return out


def sgmv_reference(x, A, B, token_adapter, scaling: float = 1.0):
    """Exported oracle (tests compare kernels against this)."""
    return sgmv_ref(x, A, B, token_adapter, scaling)
