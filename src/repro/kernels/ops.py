"""jit'd wrappers around the SGMV kernels: segment preparation (sort by
adapter, pad segments to whole blocks), kernel dispatch, and scatter-back.

``sgmv`` is the full LoRA delta y = (x @ A[aid]) @ B[aid] * scaling for a
ragged multi-adapter token batch; ``sgmv_fused`` is the same contract on
the fused shrink+expand kernel (one dispatch, no HBM round-trip for the
rank-r intermediate). ``bgmv`` is the decode special case (block_t=1,
one token per block — Punica's BGMV).

Rank-bucketed dispatch (beyond-paper, avoiding the max-rank padding tax
the paper identifies in BGMV/MBGMV batches, §Perf) comes in two forms:

* ``sgmv_rank_bucketed`` — the legacy host-side dispatcher: syncs
  ``token_adapter`` to host, compacts each bucket's tokens and launches
  a shrink+expand pair per bucket (2·n_buckets dispatches, not
  traceable under jit);
* ``sgmv_bucketed_fused`` — the v2 path: ``prepare_segments_bucketed``
  sorts tokens bucket-major (by (bucket, adapter)) ON DEVICE, and one
  fused multi-bank kernel sweep serves every bucket at its own rank
  (1 dispatch, fully jittable, stable trace across iterations — no host
  sync, no per-bucket Python loop). Outputs are bit-identical to the
  host-loop path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import resolve_interpret
from . import tune
from .ref import sgmv_ref
from .sgmv import (sgmv_expand, sgmv_fused_blocks, sgmv_multibank_blocks,
                   sgmv_shrink)


def _prepare_core(token_adapter, key, n_keys: int, block_t: int,
                  T_pad: int):
    """Shared segment layout: sort tokens by ``key``, give each key a
    whole number of ``block_t`` blocks. Returns (dest, block_adapter)
    where ``block_adapter`` holds the *adapter id* of each block."""
    T = token_adapter.shape[0]
    order = jnp.argsort(key)                             # stable
    aid_s = token_adapter[order]
    key_s = key[order]
    counts = jnp.bincount(key, length=n_keys)
    padded = ((counts + block_t - 1) // block_t) * block_t
    offs = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                            jnp.cumsum(padded)[:-1]])
    rank = jnp.arange(T) - (jnp.cumsum(counts) - counts)[key_s]
    dest_sorted = offs[key_s] + rank                     # (T,)
    dest = jnp.zeros((T,), jnp.int32).at[order].set(
        dest_sorted.astype(jnp.int32))
    nblocks = T_pad // block_t
    block_adapter = jnp.zeros((nblocks,), jnp.int32).at[
        (dest_sorted // block_t).astype(jnp.int32)].set(
            aid_s.astype(jnp.int32))
    return dest, block_adapter


@functools.partial(jax.jit, static_argnames=("n_adapters", "block_t"))
def prepare_segments(token_adapter, n_adapters: int, block_t: int = 16):
    """Sort tokens by adapter; give each adapter a whole number of
    ``block_t`` blocks.

    Returns (dest, block_adapter):
      dest          : (T,) position of each (original-order) token in the
                      padded, segment-blocked layout
      block_adapter : (T_pad//block_t,) adapter id per block
    T_pad is static: T rounded up + one spare block per adapter.
    """
    T = token_adapter.shape[0]
    T_pad = padded_len(T, n_adapters, block_t)
    return _prepare_core(token_adapter, token_adapter, n_adapters,
                         block_t, T_pad)


@functools.partial(jax.jit, static_argnames=("n_adapters", "n_buckets",
                                             "block_t"))
def prepare_segments_bucketed(token_adapter, adapter_bucket,
                              n_adapters: int, n_buckets: int = 1,
                              block_t: int = 16):
    """Bucket-major generalization: tokens sorted by (bucket, adapter)
    so each rank bucket's blocks are contiguous, fully on device (no
    host sync of ``token_adapter``). Same return contract and the same
    static T_pad as ``prepare_segments`` — every adapter still belongs
    to exactly one (bucket, adapter) key, so at most ``n_adapters``
    partial blocks exist."""
    T = token_adapter.shape[0]
    T_pad = padded_len(T, n_adapters, block_t)
    key = adapter_bucket[token_adapter] * n_adapters + token_adapter
    return _prepare_core(token_adapter, key, n_buckets * n_adapters,
                         block_t, T_pad)


def padded_len(T: int, n_adapters: int, block_t: int) -> int:
    """Static padded token count: every adapter may waste < block_t slots."""
    return T + n_adapters * block_t


@functools.partial(jax.jit, static_argnames=("block_t", "interpret",
                                             "scaling"))
def sgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         block_t: int = 16, interpret=None):
    """x: (T, d_in); A: (Na, d_in, r); B: (Na, r, d_out);
    token_adapter: (T,). Returns (T, d_out)."""
    T, d = x.shape
    Na = A.shape[0]
    dest, block_adapter = prepare_segments(token_adapter, Na, block_t)
    T_pad = padded_len(T, Na, block_t)
    x_pad = jnp.zeros((T_pad, d), x.dtype).at[dest].set(x)
    h = sgmv_shrink(x_pad, A, block_adapter, block_t=block_t,
                    interpret=interpret)
    y_pad = sgmv_expand(h, B, block_adapter, block_t=block_t,
                        interpret=interpret)
    return y_pad[dest] * scaling


@functools.partial(jax.jit, static_argnames=("block_t", "interpret",
                                             "scaling"))
def sgmv_fused(x, A, B, token_adapter, *, scaling: float = 1.0,
               block_t: int = 16, interpret=None):
    """``sgmv`` on the fused shrink+expand kernel: one dispatch, the
    (block_t, r) intermediate never leaves VMEM. Bit-identical outputs
    to ``sgmv`` (the scratch mirrors the unfused inter-kernel cast)."""
    T, d = x.shape
    Na = A.shape[0]
    dest, block_adapter = prepare_segments(token_adapter, Na, block_t)
    T_pad = padded_len(T, Na, block_t)
    x_pad = jnp.zeros((T_pad, d), x.dtype).at[dest].set(x)
    y_pad = sgmv_fused_blocks(x_pad, A, B, block_adapter, block_t=block_t,
                              interpret=interpret)
    return y_pad[dest] * scaling


def bgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         interpret=None):
    """Decode-time per-token gather (Punica BGMV): block_t = 1."""
    return sgmv(x, A, B, token_adapter, scaling=scaling, block_t=1,
                interpret=interpret)


def sgmv_rank_bucketed(x, banks, token_adapter, adapter_rank_bucket,
                       *, adapter_local=None, scaling: float = 1.0,
                       block_t: int = 16, interpret=None):
    """Legacy host-side rank-bucketed dispatcher (kept as the oracle the
    fused path is bit-compared against): group adapters into rank
    buckets, each with its own (A, B) bank pair at its *bucket* rank, so
    a rank-8 token batched with a rank-128 token pays rank-8 compute,
    not rank-128.

    banks: list of (A_i, B_i) per bucket; adapter_rank_bucket: (Na,) int
    mapping adapter -> bucket; adapter_local: optional (Na,) mapping
    adapter -> its row within its bucket's bank (None means every bucket
    bank is indexed by the global adapter id, i.e. full-width banks).

    Host-level dispatcher (``token_adapter`` must be concrete, like the
    engine's per-iteration slot indices): each bucket's tokens are
    *compacted* into a dense sub-batch and only that sub-batch runs
    through the SGMV kernels at the bucket's rank, then scatters back.
    Total FLOPs = sum_b T_b * (d*r_b + r_b*o) — each token pays its own
    bucket — instead of the padded bank's T * max_r * (d+o). Costs one
    host sync plus 2 kernel launches per non-empty bucket; prefer
    ``sgmv_bucketed_fused`` on the hot path.
    """
    import numpy as np
    T, d = x.shape
    d_out = banks[0][1].shape[-1]
    tok_adapter = np.asarray(token_adapter)
    tok_bucket = np.asarray(adapter_rank_bucket)[tok_adapter]
    local = tok_adapter if adapter_local is None else \
        np.asarray(adapter_local)[tok_adapter]
    out = jnp.zeros((T, d_out), x.dtype)
    for i, (A, B) in enumerate(banks):
        sel = np.nonzero(tok_bucket == i)[0]
        if sel.size == 0:
            continue
        y = sgmv(x[sel], A, B, jnp.asarray(local[sel], jnp.int32),
                 scaling=scaling, block_t=block_t, interpret=interpret)
        out = out.at[sel].set(y.astype(out.dtype))
    return out


@functools.partial(jax.jit, static_argnames=("block_t", "resident",
                                             "interpret", "scaling"))
def sgmv_bucketed_fused(x, banks, token_adapter, adapter_bucket,
                        adapter_local=None, *, scaling: float = 1.0,
                        block_t=None, resident=None, interpret=None):
    """Single-dispatch rank-bucketed SGMV: the whole LoRA delta for a
    heterogeneous batch as ONE traced kernel sweep.

    Same contract as ``sgmv_rank_bucketed`` (bit-identical outputs), but
    ``token_adapter`` stays on device: ``prepare_segments_bucketed``
    lays tokens out bucket-major, per-block (bucket, bank-row) metadata
    is scalar-prefetched, and each block's dots run at its own bucket's
    rank inside one kernel. Fully jittable — the trace is stable across
    engine iterations for a fixed bank signature.

    block_t=None / resident=None pick the block geometry from
    ``kernels.tune.block_plan`` — the per-bucket (T_b, r_b, d) heuristic
    table plus the bank-residency budget, memoized per bank signature.
    Pass explicit values to pin a geometry (benchmarks, tests).
    """
    T, d = x.shape
    banks = tuple((A, B) for A, B in banks)
    Na = adapter_bucket.shape[0]
    nb = len(banks)
    if block_t is None or resident is None:
        plan = tune.block_plan(
            T, d, banks[0][1].shape[-1],
            tuple(A.shape[-1] for A, _ in banks),
            tuple(A.shape[0] for A, _ in banks))
        block_t = plan.block_t if block_t is None else block_t
        resident = plan.resident if resident is None else resident
    token_adapter = jnp.asarray(token_adapter, jnp.int32)
    dest, block_adapter = prepare_segments_bucketed(
        token_adapter, adapter_bucket, Na, nb, block_t)
    local = jnp.arange(Na, dtype=jnp.int32) if adapter_local is None \
        else jnp.asarray(adapter_local, jnp.int32)
    block_bucket = jnp.asarray(adapter_bucket, jnp.int32)[block_adapter]
    block_row = local[block_adapter]
    T_pad = padded_len(T, Na, block_t)
    x_pad = jnp.zeros((T_pad, d), x.dtype).at[dest].set(x)
    y_pad = sgmv_multibank_blocks(x_pad, banks, block_bucket, block_row,
                                  block_t=block_t, resident=resident,
                                  interpret=interpret)
    return y_pad[dest] * scaling


def sgmv_reference(x, A, B, token_adapter, scaling: float = 1.0):
    """Exported oracle (tests compare kernels against this)."""
    return sgmv_ref(x, A, B, token_adapter, scaling)
