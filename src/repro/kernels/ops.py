"""jit'd wrappers around the SGMV kernels: segment preparation (sort by
adapter, pad segments to whole blocks), kernel dispatch, and scatter-back.

``sgmv`` is the full LoRA delta y = (x @ A[aid]) @ B[aid] * scaling for a
ragged multi-adapter token batch. ``bgmv`` is the decode special case
(block_t=1, one token per block — Punica's BGMV).

A beyond-paper optimization lives here too: ``sgmv_rank_bucketed``
dispatches each rank *bucket* with its own bank slice, avoiding the
max-rank padding tax the paper identifies in BGMV/MBGMV (§Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import sgmv_ref
from .sgmv import sgmv_expand, sgmv_shrink


@functools.partial(jax.jit, static_argnames=("n_adapters", "block_t"))
def prepare_segments(token_adapter, n_adapters: int, block_t: int = 16):
    """Sort tokens by adapter; give each adapter a whole number of
    ``block_t`` blocks.

    Returns (dest, block_adapter, T_pad):
      dest          : (T,) position of each (original-order) token in the
                      padded, segment-blocked layout
      block_adapter : (T_pad//block_t,) adapter id per block
    T_pad is static: T rounded up + one spare block per adapter.
    """
    T = token_adapter.shape[0]
    T_pad = padded_len(T, n_adapters, block_t)
    order = jnp.argsort(token_adapter)                   # stable
    aid_s = token_adapter[order]
    counts = jnp.bincount(token_adapter, length=n_adapters)
    padded = ((counts + block_t - 1) // block_t) * block_t
    offs = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                            jnp.cumsum(padded)[:-1]])
    rank = jnp.arange(T) - (jnp.cumsum(counts) - counts)[aid_s]
    dest_sorted = offs[aid_s] + rank                     # (T,)
    dest = jnp.zeros((T,), jnp.int32).at[order].set(
        dest_sorted.astype(jnp.int32))
    nblocks = T_pad // block_t
    block_adapter = jnp.zeros((nblocks,), jnp.int32).at[
        (dest_sorted // block_t).astype(jnp.int32)].set(
            aid_s.astype(jnp.int32))
    return dest, block_adapter


def padded_len(T: int, n_adapters: int, block_t: int) -> int:
    """Static padded token count: every adapter may waste < block_t slots."""
    return T + n_adapters * block_t


@functools.partial(jax.jit, static_argnames=("block_t", "interpret",
                                             "scaling"))
def sgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         block_t: int = 16, interpret: bool = True):
    """x: (T, d_in); A: (Na, d_in, r); B: (Na, r, d_out);
    token_adapter: (T,). Returns (T, d_out)."""
    T, d = x.shape
    Na = A.shape[0]
    dest, block_adapter = prepare_segments(token_adapter, Na, block_t)
    T_pad = padded_len(T, Na, block_t)
    x_pad = jnp.zeros((T_pad, d), x.dtype).at[dest].set(x)
    h = sgmv_shrink(x_pad, A, block_adapter, block_t=block_t,
                    interpret=interpret)
    y_pad = sgmv_expand(h, B, block_adapter, block_t=block_t,
                        interpret=interpret)
    return y_pad[dest] * scaling


def bgmv(x, A, B, token_adapter, *, scaling: float = 1.0,
         interpret: bool = True):
    """Decode-time per-token gather (Punica BGMV): block_t = 1."""
    return sgmv(x, A, B, token_adapter, scaling=scaling, block_t=1,
                interpret=interpret)


def sgmv_rank_bucketed(x, banks, token_adapter, adapter_rank_bucket,
                       *, scaling: float = 1.0, block_t: int = 16,
                       interpret: bool = True):
    """Beyond-paper optimization: group adapters into rank buckets, each
    with its own (A, B) bank pair at its *bucket* rank, so a rank-8 token
    batched with a rank-128 token pays rank-8 compute, not rank-128.

    banks: list of (A_i, B_i) per bucket; adapter_rank_bucket: (Na,) int
    mapping adapter -> bucket. Zero rows keep shapes static: every bucket
    processes the full token set, but with tokens of other buckets routed
    to a zero adapter slot — compute per bucket is at bucket rank.
    Total FLOPs = sum_b T * (d*r_b + r_b*o) instead of T * max_r * (d+o).
    """
    T, d = x.shape
    out = None
    tok_bucket = adapter_rank_bucket[token_adapter]
    for i, (A, B) in enumerate(banks):
        # adapter id within the bucket bank; tokens of other buckets -> 0
        in_bucket = tok_bucket == i
        local = jnp.where(in_bucket, token_adapter, 0)
        y = sgmv(jnp.where(in_bucket[:, None], x, 0), A, B, local,
                 scaling=scaling, block_t=block_t, interpret=interpret)
        y = jnp.where(in_bucket[:, None], y, 0)
        out = y if out is None else out + y
    return out


def sgmv_reference(x, A, B, token_adapter, scaling: float = 1.0):
    """Exported oracle (tests compare kernels against this)."""
    return sgmv_ref(x, A, B, token_adapter, scaling)
