"""Pallas TPU SGMV kernels (shrink + expand), the TPU-native adaptation of
Punica's segmented-gather GEMM (DESIGN.md §3).

Layout contract (established by ``ops.prepare_segments``): tokens are
sorted by adapter and padded so each adapter's segment occupies whole
``block_t``-row blocks. The per-block adapter id array is **scalar
prefetched** — ``BlockSpec.index_map`` reads it to gather the right A/B
slice from the HBM-resident bank into VMEM, so each grid step runs a
dense (block_t × d) × (d × r) MXU matmul with zero gather overhead in the
inner loop. Everything is padded to the bank max rank — faithfully
reproducing the max-rank tax of BGMV/MBGMV batches.

VMEM budget per grid step (fp32):
  shrink: block_t*d + d*r + block_t*r       (d=8192, r=128: ~4.3 MB)
  expand: block_t*r + r*block_o + block_t*block_o (block_o=2048: ~1.3 MB)
Both well under the ~16 MB/core VMEM of TPU v5e; block shapes keep the
MXU dims at multiples of 128 where the model dims allow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _shrink_kernel(aid_ref, x_ref, a_ref, o_ref):
    x = x_ref[...]                                   # (bt, d)
    a = a_ref[0]                                     # (d, r)
    o_ref[...] = jnp.dot(
        x, a, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _expand_kernel(aid_ref, h_ref, b_ref, o_ref):
    h = h_ref[...]                                   # (bt, r)
    b = b_ref[0]                                     # (r, bo)
    o_ref[...] = jnp.dot(
        h, b, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def sgmv_shrink(x_pad, A, block_adapter, *, block_t: int = 16,
                interpret: bool = True):
    """x_pad: (T_pad, d) segment-blocked; A: (Na, d, r);
    block_adapter: (nblocks,) int32. Returns (T_pad, r)."""
    T_pad, d = x_pad.shape
    Na, _, r = A.shape
    nblocks = T_pad // block_t
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((block_t, d), lambda i, aid: (i, 0)),
                pl.BlockSpec((1, d, r), lambda i, aid: (aid[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, r), lambda i, aid: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, r), x_pad.dtype),
        interpret=interpret,
    )(block_adapter, x_pad, A)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "interpret"))
def sgmv_expand(h_pad, B, block_adapter, *, block_t: int = 16,
                block_o: int = 2048, interpret: bool = True):
    """h_pad: (T_pad, r); B: (Na, r, d_out). Returns (T_pad, d_out)."""
    T_pad, r = h_pad.shape
    Na, _, d_out = B.shape
    bo = min(block_o, d_out)
    # pad d_out to a multiple of bo
    pad_o = (-d_out) % bo
    Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_o)))
    n_ob = (d_out + pad_o) // bo
    nblocks = T_pad // block_t
    out = pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks, n_ob),
            in_specs=[
                pl.BlockSpec((block_t, r), lambda i, j, aid: (i, 0)),
                pl.BlockSpec((1, r, bo), lambda i, j, aid: (aid[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((block_t, bo),
                                   lambda i, j, aid: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, d_out + pad_o), h_pad.dtype),
        interpret=interpret,
    )(block_adapter, h_pad, Bp)
    return out[:, :d_out]
