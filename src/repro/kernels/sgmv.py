"""Pallas TPU SGMV kernels (shrink + expand), the TPU-native adaptation of
Punica's segmented-gather GEMM (DESIGN.md §3).

Layout contract (established by ``ops.prepare_segments``): tokens are
sorted by adapter and padded so each adapter's segment occupies whole
``block_t``-row blocks. The per-block adapter id array is **scalar
prefetched** — ``BlockSpec.index_map`` reads it to gather the right A/B
slice from the HBM-resident bank into VMEM, so each grid step runs a
dense (block_t × d) × (d × r) MXU matmul with zero gather overhead in the
inner loop. Everything is padded to the bank max rank — faithfully
reproducing the max-rank tax of BGMV/MBGMV batches.

VMEM budget per grid step (fp32):
  shrink: block_t*d + d*r + block_t*r       (d=8192, r=128: ~4.3 MB)
  expand: block_t*r + r*block_o + block_t*block_o (block_o=2048: ~1.3 MB)
Both well under the ~16 MB/core VMEM of TPU v5e; block shapes keep the
MXU dims at multiples of 128 where the model dims allow. Caveat found
by ``repro.analysis.vmem``: the multibank kernel double-buffers every
bucket's A/B blocks, so a full 5-bucket bank set at d=8192 fits the
budget only at bf16 (~11 MB) — fp32 (~20 MB) is over it, which is fine
for the CPU interpret-mode paths (no VMEM there) but means compiled
TPU runs must use bf16 banks or fewer co-dispatched buckets.

``sgmv_fused_blocks`` fuses the pair: one grid sweep computes the
(block_t, r) shrink product into a VMEM scratch at the first output
block of each token block and expands it over the output blocks while it
is still resident — the rank-r intermediate never round-trips HBM and
the dispatch count halves. ``sgmv_multibank_blocks`` generalizes that to
a whole rank-bucketed bank set in ONE dispatch: per-block scalar-
prefetched (bucket, bank-row) metadata steers each token block to its
own bucket's A/B pair, and the kernel body branches (``pl.when``) to a
dot at that bucket's OWN rank, so a rank-8 block pays rank-8 compute
co-dispatched with rank-128 blocks. Non-matching buckets' index maps
clamp to row 0 — with the bucket-major token layout consecutive grid
steps then re-request the same block and the pipeline elides the fetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret


def _shrink_kernel(aid_ref, x_ref, a_ref, o_ref):
    x = x_ref[...]                                   # (bt, d)
    a = a_ref[0]                                     # (d, r)
    o_ref[...] = jnp.dot(
        x, a, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _expand_kernel(aid_ref, h_ref, b_ref, o_ref):
    h = h_ref[...]                                   # (bt, r)
    b = b_ref[0]                                     # (r, bo)
    o_ref[...] = jnp.dot(
        h, b, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def sgmv_shrink(x_pad, A, block_adapter, *, block_t: int = 16,
                interpret=None):
    """x_pad: (T_pad, d) segment-blocked; A: (Na, d, r);
    block_adapter: (nblocks,) int32. Returns (T_pad, r)."""
    interpret = resolve_interpret(interpret)
    T_pad, d = x_pad.shape
    Na, _, r = A.shape
    nblocks = T_pad // block_t
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((block_t, d), lambda i, aid: (i, 0)),
                pl.BlockSpec((1, d, r), lambda i, aid: (aid[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, r), lambda i, aid: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, r), x_pad.dtype),
        interpret=interpret,
    )(block_adapter, x_pad, A)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "interpret"))
def sgmv_expand(h_pad, B, block_adapter, *, block_t: int = 16,
                block_o: int = 2048, interpret=None):
    """h_pad: (T_pad, r); B: (Na, r, d_out). Returns (T_pad, d_out)."""
    interpret = resolve_interpret(interpret)
    T_pad, r = h_pad.shape
    Na, _, d_out = B.shape
    bo = min(block_o, d_out)
    # pad d_out to a multiple of bo
    pad_o = (-d_out) % bo
    Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_o)))
    n_ob = (d_out + pad_o) // bo
    nblocks = T_pad // block_t
    out = pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks, n_ob),
            in_specs=[
                pl.BlockSpec((block_t, r), lambda i, j, aid: (i, 0)),
                pl.BlockSpec((1, r, bo), lambda i, j, aid: (aid[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((block_t, bo),
                                   lambda i, j, aid: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, d_out + pad_o), h_pad.dtype),
        interpret=interpret,
    )(block_adapter, h_pad, Bp)
    return out[:, :d_out]


# ---------------------------------------------------------------------------
# Fused shrink+expand
# ---------------------------------------------------------------------------


def _fused_kernel(aid_ref, x_ref, a_ref, b_ref, o_ref, h_ref):
    # j (output-block dim) is the innermost grid dim: the shrink product
    # is computed once per token block (j == 0) into VMEM scratch and
    # stays resident for every output block — no HBM round-trip. The
    # scratch holds x.dtype, mirroring the unfused path's inter-kernel
    # cast so fused and unfused outputs are bit-identical.
    @pl.when(pl.program_id(1) == 0)
    def _():
        h_ref[...] = jnp.dot(
            x_ref[...], a_ref[0],
            preferred_element_type=jnp.float32).astype(h_ref.dtype)

    o_ref[...] = jnp.dot(
        h_ref[...], b_ref[0],
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _fused_kernel_1ob(aid_ref, x_ref, a_ref, b_ref, o_ref):
    # single-output-block specialization (d_out <= block_o): the shrink
    # product lives in registers only — no scratch, no conditional
    h = jnp.dot(x_ref[...], a_ref[0],
                preferred_element_type=jnp.float32).astype(x_ref.dtype)
    o_ref[...] = jnp.dot(h, b_ref[0],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "interpret"))
def sgmv_fused_blocks(x_pad, A, B, block_adapter, *, block_t: int = 16,
                      block_o: int = 2048, interpret=None):
    """Fused shrink+expand over a segment-blocked layout: one dispatch,
    (block_t, r) intermediate kept in VMEM. Returns (T_pad, d_out)."""
    interpret = resolve_interpret(interpret)
    T_pad, d = x_pad.shape
    Na, _, r = A.shape
    d_out = B.shape[-1]
    bo = min(block_o, d_out)
    pad_o = (-d_out) % bo
    Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_o)))
    n_ob = (d_out + pad_o) // bo
    nblocks = T_pad // block_t
    out = pl.pallas_call(
        _fused_kernel if n_ob > 1 else _fused_kernel_1ob,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nblocks, n_ob),
            in_specs=[
                pl.BlockSpec((block_t, d), lambda i, j, aid: (i, 0)),
                pl.BlockSpec((1, d, r), lambda i, j, aid: (aid[i], 0, 0)),
                pl.BlockSpec((1, r, bo), lambda i, j, aid: (aid[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((block_t, bo), lambda i, j, aid: (i, j)),
            scratch_shapes=[] if n_ob == 1 else
            [pltpu.VMEM((block_t, r), x_pad.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, d_out + pad_o), x_pad.dtype),
        interpret=interpret,
    )(block_adapter, x_pad, A, Bp)
    return out[:, :d_out]


# ---------------------------------------------------------------------------
# Fused multi-bank (rank-bucketed) kernel: ONE dispatch for all buckets
# ---------------------------------------------------------------------------


def _make_multibank_kernel(bucket_ranks, n_ob, resident, block_o):
    """Kernel factory closed over the static per-bucket ranks. The body
    branches on the block's scalar-prefetched bucket id; only the
    matching branch's dots execute, at that bucket's OWN rank — the
    rank-aware FLOP profile of the host-loop dispatcher, without the
    host loop. With one output block the shrink product stays in
    registers; otherwise it parks in VMEM scratch across the j sweep.

    ``resident[b]`` buckets pass their WHOLE bank as the operand block
    (constant index map — fetched once, see ``sgmv_multibank_blocks``),
    so the kernel indexes the bank row itself; blocked buckets get the
    per-row (1, d, r)/(1, r, bo) slice the index map already gathered.
    """
    nb = len(bucket_ranks)

    def kernel_1ob(bkt_ref, row_ref, x_ref, *refs):
        o_ref = refs[2 * nb]
        i = pl.program_id(0)
        bkt = bkt_ref[i]
        row = row_ref[i]
        for b, r_b in enumerate(bucket_ranks):
            a_ref, b_ref = refs[2 * b], refs[2 * b + 1]

            @pl.when(bkt == b)
            def _(a_ref=a_ref, b_ref=b_ref, res=resident[b]):
                a = a_ref[row] if res else a_ref[0]
                bmat = b_ref[row] if res else b_ref[0]
                h = jnp.dot(x_ref[...], a,
                            preferred_element_type=jnp.float32
                            ).astype(x_ref.dtype)
                o_ref[...] = jnp.dot(h, bmat,
                                     preferred_element_type=jnp.float32
                                     ).astype(o_ref.dtype)

    def kernel(bkt_ref, row_ref, x_ref, *refs):
        o_ref, h_ref = refs[2 * nb], refs[2 * nb + 1]
        i, j = pl.program_id(0), pl.program_id(1)
        bkt = bkt_ref[i]
        row = row_ref[i]
        for b, r_b in enumerate(bucket_ranks):
            a_ref, b_ref = refs[2 * b], refs[2 * b + 1]

            @pl.when((bkt == b) & (j == 0))
            def _(a_ref=a_ref, r_b=r_b, res=resident[b]):
                a = a_ref[row] if res else a_ref[0]
                h_ref[:, :r_b] = jnp.dot(
                    x_ref[...], a,
                    preferred_element_type=jnp.float32).astype(h_ref.dtype)

            @pl.when(bkt == b)
            def _(b_ref=b_ref, r_b=r_b, res=resident[b]):
                if res:
                    bmat = pl.load(
                        b_ref, (row, slice(None), pl.dslice(j * block_o,
                                                            block_o)))
                else:
                    bmat = b_ref[0]
                o_ref[...] = jnp.dot(
                    h_ref[:, :r_b], bmat,
                    preferred_element_type=jnp.float32).astype(o_ref.dtype)

    return kernel_1ob if n_ob == 1 else kernel


def _bank_a_map(b):
    # non-matching buckets clamp to row 0: consecutive grid steps (the
    # layout is bucket-major) then request the same block and the fetch
    # is elided by the pipeline.
    return lambda i, j, bkt, row: (jnp.where(bkt[i] == b, row[i], 0), 0, 0)


def _bank_b_map(b):
    return lambda i, j, bkt, row: (jnp.where(bkt[i] == b, row[i], 0), 0, j)


def _resident_map(ndim):
    # whole-bank operand: the index map is constant, so every grid step
    # requests block (0, ..., 0) — the pipeline's revisiting
    # optimization fetches it exactly ONCE (XLA hoists the
    # loop-invariant slice in interpret mode), instead of re-fetching a
    # per-row slice on every step like the blocked maps above.
    return lambda *_: (0,) * ndim


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "resident",
                                    "interpret"))
def sgmv_multibank_blocks(x_pad, banks, block_bucket, block_row, *,
                          block_t: int = 16, block_o: int = 2048,
                          resident=None, interpret=None):
    """One traced dispatch over a whole rank-bucketed bank set.

    x_pad: (T_pad, d) bucket-major segment-blocked tokens; banks: tuple
    of (A_b (Na_b, d, r_b), B_b (Na_b, r_b, d_out)) pairs in ascending
    bucket order; block_bucket/block_row: (nblocks,) int32 scalar-
    prefetched metadata (which bucket, which row of that bucket's bank).
    Returns (T_pad, d_out).

    resident: optional per-bucket bool tuple (from
    ``kernels.tune.block_plan``). A resident bucket's whole A/B bank is
    the operand block with a CONSTANT index map — fetched once for the
    entire sweep instead of a per-row slice per step. That single fetch
    is what fixes the rank-skew regression: with per-row blocked maps,
    every one of the mostly-low-rank grid steps still re-fetched the
    high-rank bucket's (d, r)/(r, d_out) slices."""
    interpret = resolve_interpret(interpret)
    T_pad, d = x_pad.shape
    d_out = banks[0][1].shape[-1]
    ranks = tuple(A.shape[-1] for A, _ in banks)
    if resident is None:
        resident = tuple(False for _ in banks)
    bo = min(block_o, d_out)
    pad_o = (-d_out) % bo
    n_ob = (d_out + pad_o) // bo
    nblocks = T_pad // block_t
    in_specs = [pl.BlockSpec((block_t, d), lambda i, j, bkt, row: (i, 0))]
    operands = [x_pad]
    for b, (A, B) in enumerate(banks):
        Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_o)))
        if resident[b]:
            in_specs.append(pl.BlockSpec(A.shape, _resident_map(3)))
            in_specs.append(pl.BlockSpec(Bp.shape, _resident_map(3)))
        else:
            in_specs.append(pl.BlockSpec((1, d, ranks[b]), _bank_a_map(b)))
            in_specs.append(pl.BlockSpec((1, ranks[b], bo),
                                         _bank_b_map(b)))
        operands.extend([A, Bp])
    out = pl.pallas_call(
        _make_multibank_kernel(ranks, n_ob, resident, bo),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nblocks, n_ob),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_t, bo),
                                   lambda i, j, bkt, row: (i, j)),
            scratch_shapes=[] if n_ob == 1 else
            [pltpu.VMEM((block_t, max(ranks)), x_pad.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, d_out + pad_o), x_pad.dtype),
        interpret=interpret,
    )(block_bucket, block_row, *operands)
    return out[:, :d_out]


# ---------------------------------------------------------------------------
# Split multibank shrink / expand: the sharded per-shard reduction contract
# ---------------------------------------------------------------------------
#
# Per-shard reduction contract (mesh-sharded serving): with the LoRA bank
# co-sharded along the model axis — A sharded on d_model (each of the s
# model shards holds a (Na, d/s, r) slice) and B sharded on d_out (each
# holds (Na, r, d_out/s)) — the fused kernel cannot run as one dispatch
# because the rank-r intermediate must be summed ACROSS shards between
# the two dots. The sharded engine therefore runs, inside one shard_map:
#
#     h_local = sgmv_multibank_shrink(x_pad_local_d, A_shard, ...)
#     h       = lax.psum(h_local, "model")     # ONE (T_pad, max_r) psum
#     out     = sgmv_multibank_expand(h, B_shard, ...)
#
# Each shard's kernels see only their local d/s (shrink) and d_out/s
# (expand) slices; the only cross-chip traffic is the rank-r
# intermediate — never the full weights, activations, or the gathered
# bank (S-LoRA's partitioned LoRA computation strategy). The expand
# output is already sharded the same way as the base layer's column-
# parallel projection output, so the delta adds in with no extra
# collective. At tp=1 the pair is bit-identical to the fused kernel
# (same dots, same inter-dot cast); under tp>1 the psum reassociates the
# d-dim sum, so parity with the single-device engine is at token level
# (argmax), not bitwise.


def _make_multibank_shrink_kernel(bucket_ranks, resident):
    nb = len(bucket_ranks)

    def kernel(bkt_ref, row_ref, x_ref, *refs):
        o_ref = refs[nb]
        i = pl.program_id(0)
        bkt = bkt_ref[i]
        row = row_ref[i]
        # zero-fill so columns above the block's own rank are defined
        # (they participate in the cross-shard psum)
        o_ref[...] = jnp.zeros_like(o_ref)
        for b, r_b in enumerate(bucket_ranks):
            a_ref = refs[b]

            @pl.when(bkt == b)
            def _(a_ref=a_ref, r_b=r_b, res=resident[b]):
                a = a_ref[row] if res else a_ref[0]
                o_ref[:, :r_b] = jnp.dot(
                    x_ref[...], a,
                    preferred_element_type=jnp.float32).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("block_t", "resident", "interpret"))
def sgmv_multibank_shrink(x_pad, A_banks, block_bucket, block_row, *,
                          block_t: int = 16, resident=None,
                          interpret=None):
    """Shrink half of the multibank dispatch: x_pad (T_pad, d_local) x
    per-bucket A (Na_b, d_local, r_b) -> (T_pad, max_r), columns above a
    block's own bucket rank zero-filled. ``d_local`` may be a model-
    sharded slice — see the per-shard reduction contract above."""
    interpret = resolve_interpret(interpret)
    T_pad, d = x_pad.shape
    ranks = tuple(A.shape[-1] for A in A_banks)
    if resident is None:
        resident = tuple(False for _ in A_banks)
    max_r = max(ranks)
    nblocks = T_pad // block_t
    in_specs = [pl.BlockSpec((block_t, d), lambda i, bkt, row: (i, 0))]
    operands = [x_pad]
    for b, A in enumerate(A_banks):
        if resident[b]:
            in_specs.append(pl.BlockSpec(A.shape, _resident_map(3)))
        else:
            in_specs.append(pl.BlockSpec(
                (1, d, ranks[b]),
                lambda i, bkt, row, b=b: (jnp.where(bkt[i] == b,
                                                    row[i], 0), 0, 0)))
        operands.append(A)
    return pl.pallas_call(
        _make_multibank_shrink_kernel(ranks, resident),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nblocks,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_t, max_r),
                                   lambda i, bkt, row: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, max_r), x_pad.dtype),
        interpret=interpret,
    )(block_bucket, block_row, *operands)


def _make_multibank_expand_kernel(bucket_ranks, n_ob, resident, block_o):
    nb = len(bucket_ranks)

    def kernel(bkt_ref, row_ref, h_ref, *refs):
        o_ref = refs[nb]
        i = pl.program_id(0)
        j = pl.program_id(1) if n_ob > 1 else 0
        bkt = bkt_ref[i]
        row = row_ref[i]
        for b, r_b in enumerate(bucket_ranks):
            b_ref = refs[b]

            @pl.when(bkt == b)
            def _(b_ref=b_ref, r_b=r_b, res=resident[b]):
                if res:
                    bmat = pl.load(
                        b_ref, (row, slice(None), pl.dslice(j * block_o,
                                                            block_o)))
                else:
                    bmat = b_ref[0]
                o_ref[...] = jnp.dot(
                    h_ref[:, :r_b], bmat,
                    preferred_element_type=jnp.float32).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "resident",
                                    "interpret"))
def sgmv_multibank_expand(h_pad, B_banks, block_bucket, block_row, *,
                          block_t: int = 16, block_o: int = 2048,
                          resident=None, interpret=None):
    """Expand half of the multibank dispatch: h_pad (T_pad, max_r)
    (typically the psum of per-shard shrink outputs) x per-bucket B
    (Na_b, r_b, d_out_local) -> (T_pad, d_out_local)."""
    interpret = resolve_interpret(interpret)
    T_pad, max_r = h_pad.shape
    d_out = B_banks[0].shape[-1]
    ranks = tuple(B.shape[1] for B in B_banks)
    if resident is None:
        resident = tuple(False for _ in B_banks)
    bo = min(block_o, d_out)
    pad_o = (-d_out) % bo
    n_ob = (d_out + pad_o) // bo
    nblocks = T_pad // block_t
    in_specs = [pl.BlockSpec((block_t, max_r),
                             lambda i, j, bkt, row: (i, 0))]
    operands = [h_pad]
    for b, B in enumerate(B_banks):
        Bp = jnp.pad(B, ((0, 0), (0, 0), (0, pad_o)))
        if resident[b]:
            in_specs.append(pl.BlockSpec(Bp.shape, _resident_map(3)))
        else:
            in_specs.append(pl.BlockSpec(
                (1, ranks[b], bo),
                lambda i, j, bkt, row, b=b: (jnp.where(bkt[i] == b,
                                                       row[i], 0), 0, j)))
        operands.append(Bp)
    out = pl.pallas_call(
        _make_multibank_expand_kernel(ranks, n_ob, resident, bo),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nblocks, n_ob),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_t, bo),
                                   lambda i, j, bkt, row: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T_pad, d_out + pad_o), h_pad.dtype),
        interpret=interpret,
    )(block_bucket, block_row, *operands)
    return out[:, :d_out]
