"""Block-geometry heuristics for the SGMV dispatch wrappers.

The multibank kernel's one static ``block_t`` was measured to be a
regression on rank-skewed batches (``experiments/bench/kernels.csv``):
with per-row blocked bank fetches, every grid step re-fetches every
bucket's (d, r_b)/(r_b, d_out) A/B slices, so the high-rank bucket's
~2 MB slices are paid on every block even when only a couple of blocks
use them. The fix has two parts, both decided here per bank signature:

* a per-bucket ``block_t`` preference from a small (T_b, r_b, d)-keyed
  table (T_b = the bucket's expected token share), collapsed to the
  dispatch's single grid ``block_t`` by expected-token weight, and
* per-bucket bank **residency**: a resident bucket's A/B operands use a
  whole-bank BlockSpec with a constant index map, so the fetch is
  loop-invariant — the pipeline's revisiting optimization (and XLA LICM
  under interpret mode) fetches it exactly once instead of per step.
  Residency is granted smallest-bank-first under the per-core VMEM
  budget at the bf16 deployment envelope, with the non-resident blocked
  slices and the working blocks charged against the same budget.

This module is import-light (no jax/numpy): ``repro.analysis.vmem``
imports it to verify that every plan the dispatcher can pick respects
the static VMEM envelope, including the sharded-engine corners where
the kernels see ``d_model / model_shards`` slices.

Plans are memoized per bank signature — (T, d, d_out, per-bucket ranks
and adapter counts, itemsize) — which is exactly the granularity at
which the serving engine's traces are cached, so a bank rebuild picks
the new plan and a stable bank keeps its trace.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

# Mirrors launch/mesh.py:VMEM_BYTES_PER_CORE (that module imports jax at
# top level; this one must stay import-light for repro.analysis).
VMEM_BYTES_PER_CORE = 16 * 2**20

# Deployment itemsize for residency budgeting: compiled TPU runs use
# bf16 banks (see kernels/sgmv.py's VMEM caveat); fp32 runs are CPU
# interpret-mode where no VMEM constraint exists.
DEPLOY_ITEMSIZE = 2


class BlockPlan(NamedTuple):
    """Static block geometry for one multibank dispatch."""
    block_t: int
    resident: Tuple[bool, ...]     # per bucket, ascending bucket order


# (T_b band, r_b band, d band) -> preferred block_t for that bucket.
# Bands: T_b <= 128 | <= 1024 | larger; r_b <= 32 | larger; d <= 4096 |
# larger. Values from the interpret-mode grid sweep in the bench: small
# buckets want small blocks (per-adapter padding waste is bounded by
# block_t, and a high-rank block's padded rows run high-rank dots);
# large low-rank buckets amortize the per-step overhead with 64-row
# blocks; at d > 4096 the (block_t, d) x-block itself dominates VMEM so
# the block shrinks.
_BLOCK_T_TABLE = {
    ("small", "low", "narrow"): 16,
    ("small", "high", "narrow"): 16,
    ("mid", "low", "narrow"): 64,
    ("mid", "high", "narrow"): 32,
    ("large", "low", "narrow"): 64,
    ("large", "high", "narrow"): 64,
    ("small", "low", "wide"): 16,
    ("small", "high", "wide"): 16,
    ("mid", "low", "wide"): 32,
    ("mid", "high", "wide"): 32,
    ("large", "low", "wide"): 32,
    ("large", "high", "wide"): 32,
}


def _t_band(t_b: int) -> str:
    if t_b <= 128:
        return "small"
    if t_b <= 1024:
        return "mid"
    return "large"


def _r_band(r_b: int) -> str:
    return "low" if r_b <= 32 else "high"


def _d_band(d: int) -> str:
    return "narrow" if d <= 4096 else "wide"


def bucket_block_t(t_b: int, r_b: int, d: int) -> int:
    """Preferred block_t for one bucket of ~t_b tokens at rank r_b."""
    return _BLOCK_T_TABLE[(_t_band(t_b), _r_band(r_b), _d_band(d))]


@functools.lru_cache(maxsize=256)
def block_plan(T: int, d: int, d_out: int,
               ranks: Tuple[int, ...], counts: Tuple[int, ...],
               *, block_o: int = 2048,
               itemsize: int = DEPLOY_ITEMSIZE,
               vmem_budget: int = VMEM_BYTES_PER_CORE) -> BlockPlan:
    """Pick the dispatch block geometry for a rank-bucketed bank set.

    T: tokens in the batch; d/d_out: model dims the kernel sees (the
    sharded engine passes its local ``d / model_shards`` slice sizes);
    ranks/counts: per-bucket (r_b, n_adapters_b) in ascending bucket
    order — together these are the bank signature, so the lru_cache
    realizes "cache the choice per bank signature".
    """
    n_total = max(1, sum(counts))
    # token share estimate per bucket (counts are all that is static)
    t_est = [max(1, T * n_b // n_total) for n_b in counts]
    # expected-token-weighted vote collapses per-bucket preferences to
    # the dispatch's single grid block_t
    votes = {}
    for t_b, r_b, n_b in zip(t_est, ranks, counts):
        bt = bucket_block_t(t_b, r_b, d)
        votes[bt] = votes.get(bt, 0) + t_b
    block_t = max(sorted(votes), key=lambda bt: votes[bt])
    # a block_t above the largest plausible segment only adds padding
    while block_t > 16 and block_t > max(t_est):
        block_t //= 2

    bo = min(block_o, d_out)
    # working set (double-buffered x/out blocks + the widest h scratch)
    working = 2 * block_t * d * itemsize \
        + 2 * block_t * bo * itemsize \
        + block_t * max(ranks) * itemsize
    # start with every bank blocked (2x double-buffered slices); the
    # resident whole-bank block is charged at 2x as well — one fetch at
    # runtime, but the pipeline still allocates double buffers, and the
    # static checker (analysis/vmem.py) applies the same uniform rule
    blocked_cost = [2 * (d * r + r * bo) * itemsize for r in ranks]
    resident_cost = [2 * n * (d * r + r * (d_out + (-d_out) % bo)) * itemsize
                     for n, r in zip(counts, ranks)]
    resident = [False] * len(ranks)
    used = working + sum(blocked_cost)
    # grant residency smallest-bank-first: maximizes how many buckets
    # stop paying per-step fetches under the same budget
    order = sorted(range(len(ranks)), key=lambda b: resident_cost[b])
    for b in order:
        new_used = used - blocked_cost[b] + resident_cost[b]
        if new_used <= vmem_budget:
            resident[b] = True
            used = new_used
    return BlockPlan(block_t=block_t, resident=tuple(resident))


def plan_vmem_bytes(plan: BlockPlan, d: int, d_out: int,
                    ranks: Tuple[int, ...], counts: Tuple[int, ...],
                    *, block_o: int = 2048,
                    itemsize: int = DEPLOY_ITEMSIZE) -> int:
    """VMEM bytes the multibank dispatch needs under ``plan`` — the same
    accounting ``block_plan`` budgets with, exposed for the static
    checker so plan and check can never drift apart."""
    bo = min(block_o, d_out)
    total = 2 * plan.block_t * d * itemsize \
        + 2 * plan.block_t * bo * itemsize \
        + plan.block_t * max(ranks) * itemsize
    for b, (n, r) in enumerate(zip(counts, ranks)):
        if plan.resident[b]:
            total += 2 * n * (d * r + r * (d_out + (-d_out) % bo)) \
                * itemsize
        else:
            total += 2 * (d * r + r * bo) * itemsize
    return total
