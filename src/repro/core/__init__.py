"""LORASERVE core: the paper's contribution — rank- and demand-aware
dynamic adapter placement (Algorithm 1), phi-weighted routing, and the
distributed adapter pool."""
from .baselines import (ContiguousPolicy, LoraservePolicy, POLICIES,
                        RandomPolicy, ToppingsPolicy)
from .demand import DemandEstimator
from .orchestrator import ClusterOrchestrator
from .placement import assign_loraserve
from .pool import AdapterStore, DistributedAdapterPool, FetchPlan
from .request import Phase, Request, ServeRequest, SimRequest
from .routing import RetiredServerError, RoutingTable, UnknownAdapterError
from .types import (AdapterInfo, Placement, PlacementContext,
                    PlacementStats, servers_to_adapters)

__all__ = ["assign_loraserve", "AdapterInfo", "Placement",
           "PlacementContext", "PlacementStats", "DemandEstimator",
           "RoutingTable", "UnknownAdapterError", "RetiredServerError",
           "AdapterStore", "FetchPlan",
           "DistributedAdapterPool", "ClusterOrchestrator",
           "POLICIES", "LoraservePolicy", "RandomPolicy",
           "ContiguousPolicy", "ToppingsPolicy", "servers_to_adapters",
           "Phase", "Request", "ServeRequest", "SimRequest"]
