"""LORASERVE core: the paper's contribution — rank- and demand-aware
dynamic adapter placement (Algorithm 1), phi-weighted routing, and the
distributed adapter pool.

Exports resolve lazily (PEP 562): ``repro.core.pool`` / ``.routing`` /
``.types`` are pure-Python control-plane modules, and the import-light
``repro.analysis`` protocol checker must be able to load them in a bare
venv without dragging in the jax-backed siblings (baselines,
orchestrator) that eager re-exports would import.
"""
_EXPORTS = {
    "ContiguousPolicy": "baselines", "LoraservePolicy": "baselines",
    "POLICIES": "baselines", "RandomPolicy": "baselines",
    "ToppingsPolicy": "baselines",
    "DemandEstimator": "demand",
    "ClusterOrchestrator": "orchestrator",
    "assign_loraserve": "placement",
    "AdapterStore": "pool", "DistributedAdapterPool": "pool",
    "FetchPlan": "pool",
    "Phase": "request", "Request": "request", "ServeRequest": "request",
    "SimRequest": "request",
    "RetiredServerError": "routing", "RoutingTable": "routing",
    "UnknownAdapterError": "routing",
    "AdapterInfo": "types", "Placement": "types",
    "PlacementContext": "types", "PlacementStats": "types",
    "servers_to_adapters": "types",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    import importlib
    mod = _EXPORTS.get(name)
    if mod is not None:
        return getattr(importlib.import_module(f".{mod}", __name__), name)
    try:                         # plain submodule access (pkg.network)
        return importlib.import_module(f".{name}", __name__)
    except ImportError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
