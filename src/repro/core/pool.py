"""Distributed adapter pool (paper §IV-B, Fig 13).

Each server stores in host memory only the adapters routed to it; the
orchestrator keeps a cluster-wide location index. On a routing miss the
adapter is fetched peer-to-peer (GPUDirect-RDMA over InfiniBand in the
paper; ICI between TPU hosts in our deployment mapping) and cached
locally; copies no longer referenced by the routing table are deleted
after the fetch completes — while the invariant "every adapter lives on
>= 1 server" is preserved at all times.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .types import AdapterInfo, Placement


class DistributedAdapterPool:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 network=None):
        self.n_servers = n_servers
        self.meta: Dict[str, AdapterInfo] = {a.adapter_id: a
                                             for a in adapters}
        self.local: List[Set[str]] = [set() for _ in range(n_servers)]
        self.index: Dict[str, Set[int]] = {a.adapter_id: set()
                                           for a in adapters}
        self.network = network
        self.desired: Dict[str, Set[int]] = {}
        # telemetry
        self.fetches = 0
        self.fetch_bytes = 0
        self.evictions = 0

    # -- initial seeding -----------------------------------------------
    def seed(self, placement: Placement) -> None:
        for aid, entry in placement.items():
            for sid in entry:
                self.local[sid].add(aid)
                self.index[aid].add(sid)
        self.desired = {aid: set(entry) for aid, entry in placement.items()}

    # -- placement updates (lazy migration, Fig 13) ---------------------
    def apply_placement(self, placement: Placement) -> None:
        """Record the new desired placement. Migration is lazy: adapters
        move on first access; stale copies are GC'd then."""
        self.desired = {aid: set(entry) for aid, entry in placement.items()}

    # -- data path -------------------------------------------------------
    def ensure_local(self, server_id: int, adapter_id: str
                     ) -> Tuple[float, int]:
        """Make `adapter_id` available on `server_id`. Returns
        (fetch_latency_seconds, bytes_transferred); (0, 0) on a hit."""
        if adapter_id in self.local[server_id]:
            self._gc(adapter_id)
            return 0.0, 0
        holders = self.index[adapter_id]
        if not holders:
            raise KeyError(f"adapter {adapter_id} lost from cluster")
        src = min(holders)          # deterministic; any holder works
        nbytes = self.meta[adapter_id].nbytes
        latency = (self.network.transfer_latency(nbytes, "ib_gdr")
                   if self.network else 0.0)
        self.local[server_id].add(adapter_id)
        self.index[adapter_id].add(server_id)
        self.fetches += 1
        self.fetch_bytes += nbytes
        self._gc(adapter_id)
        return latency, nbytes

    def _gc(self, adapter_id: str) -> None:
        """Drop copies not in the desired placement, always keeping >= 1
        copy cluster-wide (the paper's Fig 13 delete-after-copy step)."""
        want = self.desired.get(adapter_id)
        if not want:
            return
        holders = self.index[adapter_id]
        for sid in sorted(holders):
            if sid in want:
                continue
            if len(holders) == 1:
                break
            self.local[sid].discard(adapter_id)
            holders.discard(sid)
            self.evictions += 1

    # -- accounting -------------------------------------------------------
    def server_bytes(self, server_id: int) -> int:
        return sum(self.meta[a].nbytes for a in self.local[server_id])

    def server_adapter_count(self, server_id: int) -> int:
        return len(self.local[server_id])

    def max_adapters_per_server(self) -> int:
        return max((len(s) for s in self.local), default=0)

    def total_bytes(self) -> int:
        return sum(self.server_bytes(s) for s in range(self.n_servers))

    def check_invariant(self) -> bool:
        return all(len(self.index[a]) >= 1 for a in self.meta)
