"""Tiered adapter data plane (paper §IV-B, Fig 13/14).

``AdapterStore`` replaces the old synchronous ``DistributedAdapterPool``
API: adapter movement is a first-class subsystem with per-server tiers,
explicit ``FetchPlan``s, and asynchronous in-flight transfers that
occupy link bandwidth on the simulator clock.

Tiers, per server:

* **hbm** — the adapter sits in the server's bank slot and is servable
  (``local`` / ``index`` track this tier; the cluster invariant "every
  adapter lives on >= 1 server" is over HBM copies);
* **host** — a bounded LRU host-memory cache holding copies recently
  demoted from HBM (delete-after-copy GC demotes instead of dropping),
  refetchable over PCIe at ``local_host`` cost;
* **peer** — any other server's HBM copy, readable over the fabric
  (GPUDirect RDMA / ICI);
* **ssd** — a cluster-wide spill source (the paper's prohibitively
  slow one) offered as an alternative when every other link is
  congested; it is never a correctness backstop — an adapter with no
  HBM or host copy left raises instead of silently serving from SSD.

Data path: ``start_fetch`` picks the cheapest source *by modeled
latency under current link load* (replacing ``src = min(holders)``),
registers an in-flight transfer, and returns a ``FetchPlan`` whose
``eta`` the caller turns into a fetch-completion event; ``poll``
installs finished copies. Duplicate in-flight fetches of one adapter to
one server coalesce onto the first transfer. ``start_remote_read``
serves a miss from a peer's copy over GDR (per-iteration penalty from
``NetworkModel``) while the local copy warms in the background, and
``apply_placement(prefetch=True)`` proactively warms newly-placed
copies instead of migrating lazily on first hit.

GC (the Fig-13 delete-after-copy step) skips adapters with transfers in
flight: a peer copy being read by an in-flight fetch must survive until
that transfer lands.
"""
from __future__ import annotations

import dataclasses
import math
import os
import random
from typing import Dict, List, Optional, Set, Tuple

from .types import AdapterInfo, Placement

# opt-in runtime validation: with REPRO_CHECK_INVARIANTS=1 the store
# re-checks the model checker's invariants (repro.analysis.protocol)
# after every poll/fetch, so sim runs validate what the checker proves
# exhaustively on small models
CHECK_INVARIANTS_ENV = "REPRO_CHECK_INVARIANTS"


def runtime_checks_enabled() -> bool:
    return os.environ.get(CHECK_INVARIANTS_ENV, "") not in ("", "0")

TIER_HBM = "hbm"
TIER_HOST = "host"
TIER_PEER = "peer"
TIER_SSD = "ssd"

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class FetchRetryPolicy:
    """Timeout/retry knobs for in-flight transfers (repro.faults).

    A healthy transfer lands exactly at its modeled ETA, so the
    per-attempt deadline is ``eta + timeout`` — it only fires when the
    transfer was stalled or its source died. Retries back off
    exponentially with multiplicative jitter (seeded, deterministic)
    and re-pick the cheapest *surviving* source, so a dead GDR peer
    falls back to host cache or the SSD tier."""
    timeout: float = 0.25        # grace beyond the modeled ETA (s)
    base_backoff: float = 0.02   # first retry delay (s)
    max_backoff: float = 1.0     # backoff cap (s)
    jitter: float = 0.25         # multiplicative jitter fraction
    max_attempts: int = 12       # loud failure past this many retries

    def backoff(self, attempt: int, rng: random.Random) -> float:
        base = min(self.max_backoff, self.base_backoff * (2 ** attempt))
        return base * (1.0 + self.jitter * rng.random())


class CircuitBreaker:
    """Per-peer fetch-source breaker: closed -> open after
    ``threshold`` consecutive failures, half-open after ``cooldown``
    seconds (one probe transfer allowed), closed again on success."""

    def __init__(self, threshold: int = 3, cooldown: float = 1.0):
        self.threshold = threshold
        self.cooldown = cooldown
        self.state = "closed"
        self.failures = 0
        self.open_until = -_INF
        self.opens = 0

    def allows(self, now: float) -> bool:
        if self.state == "open":
            if now + 1e-12 >= self.open_until:
                self.state = "half-open"
            else:
                return False
        return True

    def record_failure(self, now: float) -> None:
        self.failures += 1
        if self.state == "half-open" or self.failures >= self.threshold:
            self.state = "open"
            self.open_until = now + self.cooldown
            self.failures = 0
            self.opens += 1

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0


@dataclasses.dataclass
class FetchPlan:
    """One planned (or in-flight, or completed) adapter movement."""
    adapter_id: str
    dest: int
    mode: str = "migrate"        # migrate | remote-read | prefetch
    hit: bool = False            # already in the dest's HBM tier
    source: str = TIER_HBM       # hbm | local_host | ib_gdr | ici | ssd
    src_server: int = -1         # peer the bytes come from (-1: host/ssd)
    nbytes: int = 0
    latency: float = 0.0         # modeled transfer time (seconds)
    eta: float = 0.0             # completion time on the caller's clock
    token_penalty: float = 0.0   # per-iteration remote-read surcharge
    read_peer: int = -1          # peer serving remote reads (remote-read)
    coalesced: bool = False      # joined an already-in-flight transfer
    # retry state (repro.faults): a transfer that blows its deadline or
    # loses its source backs off, then relaunches from a new source
    started: float = 0.0         # when the current attempt started
    deadline: float = _INF       # current attempt must land by this
    link_eta: float = 0.0        # eta registered with the network link
    attempt: int = 0             # completed (failed) attempts so far
    retry_at: float = -1.0       # >= 0: waiting out backoff until this
    stalled: bool = False        # an injector froze this transfer

    @property
    def blocking(self) -> bool:
        """Whether the request must wait for the ETA before prefill."""
        return not self.hit and self.mode != "remote-read"


class AdapterStore:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 network=None, *, host_cache_bytes: int = 512 << 20,
                 ssd_spill: bool = True,
                 retry: Optional[FetchRetryPolicy] = None,
                 durable_ssd: bool = False,
                 breaker_threshold: int = 3,
                 breaker_cooldown: float = 1.0,
                 retry_seed: int = 0):
        self.n_servers = n_servers
        self.meta: Dict[str, AdapterInfo] = {a.adapter_id: a
                                             for a in adapters}
        # hbm tier: servable copies; the invariant is over these
        self.local: List[Set[str]] = [set() for _ in range(n_servers)]
        self.index: Dict[str, Set[int]] = {a.adapter_id: set()
                                           for a in adapters}
        # host tier: LRU cache of demoted copies (aid -> nbytes)
        self.host_cache: List[Dict[str, int]] = [dict()
                                                 for _ in range(n_servers)]
        self.host_cache_bytes = host_cache_bytes
        self.ssd_spill = ssd_spill
        self.network = network
        self.desired: Dict[str, Set[int]] = {}
        self._inflight: Dict[Tuple[int, str], FetchPlan] = {}
        # autoscaling lifecycle: draining servers accept no new copies
        # (their holdings are being migrated out); retired servers are
        # out of the cluster entirely, ids never reused
        self.draining: Set[int] = set()
        self.retired: Set[int] = set()
        # fault plane (repro.faults): crashed servers lose every copy
        # instantly; ``lost`` tracks adapters whose last HBM/host copy
        # died and are recoverable only from the durable SSD tier
        self.failed: Set[int] = set()
        self.lost: Set[str] = set()
        self.retry = retry or FetchRetryPolicy()
        self.durable_ssd = durable_ssd
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown = breaker_cooldown
        self.breakers: Dict[int, CircuitBreaker] = {}
        self._rng = random.Random(retry_seed)
        # telemetry
        self.fetches = 0
        self.fetch_bytes = 0
        self.evictions = 0
        self.remote_reads = 0
        self.prefetches = 0
        self.coalesced = 0
        self.host_hits = 0
        self.ssd_fetches = 0
        self.drain_fetches = 0
        self.fetch_retries = 0
        self.fetch_timeouts = 0
        self.ssd_recoveries = 0
        # obs.Tracer (host-attached): every started transfer emits a
        # "transfer" span on the store track, start -> modeled ETA
        self.tracer = None

    # -- initial seeding -----------------------------------------------
    def seed(self, placement: Placement) -> None:
        for aid, entry in placement.items():
            for sid in entry:
                self.local[sid].add(aid)
                self.index[aid].add(sid)
        self.desired = {aid: set(entry) for aid, entry in placement.items()}

    # -- tier introspection ----------------------------------------------
    def tier(self, server_id: int, adapter_id: str) -> Optional[str]:
        if adapter_id in self.local[server_id]:
            return TIER_HBM
        if adapter_id in self.host_cache[server_id]:
            return TIER_HOST
        return None

    def inflight_count(self, adapter_id: Optional[str] = None) -> int:
        if adapter_id is None:
            return len(self._inflight)
        return sum(1 for (_, aid) in self._inflight if aid == adapter_id)

    def inflight_to(self, server_id: int) -> int:
        return sum(1 for (sid, _) in self._inflight if sid == server_id)

    def inflight_from(self, server_id: int) -> int:
        """Transfers currently reading bytes out of ``server_id`` — a
        draining server cannot retire while it is still a source."""
        return sum(1 for p in self._inflight.values()
                   if p.src_server == server_id)

    # -- adapter lifecycle (runtime register / deregister) -----------------
    def register_adapter(self, info: AdapterInfo, server_id: int) -> None:
        """Install a newly-registered adapter's first copy directly in
        ``server_id``'s HBM tier (the registration upload, not a fetch —
        the fetch counters stay miss-driven). The caller has already
        placed it there."""
        aid = info.adapter_id
        if aid in self.meta:
            raise ValueError(f"adapter {aid!r} already registered")
        if server_id in self.retired:
            raise RuntimeError(f"register of {aid!r} on retired "
                               f"server {server_id}")
        if server_id in self.draining:
            raise RuntimeError(f"register of {aid!r} on draining "
                               f"server {server_id}")
        self.meta[aid] = info
        self.index[aid] = {server_id}
        self.local[server_id].add(aid)
        self.desired.setdefault(aid, set()).add(server_id)
        self._debug_check()

    def deregister_adapter(self, adapter_id: str) -> None:
        """Remove every copy of a retired adapter from every tier. The
        caller guarantees quiescence (no live requests, no transfers in
        flight); loud otherwise — dropping an adapter mid-transfer would
        strand its bytes on a link."""
        if adapter_id not in self.meta:
            raise KeyError(adapter_id)
        if self.inflight_count(adapter_id):
            raise RuntimeError(f"deregister of {adapter_id!r} with "
                               f"transfers in flight")
        for sid in range(self.n_servers):
            self.local[sid].discard(adapter_id)
            self.host_cache[sid].pop(adapter_id, None)
        self.index.pop(adapter_id, None)
        self.desired.pop(adapter_id, None)
        self.meta.pop(adapter_id)

    # -- fleet lifecycle (controlplane scale-up / drain / retire) ---------
    def add_server(self) -> int:
        """Provision one empty server; returns its (stable, new) id."""
        sid = self.n_servers
        self.n_servers += 1
        self.local.append(set())
        self.host_cache.append(dict())
        return sid

    def begin_drain(self, server_id: int) -> None:
        """Stop placing new copies on ``server_id``; its existing copies
        stay readable (as fetch sources and remote-read peers) until the
        migration out completes."""
        self.draining.add(server_id)

    def drain_server(self, server_id: int, now: float = 0.0
                     ) -> List[FetchPlan]:
        """Migrate everything off ``server_id``: for each adapter it
        holds, start fetches toward its desired servers (the caller has
        already re-placed without this server) and GC copies that are
        already redundant. Returns the started plans; the server is
        empty once they land and ``poll`` has GC'd it."""
        self.begin_drain(server_id)
        plans: List[FetchPlan] = []
        for aid in sorted(self.local[server_id]):
            dests = self.desired.get(aid, set()) - {server_id}
            if not dests:
                raise RuntimeError(
                    f"drain of server {server_id} before re-placement: "
                    f"adapter {aid!r} has nowhere to go")
            for d in sorted(dests):
                if aid not in self.local[d]:
                    p = self.start_fetch(d, aid, now=now, mode="drain")
                    if not p.hit and not p.coalesced:
                        plans.append(p)
            self._gc(aid)   # no-op while the migration is in flight
        return plans

    def retire_server(self, server_id: int) -> None:
        """Remove an emptied, drained server from the cluster. Loud if
        it still holds copies or feeds in-flight transfers."""
        if self.local[server_id]:
            raise RuntimeError(
                f"retire of server {server_id} with "
                f"{len(self.local[server_id])} HBM copies still resident")
        if self.inflight_from(server_id) or self.inflight_to(server_id):
            raise RuntimeError(
                f"retire of server {server_id} with transfers in flight")
        self.host_cache[server_id].clear()
        self.draining.discard(server_id)
        self.retired.add(server_id)

    def live_servers(self) -> List[int]:
        return [s for s in range(self.n_servers)
                if s not in self.retired and s not in self.failed]

    # -- fault plane (repro.faults) ---------------------------------------
    def fail_server(self, server_id: int, now: float = 0.0) -> List[str]:
        """Crash ``server_id``: every tier it holds vanishes, transfers
        into it are cancelled (link slots released), and transfers
        sourcing from it lose their source and enter the retry path.
        Returns the adapters whose *last* HBM/host copy just died —
        recoverable from SSD when the store is ``durable_ssd``, lost
        (loud on next access) otherwise."""
        if server_id in self.retired:
            raise RuntimeError(f"crash of retired server {server_id}")
        if server_id in self.failed:
            return []
        self.failed.add(server_id)
        orphans: List[str] = []
        for aid in sorted(self.local[server_id]):
            self.local[server_id].discard(aid)
            self.index[aid].discard(server_id)
            if not self.index[aid]:
                orphans.append(aid)
        self.host_cache[server_id].clear()
        cancelled: List[str] = []
        for key in sorted(self._inflight):
            dest, aid = key
            p = self._inflight[key]
            if dest == server_id:
                if self.network is not None and p.src_server >= 0:
                    self.network.end_transfer(p.src_server, p.link_eta)
                del self._inflight[key]
                cancelled.append(aid)
            elif p.src_server == server_id and p.retry_at < 0:
                self._fail_attempt(p, now)
        for aid in orphans + cancelled:
            # an in-flight copy may still land elsewhere; only a truly
            # copy-less adapter is "lost" (awaiting SSD recovery) — a
            # cancelled inbound fetch counts when it was the sole copy
            # in motion for an already-orphaned adapter
            if not self.index.get(aid) and not self.inflight_count(aid) \
                    and not any(aid in hc for hc in self.host_cache):
                self.lost.add(aid)
        self._debug_check(now)
        return orphans

    def restore_server(self, server_id: int) -> None:
        """Bring a crashed server back, empty: it rejoins the fleet as
        a valid fetch destination; copies re-warm via placement."""
        self.failed.discard(server_id)

    def stall_transfer(self, dest: int, adapter_id: str,
                       extra: float = _INF) -> bool:
        """Fault injection: freeze (or slow by ``extra`` seconds) the
        in-flight transfer of ``adapter_id`` to ``dest``. The link slot
        is re-timed to match, so occupancy accounting stays exact; the
        attempt's deadline is *not* moved, so the retry path fires."""
        p = self._inflight.get((dest, adapter_id))
        if p is None or p.retry_at >= 0:
            return False
        new_eta = p.eta + extra
        if self.network is not None and p.src_server >= 0:
            self.network.move_transfer(p.src_server, p.link_eta, new_eta)
        p.eta = new_eta
        p.link_eta = new_eta
        p.stalled = True
        return True

    def _fail_attempt(self, p: FetchPlan, now: float) -> None:
        """One attempt timed out (or its source died): release the link
        slot, charge the source's breaker, and back off before
        re-picking a source. Loud past ``retry.max_attempts``."""
        if self.network is not None and p.src_server >= 0:
            self.network.end_transfer(p.src_server, p.link_eta)
            self._breaker(p.src_server).record_failure(now)
        self.fetch_timeouts += 1
        p.attempt += 1
        if p.attempt >= self.retry.max_attempts:
            raise RuntimeError(
                f"fetch of {p.adapter_id!r} to server {p.dest} failed "
                f"{p.attempt} attempts (last source {p.source!r} from "
                f"server {p.src_server})")
        p.retry_at = now + self.retry.backoff(p.attempt - 1, self._rng)
        p.src_server = -1
        p.source = "retry-wait"
        p.eta = _INF
        p.deadline = _INF
        p.stalled = False

    def _relaunch(self, p: FetchPlan, now: float) -> None:
        """Backoff elapsed: re-pick the cheapest surviving source and
        restart the transfer (same plan object — coalesced waiters keep
        observing it through the in-flight table)."""
        source, src_server, _ = self._pick_source(p.dest, p.adapter_id,
                                                  now)
        if self.network is None:
            latency, eta = 0.0, now
        else:
            latency, eta = self.network.begin_transfer(
                p.nbytes, source, now=now,
                src_server=src_server if src_server >= 0 else None)
        p.source = source
        p.src_server = src_server
        p.latency = latency
        p.eta = eta
        p.link_eta = eta
        p.started = now
        p.deadline = eta + self.retry.timeout
        p.retry_at = -1.0
        self.fetch_retries += 1
        if source == "ssd":
            self.ssd_fetches += 1
        elif source == "local_host":
            self.host_hits += 1
        if self.tracer is not None:
            self.tracer.record(
                "transfer-retry", now, eta, cat="transfer", track="store",
                attrs={"adapter_id": p.adapter_id, "mode": p.mode,
                       "source": source, "src_server": src_server,
                       "dest": p.dest, "attempt": p.attempt})

    # -- placement updates (Fig 13; now with optional prefetch) ----------
    def apply_placement(self, placement: Placement, now: float = 0.0,
                        prefetch: bool = False) -> List[FetchPlan]:
        """Record the new desired placement. Default is lazy migration
        (adapters move on first access, stale copies GC'd then); with
        ``prefetch=True`` newly-placed copies start warming immediately,
        highest-phi routes first (link occupancy makes order matter).
        Returns the prefetch plans started (empty when lazy)."""
        self.desired = {aid: set(entry) for aid, entry in placement.items()}
        if not prefetch:
            return []
        todo = sorted(((phi, aid, sid)
                       for aid, entry in placement.items()
                       for sid, phi in entry.items()
                       if aid not in self.local[sid]),
                      key=lambda t: (-t[0], t[1], t[2]))
        plans = []
        for _, aid, sid in todo:
            p = self.start_fetch(sid, aid, now=now, mode="prefetch")
            if not p.hit:
                plans.append(p)
        return plans

    # -- source selection -------------------------------------------------
    def _quote(self, nbytes: int, source: str, now: float,
               src_server: Optional[int] = None) -> float:
        if self.network is None:
            return 0.0
        return self.network.plan_latency(nbytes, source, now, src_server)

    def _breaker(self, peer: int) -> CircuitBreaker:
        br = self.breakers.get(peer)
        if br is None:
            br = CircuitBreaker(self.breaker_threshold,
                                self.breaker_cooldown)
            self.breakers[peer] = br
        return br

    def _pick_source(self, dest: int, adapter_id: str, now: float
                     ) -> Tuple[str, int, float]:
        """Cheapest source under current link load: host cache beats an
        idle peer link, a loaded peer link can lose to another peer (or
        even SSD), replacing the old hardcoded ``min(holders)``.

        Fault-aware: crashed peers, downed links, and peers whose
        circuit breaker is open are never quoted. When every peer is
        excluded by a breaker — or the adapter's last copy died and the
        SSD tier is durable — the fetch falls back to SSD."""
        nbytes = self.meta[adapter_id].nbytes
        fabric = self.network.fabric if self.network else "ib_gdr"
        cands: List[Tuple[float, int, str, int]] = []
        if adapter_id in self.host_cache[dest]:
            cands.append((self._quote(nbytes, "local_host", now),
                          0, "local_host", -1))
        excluded = 0
        for p in sorted(self.index[adapter_id] - {dest}):
            if p in self.failed:
                continue
            if self.network is not None and not self.network.link_up(p):
                excluded += 1
                continue
            if p in self.breakers and not self.breakers[p].allows(now):
                excluded += 1
                continue
            lat = self._quote(nbytes, fabric, now, p)
            if math.isinf(lat):
                excluded += 1
                continue
            cands.append((lat, 1 + p, fabric, p))
        if not cands:
            # the SSD tier is a congestion alternative, never a silent
            # correctness backstop: it serves a copy-less fetch only
            # when peers exist but are fault-excluded, or when the
            # store was built durable_ssd (crash recovery); losing the
            # last copy otherwise stays loud
            if self.ssd_spill and (excluded or self.durable_ssd):
                if not self.index[adapter_id]:
                    self.ssd_recoveries += 1
                return "ssd", -1, self._quote(nbytes, "ssd", now)
            raise KeyError(f"adapter {adapter_id} lost from cluster")
        if self.ssd_spill:
            cands.append((self._quote(nbytes, "ssd", now),
                          1_000_000, "ssd", -1))
        lat, _, source, src = min(cands)
        return source, src, lat

    # -- async data path --------------------------------------------------
    def start_fetch(self, server_id: int, adapter_id: str,
                    now: float = 0.0, mode: str = "migrate") -> FetchPlan:
        """Plan and start moving ``adapter_id`` to ``server_id``. Hits
        return immediately; duplicate in-flight fetches coalesce onto
        the existing transfer (same ETA, no extra link traffic)."""
        if adapter_id in self.local[server_id]:
            self._gc(adapter_id)
            return FetchPlan(adapter_id, server_id, mode=mode, hit=True,
                             eta=now)
        if server_id in self.retired:
            raise RuntimeError(f"fetch of {adapter_id!r} to retired "
                               f"server {server_id}")
        if server_id in self.failed:
            raise RuntimeError(f"fetch of {adapter_id!r} to failed "
                               f"server {server_id}")
        if server_id in self.draining:
            raise RuntimeError(f"fetch of {adapter_id!r} to draining "
                               f"server {server_id}")
        key = (server_id, adapter_id)
        if key in self._inflight:
            self.coalesced += 1
            return dataclasses.replace(self._inflight[key], mode=mode,
                                       coalesced=True)
        nbytes = self.meta[adapter_id].nbytes
        source, src_server, _ = self._pick_source(server_id, adapter_id,
                                                  now)
        if self.network is None:
            latency, eta = 0.0, now
        else:
            latency, eta = self.network.begin_transfer(
                nbytes, source, now=now,
                src_server=src_server if src_server >= 0 else None)
        plan = FetchPlan(adapter_id, server_id, mode=mode, source=source,
                         src_server=src_server, nbytes=nbytes,
                         latency=latency, eta=eta, started=now,
                         deadline=eta + self.retry.timeout, link_eta=eta)
        self._inflight[key] = plan
        if self.tracer is not None:
            self.tracer.record(
                "transfer", now, eta, cat="transfer", track="store",
                attrs={"adapter_id": adapter_id, "mode": mode,
                       "source": source, "src_server": src_server,
                       "dest": server_id, "nbytes": nbytes})
        # `fetches`/`fetch_bytes` stay miss-driven (their pre-data-plane
        # meaning) so they compare across access modes; proactive warms
        # and drain migrations are counted separately
        if mode == "prefetch":
            self.prefetches += 1
        elif mode == "drain":
            self.drain_fetches += 1
        else:
            self.fetches += 1
            self.fetch_bytes += nbytes
        if source == "local_host":
            self.host_hits += 1
        elif source == "ssd":
            self.ssd_fetches += 1
        self._debug_check(now)
        return plan

    def plan_access(self, server_id: int, adapter_id: str,
                    now: float = 0.0, access_mode: str = "migrate",
                    preferred_peers: Optional[List[int]] = None
                    ) -> FetchPlan:
        """The data-plane decision tree, shared by every substrate:
        remote-read when configured and a peer can serve it, otherwise a
        (possibly blocking) migrate fetch."""
        if access_mode == "remote-read":
            plan = self.start_remote_read(server_id, adapter_id, now=now,
                                          preferred_peers=preferred_peers)
            if plan is not None:
                return plan
        return self.start_fetch(server_id, adapter_id, now=now)

    def start_remote_read(self, server_id: int, adapter_id: str,
                          now: float = 0.0,
                          preferred_peers: Optional[List[int]] = None
                          ) -> Optional[FetchPlan]:
        """Serve a miss by reading the adapter from a peer's HBM copy
        over the fabric while the local copy warms in the background.
        The returned plan is non-blocking: ``token_penalty`` is the
        per-iteration surcharge until ``eta`` (warm-fetch completion).
        Returns None when no peer holds a copy (caller falls back to a
        blocking migrate fetch)."""
        if adapter_id in self.local[server_id]:
            self._gc(adapter_id)
            return FetchPlan(adapter_id, server_id, mode="remote-read",
                             hit=True, eta=now)
        holders = sorted(
            p for p in self.index[adapter_id] - {server_id}
            if p not in self.failed
            and (self.network is None or self.network.link_up(p)))
        if not holders:
            return None
        prefs = [p for p in (preferred_peers or []) if p in holders]
        pool = prefs or holders
        if self.network is not None:
            peer = min(pool, key=lambda p: (self.network.link_load(p, now),
                                            p))
            penalty = self.network.remote_read_penalty(
                self.meta[adapter_id].nbytes)
        else:
            peer, penalty = pool[0], 0.0
        warm = self.start_fetch(server_id, adapter_id, now=now,
                                mode="remote-read")
        self.remote_reads += 1
        return dataclasses.replace(warm, mode="remote-read",
                                   token_penalty=penalty, read_peer=peer)

    def _complete(self, plan: FetchPlan) -> None:
        """Install a finished transfer: HBM copy at the destination,
        source link released, host-cache copy superseded."""
        del self._inflight[(plan.dest, plan.adapter_id)]
        if self.network is not None and plan.src_server >= 0:
            self.network.end_transfer(plan.src_server, plan.link_eta)
        if plan.src_server >= 0 and plan.src_server in self.breakers:
            self.breakers[plan.src_server].record_success()
        self.local[plan.dest].add(plan.adapter_id)
        self.index[plan.adapter_id].add(plan.dest)
        self.host_cache[plan.dest].pop(plan.adapter_id, None)
        self.lost.discard(plan.adapter_id)

    def poll(self, now: float) -> List[FetchPlan]:
        """Complete transfers whose ETA has passed: install the copy in
        the destination's HBM tier, release the source link, and run the
        (now unpinned) delete-after-copy GC. The fault path runs here
        too: transfers past their per-attempt deadline (or whose source
        died) release the link and back off; transfers whose backoff
        elapsed relaunch from the cheapest surviving source."""
        eps = 1e-12
        done: List[FetchPlan] = []
        for p in sorted(self._inflight.values(),
                        key=lambda q: (q.dest, q.adapter_id)):
            if p.retry_at >= 0.0:
                if p.retry_at <= now + eps:
                    self._relaunch(p, now)
                continue
            src_dead = p.src_server >= 0 and p.src_server in self.failed
            if not src_dead and p.eta <= now + eps:
                done.append(p)
            elif src_dead or p.deadline <= now + eps:
                self._fail_attempt(p, now)
        for p in done:
            self._complete(p)
        for p in done:
            self._gc(p.adapter_id)
        self._debug_check(now)
        return done

    def finish(self, plan: FetchPlan) -> None:
        """Synchronously complete one in-flight transfer ahead of its
        ETA (for clock-less legacy callers); no-op if already done."""
        key = (plan.dest, plan.adapter_id)
        if key in self._inflight:
            self._complete(self._inflight[key])
            self._gc(plan.adapter_id)

    def next_event_time(self, now: float = 0.0) -> Optional[float]:
        """Earliest future time a transfer can make progress — landing
        at its ETA, blowing its deadline, or retrying after backoff.
        Overdue (not yet polled) transfers report ``now``."""
        if not self._inflight:
            return None
        times = []
        for p in self._inflight.values():
            if p.retry_at >= 0.0:
                times.append(p.retry_at)
            else:
                times.append(min(p.eta, p.deadline))
        t = min(times)
        if math.isinf(t):
            return None
        return max(t, now)

    # -- sync compatibility shim ------------------------------------------
    def ensure_local(self, server_id: int, adapter_id: str,
                     now: float = 0.0) -> Tuple[float, int]:
        """Legacy synchronous path: start the fetch and complete *that
        transfer* immediately (other in-flight transfers keep their
        ETAs; whatever is genuinely due by ``now`` is drained first).
        Returns (fetch_latency_seconds, bytes); (0, 0) on a hit. A
        coalesced fetch is charged only the remaining wait to the
        in-flight transfer's ETA."""
        self.poll(now)
        plan = self.start_fetch(server_id, adapter_id, now=now)
        if plan.hit:
            return 0.0, 0
        self.finish(plan)
        return max(0.0, plan.eta - now), plan.nbytes

    # -- GC (Fig 13 delete-after-copy) ------------------------------------
    def _gc(self, adapter_id: str) -> None:
        """Drop copies not in the desired placement, always keeping >= 1
        HBM copy cluster-wide. Skips adapters with transfers in flight:
        an in-flight fetch may be reading any surviving copy, so nothing
        is deleted until it lands (the hit-path GC races fixed here).
        Demoted copies land in the host cache, not the void."""
        if self.inflight_count(adapter_id):
            return
        want = self.desired.get(adapter_id)
        if not want:
            return
        holders = self.index[adapter_id]
        for sid in sorted(holders):
            if sid in want:
                continue
            if len(holders) == 1:
                break
            self.local[sid].discard(adapter_id)
            holders.discard(sid)
            self._demote(sid, adapter_id)
            self.evictions += 1

    def _demote(self, server_id: int, adapter_id: str) -> None:
        nbytes = self.meta[adapter_id].nbytes
        if self.host_cache_bytes <= 0 or nbytes > self.host_cache_bytes:
            return
        cache = self.host_cache[server_id]
        cache.pop(adapter_id, None)
        cache[adapter_id] = nbytes          # most-recently demoted last
        while sum(cache.values()) > self.host_cache_bytes:
            cache.pop(next(iter(cache)))    # evict LRU head

    # -- accounting -------------------------------------------------------
    def server_bytes(self, server_id: int) -> int:
        return sum(self.meta[a].nbytes for a in self.local[server_id])

    def host_cache_used(self, server_id: int) -> int:
        return sum(self.host_cache[server_id].values())

    def server_adapter_count(self, server_id: int) -> int:
        return len(self.local[server_id])

    def max_adapters_per_server(self) -> int:
        return max((len(s) for s in self.local), default=0)

    def total_bytes(self) -> int:
        return sum(self.server_bytes(s) for s in range(self.n_servers))

    def check_invariant(self) -> bool:
        return all(len(self.index[a]) >= 1 for a in self.meta)

    # -- debug invariant hook (shared with the model checker) -------------
    def check_invariants(self, now: float = 0.0, routing=None,
                         raise_on_violation: bool = False) -> List[str]:
        """Full safety-invariant sweep (min-copy, index consistency,
        tier exclusivity, in-flight source residency, retired-server
        silence, link occupancy) — the same predicate the protocol
        model checker evaluates at every explored state."""
        from repro.analysis.protocol import check_store_invariants
        errs = check_store_invariants(self, now, routing)
        if errs and raise_on_violation:
            raise RuntimeError("AdapterStore invariant violation:\n  "
                               + "\n  ".join(errs))
        return errs

    def _debug_check(self, now: float = 0.0) -> None:
        if runtime_checks_enabled():
            self.check_invariants(now, raise_on_violation=True)


# Legacy name: the synchronous pool grew into the tiered store; callers
# using seed/apply_placement/ensure_local/check_invariant are unchanged.
DistributedAdapterPool = AdapterStore
