"""Baseline placement/routing policies from the paper's evaluation (§V-D):

- S-LoRA Random: static uniform-random adapter->server assignment (what
  Company X runs today per the paper).
- S-LoRA Contiguous: adapters sorted by rank, equal contiguous chunks per
  server (rank-homogeneous servers, load-oblivious).
- Toppings: every adapter replicated on every server (the memory cost the
  paper's Fig 18-bottom charges it for); request-level load-aware routing
  picks the server with the least estimated outstanding work — rank-aware
  in service-time estimation but rank-agnostic in co-batching.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from .placement import assign_loraserve
from .types import AdapterInfo, Placement, PlacementContext


class LoraservePolicy:
    name = "loraserve"
    dynamic = True
    replicate_all = False

    def place(self, ctx: PlacementContext) -> Placement:
        placement, self.last_stats = assign_loraserve(ctx)
        return placement


class RandomPolicy:
    name = "slora-random"
    dynamic = False
    replicate_all = False

    def __init__(self, seed: int = 0):
        self.seed = seed

    def place(self, ctx: PlacementContext) -> Placement:
        rng = random.Random(self.seed)
        ids = ctx.servers()
        return {a.adapter_id: {rng.choice(ids): 1.0}
                for a in ctx.adapters}


class ContiguousPolicy:
    name = "slora-contiguous"
    dynamic = False
    replicate_all = False

    def place(self, ctx: PlacementContext) -> Placement:
        ordered = sorted(ctx.adapters, key=lambda a: a.rank)
        ids = ctx.servers()
        per = -(-len(ordered) // len(ids))
        placement: Placement = {}
        for i, a in enumerate(ordered):
            placement[a.adapter_id] = {ids[min(i // per, len(ids) - 1)]: 1.0}
        return placement


class ToppingsPolicy:
    name = "toppings"
    dynamic = False
    replicate_all = True     # assumes full replication (paper §II-B.2)

    def place(self, ctx: PlacementContext) -> Placement:
        ids = ctx.servers()
        return {a.adapter_id: {s: 1.0 / len(ids) for s in ids}
                for a in ctx.adapters}


POLICIES = {
    "loraserve": LoraservePolicy,
    "slora-random": RandomPolicy,
    "slora-contiguous": ContiguousPolicy,
    "toppings": ToppingsPolicy,
}
