"""Core types for the LORASERVE orchestrator control plane."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

# adapter_id -> {server_id: phi}, with sum(phi.values()) == 1 per adapter.
Placement = Dict[str, Dict[int, float]]


@dataclasses.dataclass(frozen=True)
class AdapterInfo:
    adapter_id: str
    rank: int
    nbytes: int = 0          # host-memory footprint (for pool accounting)


@dataclasses.dataclass
class PlacementContext:
    """Everything a placement policy may look at."""
    n_servers: int
    adapters: List[AdapterInfo]
    demand_tps: Dict[str, float]                  # projected TPS per adapter
    operating_points: Dict[int, float]            # rank -> max TPS under SLO
    prev_placement: Optional[Placement] = None
    # with autoscaling the placeable fleet is no longer 0..n-1: retired
    # and draining servers drop out while their ids stay stable
    server_ids: Optional[List[int]] = None

    def servers(self) -> List[int]:
        """Physical ids of the placeable servers (len == n_servers)."""
        return (list(self.server_ids) if self.server_ids is not None
                else list(range(self.n_servers)))

    def adapter(self, adapter_id: str) -> AdapterInfo:
        return next(a for a in self.adapters if a.adapter_id == adapter_id)


@dataclasses.dataclass
class PlacementStats:
    target_util: float
    rank_server_budget: Dict[int, int]
    server_util: Dict[int, float]
    moved_adapters: int = 0


def placement_servers(placement: Placement, adapter_id: str) -> List[int]:
    return sorted(placement.get(adapter_id, {}).keys())


def servers_to_adapters(placement: Placement) -> Dict[int, List[str]]:
    out: Dict[int, List[str]] = {}
    for aid, entry in placement.items():
        for sid in entry:
            out.setdefault(sid, []).append(aid)
    return out
