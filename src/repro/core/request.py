"""Unified request lifecycle type shared by the simulator and the real
JAX engine.

Historically the repo had two incompatible request classes: the
simulator's ``SimRequest`` (length-only workload, virtual timestamps)
and the engine's ``Request`` (concrete token ids, wall-clock stamps).
``ServeRequest`` merges them: every request carries its workload shape
(``prompt_len``/``output_len``), optionally concrete prompt tokens for
real execution, and one set of lifecycle timestamps on whatever clock
the backend runs (virtual seconds for ``SimBackend``, seconds since run
start for ``EngineBackend``). ``SimRequest`` and ``Request`` remain as
thin compatibility aliases.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    adapter_id: str
    rank: int = 0
    prompt_len: int = 0
    output_len: int = 0
    arrival: float = 0.0
    prompt: Optional[List[int]] = None     # concrete tokens (real engine)
    # lifecycle, stamped on the backend's clock
    ready: float = 0.0                     # arrival + adapter fetch latency
    prefill_start: float = -1.0            # admitted into a prefill batch
    prefill_done: float = -1.0
    finish: float = -1.0
    server: int = -1
    decoded: int = 0
    fetch_latency: float = 0.0
    # remote-read data plane: while the local copy warms (until
    # `remote_until` on the backend clock) every iteration containing
    # this request pays `remote_penalty` seconds of GDR weight streaming
    remote_penalty: float = 0.0
    remote_until: float = -1.0
    # real-engine lifecycle
    phase: Phase = Phase.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                         # engine batch slot
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    def apply_fetch_plan(self, plan, now: float) -> None:
        """Stamp readiness and remote-read fields from an
        ``AdapterStore`` ``FetchPlan`` — the one plan-to-request mapping
        both substrates use: hits and remote reads start immediately
        (remote reads paying the per-iteration streaming tax until the
        warm copy lands), migrate fetches block until the ETA."""
        if plan.blocking:
            self.fetch_latency = max(0.0, plan.eta - now)
            self.ready = plan.eta
        else:
            self.ready = now
            self.fetch_latency = 0.0
            if not plan.hit:
                self.remote_penalty = plan.token_penalty
                self.remote_until = plan.eta

    @property
    def max_new_tokens(self) -> int:
        return self.output_len

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is not None:
            return self.t_first_token - self.arrival
        if self.prompt is not None:        # real request, prefill pending
            return None
        return self.prefill_done - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        if self.prompt is not None:        # real-engine request
            if self.t_finish is None or len(self.output) <= 1 \
                    or self.t_first_token is None:
                return None
            return (self.t_finish - self.t_first_token) / \
                (len(self.output) - 1)
        if self.output_len <= 1 or self.finish < 0:
            return 0.0
        return (self.finish - self.prefill_done) / max(1, self.output_len - 1)


def Request(req_id: int, adapter_id: str, prompt: List[int],
            max_new_tokens: int, arrival: float = 0.0,
            rank: int = 0) -> ServeRequest:
    """Compatibility constructor matching the old engine ``Request``
    signature: concrete prompt tokens + output budget."""
    return ServeRequest(req_id=req_id, adapter_id=adapter_id, rank=rank,
                        prompt_len=len(prompt),
                        output_len=int(max_new_tokens),
                        arrival=arrival, prompt=list(prompt))


# The simulator constructs requests with (req_id, adapter_id, rank,
# prompt_len, output_len, arrival) keywords — same dataclass, same name.
SimRequest = ServeRequest
