"""LORASERVE adapter placement — Algorithm 1, faithfully.

Steps (paper §IV-A):
  1. Estimate per-adapter TPS demand; target utilization per server
     = sum_r rank_util(r) / n_servers, with rank_util(r) =
     sum_{a of rank r} demand(a) / operating_point(r).
  2. Server budget per rank = round(rank_util / target_util) — then
     remainder-adjusted so budgets sum to n_servers (every server gets a
     bin; budget-0 ranks flow to Step 4 exactly as in the paper).
  3. Fractional bin packing of each rank's adapters into its budget of
     bins; adapters split across bins get fractional routing weights phi
     (sum phi = 1). Overflow beyond a rank's bins spills to leftovers.
  4. Leftovers sorted by descending rank; each goes to the bin with the
     highest max-rank (>= its own rank if possible) and least utilization.
  5. Permute bins onto physical servers to maximize overlap with the
     previous placement (minimizes adapter migrations).
  6. The caller updates the routing table / pool from the returned
     Placement.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .types import AdapterInfo, Placement, PlacementContext, PlacementStats


class _Bin:
    __slots__ = ("shares", "util", "ranks")

    def __init__(self):
        self.shares: Dict[str, float] = {}   # adapter -> util placed here
        self.util: float = 0.0
        self.ranks: List[int] = []

    @property
    def max_rank(self) -> int:
        return max(self.ranks) if self.ranks else 0

    def add(self, adapter_id: str, util: float, rank: int) -> None:
        self.shares[adapter_id] = self.shares.get(adapter_id, 0.0) + util
        self.util += util
        self.ranks.append(rank)


def _rank_utils(ctx: PlacementContext) -> Dict[int, float]:
    out: Dict[int, float] = {}
    for a in ctx.adapters:
        load = ctx.demand_tps.get(a.adapter_id, 0.0)
        op = ctx.operating_points[a.rank]
        out[a.rank] = out.get(a.rank, 0.0) + load / op
    return out


def _budgets(rank_util: Dict[int, float], target_util: float,
             n_servers: int) -> Dict[int, int]:
    """Step 2 + remainder fix-up so sum(budgets) == n_servers."""
    raw = {r: u / target_util if target_util > 0 else 0.0
           for r, u in rank_util.items()}
    budget = {r: int(round(v)) for r, v in raw.items()}
    total = sum(budget.values())
    # adjust by largest/smallest fractional remainder
    while total < n_servers:
        r = max(raw, key=lambda r: raw[r] - budget[r])
        budget[r] += 1
        total += 1
    while total > n_servers:
        cands = [r for r in raw if budget[r] > 0]
        r = min(cands, key=lambda r: raw[r] - budget[r])
        budget[r] -= 1
        total -= 1
    return budget


def _fractional_bin_packing(adapters: List[Tuple[str, float, int]],
                            n_bins: int, capacity: float,
                            bins: List[_Bin]) -> List[Tuple[str, float, int]]:
    """Pack (adapter_id, util, rank) items into n_bins fresh bins appended
    to `bins`. Adapters exceeding remaining capacity are split (fractional
    phi). Returns overflow items that did not fit in this rank's budget."""
    mine = [_Bin() for _ in range(n_bins)]
    bins.extend(mine)
    overflow: List[Tuple[str, float, int]] = []
    if not mine:
        return adapters
    items = sorted(adapters, key=lambda t: -t[1])
    bi = 0
    for aid, util, rank in items:
        remaining = util
        while remaining > 1e-12 and bi < len(mine):
            space = capacity - mine[bi].util
            if space <= 1e-12:
                bi += 1
                continue
            placed = min(space, remaining)
            mine[bi].add(aid, placed, rank)
            remaining -= placed
        if remaining > 1e-12:
            overflow.append((aid, remaining, rank))
    return overflow


def _allocate_leftovers(leftovers: List[Tuple[str, float, int]],
                        bins: List[_Bin], capacity: float) -> None:
    """Step 4: descending-rank; prefer bins whose max rank >= adapter rank
    *if possible* (paper's wording) — i.e. only while they have capacity —
    else fall back to the least-utilized bin."""
    for aid, util, rank in sorted(leftovers, key=lambda t: -t[2]):
        eligible = [b for b in bins
                    if b.max_rank >= rank and b.util + util <= capacity]
        pool = eligible or bins
        target = min(pool, key=lambda b: (b.util, -b.max_rank))
        target.add(aid, util, rank)


def _permute(bins: List[_Bin], prev: Optional[Placement],
             server_ids: List[int]) -> List[int]:
    """Step 5: greedy max-overlap matching bins -> physical server ids
    (with autoscaling these need not be 0..n-1)."""
    if not prev:
        return list(server_ids[:len(bins)])
    prev_sets: Dict[int, set] = {s: set() for s in server_ids}
    for aid, entry in prev.items():
        for sid in entry:
            if sid in prev_sets:
                prev_sets[sid].add(aid)
    assigned = [-1] * len(bins)
    free = set(server_ids)
    order = sorted(range(len(bins)),
                   key=lambda i: -len(bins[i].shares))
    for i in order:
        keys = set(bins[i].shares)
        best = max(free, key=lambda s: len(keys & prev_sets[s]))
        assigned[i] = best
        free.discard(best)
    return assigned


def assign_loraserve(ctx: PlacementContext) -> Tuple[Placement,
                                                     PlacementStats]:
    """Algorithm 1: ASSIGNLORASERVE."""
    n = len(ctx.servers())
    # -- Step 1
    rank_util = _rank_utils(ctx)
    total_util = sum(rank_util.values())
    target_util = total_util / n if n else 0.0
    if target_util <= 0:
        target_util = 1e-9
    # -- Step 2
    budget = _budgets(rank_util, target_util, n)
    # -- Step 3
    by_rank: Dict[int, List[Tuple[str, float, int]]] = {}
    for a in ctx.adapters:
        util = ctx.demand_tps.get(a.adapter_id, 0.0) / \
            ctx.operating_points[a.rank]
        by_rank.setdefault(a.rank, []).append((a.adapter_id, util, a.rank))
    bins: List[_Bin] = []
    leftovers: List[Tuple[str, float, int]] = []
    for rank in sorted(by_rank, reverse=True):
        over = _fractional_bin_packing(by_rank[rank], budget.get(rank, 0),
                                       target_util, bins)
        leftovers.extend(over)
    # -- Step 4
    _allocate_leftovers(leftovers, bins, target_util)
    # -- Step 5
    server_of_bin = _permute(bins, ctx.prev_placement, ctx.servers())
    # -- Build placement with normalized phi
    placement: Placement = {}
    for b, sid in zip(bins, server_of_bin):
        for aid, util in b.shares.items():
            placement.setdefault(aid, {})
            placement[aid][sid] = placement[aid].get(sid, 0.0) + util
    for a in ctx.adapters:
        aid = a.adapter_id
        entry = placement.setdefault(aid, {})
        if not entry:
            # zero-demand adapter: park on least-utilized bin's server
            i = min(range(len(bins)), key=lambda i: bins[i].util)
            entry[server_of_bin[i]] = 1.0
            continue
        tot = sum(entry.values())
        if tot <= 0:
            # zero-demand adapters land on one leftover bin: equal phi
            for sid in entry:
                entry[sid] = 1.0 / len(entry)
        else:
            for sid in entry:
                entry[sid] = entry[sid] / tot
    moved = 0
    if ctx.prev_placement:
        for aid, entry in placement.items():
            prev_s = set(ctx.prev_placement.get(aid, {}))
            moved += len(set(entry) - prev_s)
    stats = PlacementStats(
        target_util=target_util,
        rank_server_budget=budget,
        server_util={server_of_bin[i]: bins[i].util
                     for i in range(len(bins))},
        moved_adapters=moved,
    )
    return placement, stats
