"""Per-adapter demand (TPS) tracking and extrapolation — Algorithm 1 Step 1.

``GETPREVTIMESTEPTPS`` + ``EXTRAPOLATE``: the projected demand for the next
timestep is an EWMA-smoothed level plus a clipped linear trend, which
tracks the gradual drifts / diurnal patterns in the production traces
(paper Fig 10) without overreacting to bursts.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict


class DemandEstimator:
    def __init__(self, alpha: float = 0.5, trend_beta: float = 0.5,
                 history: int = 16):
        self.alpha = alpha
        self.trend_beta = trend_beta
        self.tps_history: Dict[str, Deque[float]] = {}
        self._level: Dict[str, float] = {}
        self._trend: Dict[str, float] = {}
        self.history = history

    def observe(self, adapter_id: str, tps: float) -> None:
        """Record the measured TPS of the finished timestep (Step 1 line 4)."""
        h = self.tps_history.setdefault(
            adapter_id, collections.deque(maxlen=self.history))
        h.append(tps)
        prev_level = self._level.get(adapter_id)
        if prev_level is None:
            self._level[adapter_id] = tps
            self._trend[adapter_id] = 0.0
        else:  # Holt's linear smoothing
            level = self.alpha * tps + (1 - self.alpha) * (
                prev_level + self._trend[adapter_id])
            self._trend[adapter_id] = (
                self.trend_beta * (level - prev_level)
                + (1 - self.trend_beta) * self._trend[adapter_id])
            self._level[adapter_id] = level

    def extrapolate(self, adapter_id: str) -> float:
        """Projected TPS for the next timestep (Step 1 line 5)."""
        level = self._level.get(adapter_id, 0.0)
        trend = self._trend.get(adapter_id, 0.0)
        return max(0.0, level + trend)

    def demands(self, adapter_ids) -> Dict[str, float]:
        return {a: self.extrapolate(a) for a in adapter_ids}
