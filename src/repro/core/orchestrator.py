"""Cluster orchestrator facade (paper Fig 11): owns the placement policy,
routing table, distributed adapter pool, and demand estimator. The
discrete-event simulator drives it; ``launch/serve.py`` drives the same
object against real JAX engines for the end-to-end example.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .baselines import POLICIES
from .demand import DemandEstimator
from .pool import DistributedAdapterPool
from .routing import RoutingTable
from .types import AdapterInfo, Placement, PlacementContext


class ClusterOrchestrator:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 operating_points: Dict[int, float],
                 policy: str = "loraserve", network=None, seed: int = 0):
        self.n = n_servers
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.operating_points = operating_points
        self.policy = POLICIES[policy]() if isinstance(policy, str) \
            else policy
        self.demand = DemandEstimator()
        ctx = PlacementContext(
            n_servers=n_servers, adapters=adapters,
            demand_tps={a.adapter_id: 1.0 for a in adapters},
            operating_points=operating_points)
        self.placement: Placement = self.policy.place(ctx)
        self.router = RoutingTable(self.placement, seed=seed)
        self.pool = DistributedAdapterPool(n_servers, adapters, network)
        self.pool.seed(self.placement)
        self._window_tokens: Dict[str, float] = {}

    # -- request path (Fig 11 steps 1-4) ----------------------------------
    def route(self, adapter_id: str, tokens: float = 0.0):
        """Returns (server_id, fetch_latency_seconds)."""
        sid = self.router.route(adapter_id, tokens)
        lat, _ = self.pool.ensure_local(sid, adapter_id)
        self._window_tokens[adapter_id] = \
            self._window_tokens.get(adapter_id, 0.0) + tokens
        return sid, lat

    # -- control path (Fig 11 steps 6-7) -----------------------------------
    def end_of_timestep(self, period_s: float) -> Placement:
        for aid in self.meta:
            self.demand.observe(aid, self._window_tokens.get(aid, 0.0)
                                / period_s)
        self._window_tokens = {}
        if self.policy.dynamic:
            ctx = PlacementContext(
                n_servers=self.n, adapters=self.adapters,
                demand_tps=self.demand.demands(list(self.meta)),
                operating_points=self.operating_points,
                prev_placement=self.placement)
            self.placement = self.policy.place(ctx)
            self.router.update(self.placement)
            self.pool.apply_placement(self.placement)
        return self.placement
