"""Cluster orchestrator facade (paper Fig 11): owns the placement policy,
routing table, tiered adapter store, and demand estimator. The
discrete-event simulator drives it; ``launch/serve.py`` drives the same
object against real JAX engines for the end-to-end example.

The request path speaks ``FetchPlan``s: ``route_plan`` routes a request
and asks the ``AdapterStore`` how its adapter will be served — a hit, a
blocking migrate fetch (async, completing at ``plan.eta``), or a GDR
remote read from a peer while the local copy warms (``access_mode=
"remote-read"``). The legacy ``route`` keeps the old synchronous
(server_id, latency) contract on top of the same store.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .baselines import POLICIES
from .demand import DemandEstimator
from .pool import AdapterStore, FetchPlan, FetchRetryPolicy
from .routing import RoutingTable
from .types import AdapterInfo, Placement, PlacementContext


class ClusterOrchestrator:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 operating_points: Dict[int, float],
                 policy: str = "loraserve", network=None, seed: int = 0,
                 access_mode: str = "migrate", prefetch: bool = False,
                 sync_store: bool = True,
                 retry: Optional["FetchRetryPolicy"] = None,
                 durable_ssd: bool = False):
        if access_mode not in ("migrate", "remote-read"):
            raise ValueError(f"unknown access_mode {access_mode!r}")
        # sync_store: legacy clock-less callers (route()/end_of_timestep
        # with the default now=0.0) have no event loop to drive
        # store.poll(); prefetch warms then complete synchronously so
        # transfers cannot strand on links or pin GC. Async drivers
        # (LoRAServeCluster) pass sync_store=False and poll themselves.
        self.sync_store = sync_store
        self.n = n_servers
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.operating_points = operating_points
        self.policy = POLICIES[policy]() if isinstance(policy, str) \
            else policy
        self.access_mode = access_mode
        self.prefetch = prefetch
        self.demand = DemandEstimator()
        # fleet lifecycle (controlplane scale/drain): ids are stable,
        # placement is solved over active-minus-draining only
        self.active: List[int] = list(range(n_servers))
        self.draining: set = set()
        # adapter lifecycle: ids mid loss-free retire — routing entries
        # already gone, copies leave once the host signals quiescence
        self.retiring: set = set()
        ctx = PlacementContext(
            n_servers=n_servers, adapters=adapters,
            demand_tps={a.adapter_id: 1.0 for a in adapters},
            operating_points=operating_points)
        self.placement: Placement = self.policy.place(ctx)
        self.router = RoutingTable(self.placement, seed=seed)
        # one AdapterStore; `pool` kept as the legacy name
        self.store = self.pool = AdapterStore(n_servers, adapters,
                                              network, retry=retry,
                                              durable_ssd=durable_ssd,
                                              retry_seed=seed)
        self.store.seed(self.placement)
        self._window_tokens: Dict[str, float] = {}

    # -- request path (Fig 11 steps 1-4) ----------------------------------
    def route_plan(self, adapter_id: str, tokens: float = 0.0,
                   now: float = 0.0) -> Tuple[int, FetchPlan]:
        """Route a request and plan its adapter's data path. Returns
        (server_id, FetchPlan); the plan is a hit, an async migrate
        fetch, or a remote-read serve depending on residency and the
        configured access mode."""
        sid, entry = self.router.route_detailed(adapter_id, tokens)
        # remote reads prefer peers the adapter is *placed* on
        plan = self.store.plan_access(sid, adapter_id, now=now,
                                      access_mode=self.access_mode,
                                      preferred_peers=[s for s, _ in
                                                       entry])
        if self.sync_store:
            # no event loop will poll(): complete the transfer now so
            # it cannot strand on links or pin GC; the plan still
            # carries the modeled latency/ETA for accounting
            self.store.finish(plan)
        self._window_tokens[adapter_id] = \
            self._window_tokens.get(adapter_id, 0.0) + tokens
        return sid, plan

    def route(self, adapter_id: str, tokens: float = 0.0,
              now: float = 0.0):
        """Legacy synchronous path: returns (server_id,
        fetch_latency_seconds); the fetch completes instantly. Callers
        combining this path with ``prefetch=True`` should pass their
        clock as ``now`` so background prefetch transfers (completed by
        ``ensure_local``'s internal poll) land and release their
        links."""
        sid = self.router.route(adapter_id, tokens)
        lat, _ = self.store.ensure_local(sid, adapter_id, now=now)
        self._window_tokens[adapter_id] = \
            self._window_tokens.get(adapter_id, 0.0) + tokens
        return sid, lat

    # -- control path (Fig 11 steps 6-7) -----------------------------------
    def placeable_servers(self) -> List[int]:
        return [s for s in self.active if s not in self.draining]

    def end_of_timestep(self, period_s: float,
                        now: float = 0.0) -> Placement:
        for aid in self.meta:
            self.demand.observe(aid, self._window_tokens.get(aid, 0.0)
                                / period_s)
        self._window_tokens = {}
        if self.policy.dynamic:
            self._resolve(now)
        return self.placement

    def _resolve(self, now: float) -> List[FetchPlan]:
        """Re-solve placement over the placeable fleet and push it into
        the routing table + store. Returns any started prefetch plans
        (already completed when ``sync_store``)."""
        ids = self.placeable_servers()
        ctx = PlacementContext(
            n_servers=len(ids), adapters=self.adapters,
            demand_tps=self.demand.demands(list(self.meta)),
            operating_points=self.operating_points,
            prev_placement=self.placement, server_ids=ids)
        self.placement = self.policy.place(ctx)
        self.router.update(self.placement)
        plans = self.store.apply_placement(self.placement, now=now,
                                           prefetch=self.prefetch)
        if self.sync_store:
            for p in plans:
                self.store.finish(p)
        return plans

    # -- adapter lifecycle (runtime register / loss-free retire) -----------
    def register_adapter(self, info: AdapterInfo, now: float = 0.0,
                         server: Optional[int] = None) -> int:
        """Make a new adapter servable mid-run. Its first copy lands on
        ``server`` (default: the placeable server holding the fewest
        adapters) with a single full-phi route; the next
        ``end_of_timestep`` folds it into the demand-driven placement
        like any other adapter. Returns the chosen server id."""
        aid = info.adapter_id
        if aid in self.meta:
            raise ValueError(f"adapter {aid!r} already registered")
        if server is None:
            server = min(self.placeable_servers(),
                         key=lambda s: (self.store.server_adapter_count(s),
                                        s))
        elif server not in self.placeable_servers():
            raise RuntimeError(f"register of {aid!r} on non-placeable "
                               f"server {server}")
        self.adapters.append(info)
        self.meta[aid] = info
        self.placement[aid] = {server: 1.0}
        self.router.update(self.placement)
        self.store.register_adapter(info, server)
        return server

    def begin_retire_adapter(self, adapter_id: str) -> None:
        """Start a loss-free adapter retire: routing stops now (new
        routes raise ``UnknownAdapterError``), placement forgets it, the
        store keeps its copies readable until ``finish_retire_adapter``.
        In-flight requests referencing it are unaffected."""
        if adapter_id not in self.meta:
            raise KeyError(adapter_id)
        self.retiring.add(adapter_id)
        self.adapters[:] = [a for a in self.adapters
                            if a.adapter_id != adapter_id]
        self.meta.pop(adapter_id, None)
        self.placement.pop(adapter_id, None)
        self.router.remove_adapter(adapter_id)
        # popping `desired` freezes GC for this adapter: its copies
        # survive (readable by in-flight work) until deregistration
        self.store.desired.pop(adapter_id, None)
        self._window_tokens.pop(adapter_id, None)

    def finish_retire_adapter(self, adapter_id: str) -> None:
        """Complete a retire once the host observes quiescence (no live
        requests, no transfers): purge every copy from every tier."""
        self.store.deregister_adapter(adapter_id)
        self.retiring.discard(adapter_id)

    # -- fleet lifecycle (controlplane scale-up / drain / retire) ----------
    def add_server(self, now: float = 0.0) -> int:
        """Provision one server and fold it into a fresh placement.
        Returns the new (stable) server id."""
        sid = self.store.add_server()
        self.n = self.store.n_servers
        self.active.append(sid)
        self._resolve(now)
        return sid

    def begin_drain(self, server_id: int,
                    now: float = 0.0) -> List[FetchPlan]:
        """Take ``server_id`` out of placement and routing, then migrate
        its holdings to the survivors through the store. Returns the
        in-flight migration plans (the caller turns their ETAs into
        fetch events; empty when ``sync_store`` completed them)."""
        if server_id in self.draining:
            return []
        self.draining.add(server_id)
        self._resolve(now)
        plans = self.store.drain_server(server_id, now=now)
        if self.sync_store:
            for p in plans:
                self.store.finish(p)
            return []
        return plans

    def drain_complete(self, server_id: int) -> bool:
        """Whether the store side of a drain has finished: no copies
        left on the server and no transfers touching it. (The host also
        checks its backend for still-running requests.)"""
        return (self.store.server_adapter_count(server_id) == 0
                and self.store.inflight_from(server_id) == 0
                and self.store.inflight_to(server_id) == 0)

    def retire_server(self, server_id: int) -> None:
        self.store.retire_server(server_id)
        self.router.block_server(server_id)
        self.draining.discard(server_id)
        self.active.remove(server_id)

    # -- fault plane (repro.faults crash -> recover -> restore) ------------
    def fail_server(self, server_id: int,
                    now: float = 0.0) -> List[FetchPlan]:
        """Crash-triggered recovery, ordered so every intermediate state
        is consistent: (1) the store drops the dead server's copies and
        re-sources its transfers, (2) placement re-solves over the
        survivors and the routing table updates (entries no longer
        reference the dead server), (3) the server is blocked so a stale
        route raises instead of dispatching. Orphaned adapters re-warm
        via prefetch onto survivors (from host cache, a surviving peer,
        or the durable SSD tier). Returns the recovery fetch plans."""
        if server_id in self.draining:
            self.draining.discard(server_id)
        if server_id not in self.active:
            raise RuntimeError(f"crash of unknown/retired server "
                               f"{server_id}")
        self.store.fail_server(server_id, now=now)
        self.active.remove(server_id)
        prefetch, self.prefetch = self.prefetch, True
        try:
            plans = self._resolve(now)
        finally:
            self.prefetch = prefetch
        self.router.block_server(server_id)
        return plans

    def restore_server(self, server_id: int, now: float = 0.0) -> None:
        """Bring a crashed server back (empty): unblock routing, rejoin
        the active fleet, and re-solve placement so copies re-warm onto
        it."""
        if server_id in self.active:
            return
        self.store.restore_server(server_id)
        self.router.unblock_server(server_id)
        self.active.append(server_id)
        self.active.sort()
        self._resolve(now)
