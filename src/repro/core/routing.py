"""Routing table + phi-weighted request routing (paper Fig 11 steps 1-2).

The routing table holds (adapter_id, server_id, phi) tuples with
sum(phi) = 1 per adapter; a request is dispatched to server s with
probability phi_s. Toppings-style request-level routing is implemented in
baselines.py (it bypasses phi and queries live server load).
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from .types import Placement


class UnknownAdapterError(KeyError):
    """Raised when routing is asked about an adapter with no placement
    entry (never placed, or dropped from the routing table)."""

    def __init__(self, adapter_id: str):
        super().__init__(adapter_id)
        self.adapter_id = adapter_id

    def __str__(self) -> str:
        return (f"adapter {self.adapter_id!r} has no entry in the routing "
                f"table — it was never placed (or was dropped by a "
                f"placement update)")


class RetiredServerError(RuntimeError):
    """Raised when a placement or route would touch a retired server —
    the control plane's loss-free-drain guarantee made loud."""


class RoutingTable:
    def __init__(self, placement: Optional[Placement] = None, seed: int = 0):
        self._rng = random.Random(seed)
        self._table: Dict[str, List[Tuple[int, float]]] = {}
        self.request_counts: Dict[str, int] = {}
        self.token_counts: Dict[str, float] = {}
        self.blocked: set = set()          # retired server ids
        if placement:
            self.update(placement)

    def update(self, placement: Placement) -> None:
        table = {}
        for aid, entry in placement.items():
            items = sorted(entry.items())
            bad = [sid for sid, _ in items if sid in self.blocked]
            if bad:
                raise RetiredServerError(
                    f"placement routes adapter {aid!r} to retired "
                    f"server(s) {bad}")
            tot = sum(phi for _, phi in items)
            assert tot > 0, f"adapter {aid} has zero total phi"
            table[aid] = [(sid, phi / tot) for sid, phi in items]
        self._table = table

    def remove_adapter(self, adapter_id: str) -> None:
        """Drop an adapter's routing entry (runtime deregister): every
        subsequent route for it raises ``UnknownAdapterError``. No-op if
        it was never routed."""
        self._table.pop(adapter_id, None)

    def block_server(self, server_id: int) -> None:
        """Retire ``server_id`` from routing: strip it from every entry
        (renormalizing phi over the survivors) and refuse it in all
        future placements. An adapter whose *only* route was the blocked
        server raises — the drain that preceded retirement must already
        have re-placed it."""
        self.blocked.add(server_id)
        for aid, entry in list(self._table.items()):
            kept = [(sid, phi) for sid, phi in entry if sid != server_id]
            if len(kept) == len(entry):
                continue
            if not kept:
                raise RetiredServerError(
                    f"adapter {aid!r} has no route left after retiring "
                    f"server {server_id}")
            tot = sum(phi for _, phi in kept)
            self._table[aid] = [(sid, phi / tot) if tot > 0
                                else (sid, 1.0 / len(kept))
                                for sid, phi in kept]

    def unblock_server(self, server_id: int) -> None:
        """Re-admit a previously blocked server (crash -> restore in the
        fault plane): future placements may route to it again. Existing
        entries are untouched — the next placement update re-spreads
        phi."""
        self.blocked.discard(server_id)

    def servers(self, adapter_id: str) -> List[Tuple[int, float]]:
        try:
            return list(self._table[adapter_id])
        except KeyError:
            raise UnknownAdapterError(adapter_id) from None

    def route(self, adapter_id: str, tokens: float = 0.0) -> int:
        return self.route_detailed(adapter_id, tokens)[0]

    def route_detailed(self, adapter_id: str, tokens: float = 0.0
                       ) -> Tuple[int, List[Tuple[int, float]]]:
        """Route plus the adapter's full phi entry. The alternates feed
        the data plane's ``FetchPlan``: on a miss, a remote read prefers
        peers the adapter is *placed* on (they are guaranteed warm and
        phi-weighted), not just any current holder."""
        try:
            entry = self._table[adapter_id]
        except KeyError:
            raise UnknownAdapterError(adapter_id) from None
        self.request_counts[adapter_id] = \
            self.request_counts.get(adapter_id, 0) + 1
        self.token_counts[adapter_id] = \
            self.token_counts.get(adapter_id, 0.0) + tokens
        if len(entry) == 1:
            return self._checked(entry[0][0]), list(entry)
        u = self._rng.random()
        acc = 0.0
        for sid, phi in entry:
            acc += phi
            if u <= acc:
                return self._checked(sid), list(entry)
        return self._checked(entry[-1][0]), list(entry)

    def _checked(self, sid: int) -> int:
        if sid in self.blocked:
            raise RetiredServerError(f"routed to retired server {sid}")
        return sid

    def reset_counts(self) -> Dict[str, int]:
        counts = self.request_counts
        self.request_counts = {}
        self.token_counts = {}
        return counts
