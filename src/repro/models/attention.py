"""Attention variants: GQA (optionally sliding-window ring cache), MLA
(DeepSeek-V2 latent attention), and cross-attention (VLM / enc-dec).

Every projection accepts an optional ``lora`` hook: a callable
``lora(name, x) -> delta`` used by the serving engine to add batched
heterogeneous-adapter deltas on the Q/K/V/O projections (the paper's
attach points).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

import numpy as _np
from jax.sharding import PartitionSpec as P

from .common import (SHARDING_MODE, apply_rope, attend_cache, constrain,
                     constrain_resid, current_axis_env, dense_init,
                     flash_attention, rmsnorm)


def _zero_lora(name, x):
    return 0.0


# shard_map moved to the jax root (and check_rep became check_vma) in
# newer jax; support both so the head-parallel path runs on the pinned
# 0.4.x toolchain too.
try:
    from jax import shard_map as _shard_map
    _SM_NOCHECK = {"check_vma": False}
except ImportError:                                    # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_NOCHECK = {"check_rep": False}


def run_flash(q, k, v, *, causal, q_positions, k_positions, window=0,
              scale=None, extra_qk=None):
    """Flash attention, head-parallel under shard_map when the mesh
    divides the head dims (§Perf: keeping the kv-chunk scan fully local
    stops the SPMD partitioner from resharding scores in the backward
    pass). Falls back to the plain (GSPMD) path otherwise."""
    env = current_axis_env()
    kw = dict(causal=causal, q_positions=q_positions,
              k_positions=k_positions, window=window, scale=scale,
              extra_qk=extra_qk)
    if SHARDING_MODE == "baseline" or env.mesh is None or env.model is None:
        return flash_attention(q, k, v, **kw)
    mesh, m = env.mesh, env.model
    n = mesh.shape[m]
    B, _, H, _ = q.shape
    Kv = k.shape[2]
    if H % n or Kv % n:
        return flash_attention(q, k, v, **kw)
    bsz = int(_np.prod([mesh.shape[a] for a in env.batch])) \
        if env.batch else 1
    bspec = (env.batch if len(env.batch) > 1 else env.batch[0]) \
        if env.batch and B % bsz == 0 else None
    hspec = P(bspec, None, m, None)

    if extra_qk is not None:
        q2, k2 = extra_qk

        def local(q, k, v, q2, k2):
            return flash_attention(q, k, v, **{**kw, "extra_qk": (q2, k2)})

        return _shard_map(local, mesh=mesh,
                          in_specs=(hspec, hspec, hspec, hspec,
                                    P(bspec, None, None)),
                          out_specs=hspec,
                          **_SM_NOCHECK)(q, k, v, q2, k2)

    def local(q, k, v):
        return flash_attention(q, k, v, **kw)

    return _shard_map(local, mesh=mesh, in_specs=(hspec, hspec, hspec),
                      out_specs=hspec, **_SM_NOCHECK)(q, k, v)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(cfg, key, dtype=jnp.float32):
    d, H, Kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, Kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, Kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Kv * hd,), dtype)
        p["bv"] = jnp.zeros((Kv * hd,), dtype)
    return p


def _qkv(cfg, p, x, positions, lora, rope: bool = True):
    B, S, d = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"] + lora("q", x)
    k = x @ p["wk"] + lora("k", x)
    v = x @ p["wv"] + lora("v", x)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    return q, k, v


def _mesh_model_size() -> int:
    env = current_axis_env()
    if SHARDING_MODE == "baseline" or env.mesh is None or env.model is None:
        return 0
    return env.mesh.shape[env.model]


def _regroup_plan(H: int, Kv: int, n: int):
    """Find (rep, Gp) such that Kv*rep divides the n-way model axis and
    queries regroup into Kv*rep uniform groups of Gp = ceil(G/rep) —
    padding each sub-group with zero queries when rep does not divide G.
    Returns None when no plan exists (or none is needed)."""
    if n == 0 or Kv % n == 0:
        return None
    G = H // Kv
    rep = 1
    while rep <= G:
        if (Kv * rep) % n == 0:
            return rep, -(-G // rep)
        rep += 1
    return None


def _pad_regroup_q(q, Kv: int, rep: int, Gp: int):
    """q: (B,S,H,hd) with H = Kv*G -> (B,S,Kv*rep*Gp,hd): each kv head's
    G queries are split across its `rep` duplicates in Gp-sized
    sub-groups, zero-padded to uniform size (zero queries attend
    uniformly; their outputs are sliced away by _unpad_o)."""
    B, S, H, hd = q.shape
    G = H // Kv
    qr = q.reshape(B, S, Kv, G, hd)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, rep * Gp - G), (0, 0)))
    return qr.reshape(B, S, Kv * rep * Gp, hd)


def _unpad_o(o, Kv: int, G: int, rep: int, Gp: int):
    B, S = o.shape[:2]
    hd = o.shape[-1]
    orr = o.reshape(B, S, Kv, rep * Gp, hd)
    return orr[:, :, :, :G].reshape(B, S, Kv * G, hd)


def gqa_full(cfg, p, x, positions, *, causal=True, window=0,
             lora: Optional[Callable] = None):
    """Full-sequence attention. Returns (out, (k, v)) for cache seeding."""
    lora = lora or _zero_lora
    q, k, v = _qkv(cfg, p, x, positions, lora)
    H, Kv = cfg.n_heads, cfg.n_kv_heads
    plan = _regroup_plan(H, Kv, _mesh_model_size())
    if plan is not None:
        # §Perf iter 4: duplicate kv heads (+ zero-pad query groups) so
        # the head dims divide the mesh and the shard_map flash path
        # engages — an identity transform, validated in
        # test_models_features.test_kv_regroup_identity.
        rep, Gp = plan
        qf = _pad_regroup_q(q, Kv, rep, Gp)
        kf = jnp.repeat(k, rep, axis=2)
        vf = jnp.repeat(v, rep, axis=2)
        o = run_flash(qf, kf, vf, causal=causal, q_positions=positions,
                      k_positions=positions, window=window,
                      scale=1.0 / (cfg.resolved_head_dim ** 0.5))
        o = _unpad_o(o, Kv, H // Kv, rep, Gp)
    else:
        o = run_flash(q, k, v, causal=causal, q_positions=positions,
                      k_positions=positions, window=window)
    B, S = x.shape[:2]
    o = o.reshape(B, S, -1)
    out = o @ p["wo"] + lora("o", o)
    return constrain_resid(out), (k, v)


def gqa_decode(cfg, p, x, k_cache, v_cache, pos, *, window=0,
               lora: Optional[Callable] = None):
    """Single-token decode. x: (B,1,d); caches (B,S,Kv,hd); pos: (B,) int32
    current position of the new token per row. Returns (out, (k_cache,
    v_cache)) with the new token written (ring-indexed when window>0)."""
    lora = lora or _zero_lora
    B = x.shape[0]
    S = k_cache.shape[1]
    q, k, v = _qkv(cfg, p, x, pos[:, None], lora)
    if SHARDING_MODE != "baseline":
        # opt (§Perf iter 1): the cache is sequence-sharded over the model
        # axis (context-parallel decode); the new token's k/v is tiny —
        # replicate it rather than asking for a kv-head layout the mesh
        # cannot divide (avoids the (8,2)<->(16,1) reshard storm).
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    write_idx = pos % S if window else pos
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, write_idx].set(k[:, 0])
    v_cache = v_cache.at[bidx, write_idx].set(v[:, 0])
    slots = jnp.arange(S)[None, :]
    valid = slots <= jnp.minimum(pos, S - 1)[:, None]
    o = attend_cache(q, k_cache, v_cache, valid)
    o = o.reshape(B, 1, -1)
    out = o @ p["wo"] + lora("o", o)
    return constrain_resid(out), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache of (c_kv, k_rope).
# ---------------------------------------------------------------------------


def init_mla(cfg, key, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, H * qd), dtype=dtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype=dtype),
        "ln_kv": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H * m.qk_nope_head_dim),
                           dtype=dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H * m.v_head_dim),
                           dtype=dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dtype=dtype),
    }


def _mla_q(cfg, p, x, positions, lora):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = (x @ p["wq"] + lora("q", x)).reshape(B, S, H, qd)
    qn, qr = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return jnp.concatenate([qn, qr], axis=-1)


def _mla_ckv(cfg, p, x, positions, lora):
    m = cfg.mla
    B, S, _ = x.shape
    dkv = x @ p["w_dkv"] + lora("k", x)
    c, kr = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c = rmsnorm(c, p["ln_kv"], cfg.rmsnorm_eps)
    kr = apply_rope(kr.reshape(B, S, 1, m.qk_rope_head_dim), positions,
                    cfg.rope_theta)
    return c, kr


def _mla_expand(cfg, p, c):
    """Expand compressed cache into per-head K_nope and V."""
    m = cfg.mla
    B, S, _ = c.shape
    H = cfg.n_heads
    kn = (c @ p["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    return kn, v


def mla_full(cfg, p, x, positions, *, causal=True, window=0, lora=None):
    lora = lora or _zero_lora
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = _mla_q(cfg, p, x, positions, lora)
    c, kr = _mla_ckv(cfg, p, x, positions, lora)
    kn, v = _mla_expand(cfg, p, c)
    # score = q_nope.k_nope + q_rope.k_rope computed as two einsums — the
    # shared rope key never gets broadcast+concat'd into a per-head K
    # (§Perf iter 2d: that concat reshards scores inside the kv scan)
    qn, qr = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    o = run_flash(qn, kn, v, causal=causal, q_positions=positions,
                  k_positions=positions, window=window, scale=scale,
                  extra_qk=(qr, kr[:, :, 0, :]))
    o = o.reshape(B, S, -1)
    out = o @ p["wo"] + lora("o", o)
    return constrain_resid(out), (c, kr[:, :, 0, :])


def mla_decode(cfg, p, x, c_cache, kr_cache, pos, *, window=0, lora=None,
               absorbed: bool = False):
    """c_cache: (B,S,kv_lora); kr_cache: (B,S,rope_dim).

    ``absorbed=False`` is the paper-faithful naive path: expand the full
    cached latent into per-head K/V each step. ``absorbed=True`` applies the
    W_UK/W_UV absorption identity (beyond-paper optimization, §Perf):
    score = (q_nope @ W_UK^T) · c  — never materializes per-head K/V.
    """
    lora = lora or _zero_lora
    m = cfg.mla
    B = x.shape[0]
    S = c_cache.shape[1]
    H = cfg.n_heads
    q = _mla_q(cfg, p, x, pos[:, None], lora)          # (B,1,H,qd)
    c_t, kr_t = _mla_ckv(cfg, p, x, pos[:, None], lora)
    write_idx = pos % S if window else pos
    bidx = jnp.arange(B)
    c_cache = c_cache.at[bidx, write_idx].set(c_t[:, 0])
    kr_cache = kr_cache.at[bidx, write_idx].set(kr_t[:, 0, 0])
    slots = jnp.arange(S)[None, :]
    valid = slots <= jnp.minimum(pos, S - 1)[:, None]
    qn, qr = jnp.split(q[:, 0], [m.qk_nope_head_dim], axis=-1)  # (B,H,*)
    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    if absorbed:
        wuk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bhn,rhn->bhr", qn.astype(jnp.float32),
                           wuk.astype(jnp.float32))
        s = jnp.einsum("bhr,bsr->bhs", q_lat,
                       c_cache.astype(jnp.float32))
        s += jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
        s = jnp.where(valid[:, None, :], s * scale, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsr->bhr", pr, c_cache.astype(jnp.float32))
        wuv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
        o = jnp.einsum("bhr,rhv->bhv", o_lat, wuv.astype(jnp.float32))
        o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    else:
        kn, v = _mla_expand(cfg, p, c_cache)           # (B,S,H,*)
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr_cache[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
        o = attend_cache(q, k, v, valid, scale=scale)
        o = o.reshape(B, 1, -1)
    out = o @ p["wo"] + lora("o", o)
    return constrain_resid(out), (c_cache, kr_cache)


# ---------------------------------------------------------------------------
# Cross attention (VLM image layers / enc-dec decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(cfg, key, dtype=jnp.float32):
    d, H, Kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, Kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, Kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }


def cross_kv(cfg, p, memory):
    """Precompute cross-attn K/V from memory (B,M,d). Cached once."""
    B, M, _ = memory.shape
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (memory @ p["wk"]).reshape(B, M, Kv, hd)
    v = (memory @ p["wv"]).reshape(B, M, Kv, hd)
    return k, v


def cross_attend(cfg, p, x, k, v, lora=None):
    """x: (B,S,d) queries; k/v: (B,M,Kv,hd) precomputed. Non-causal."""
    lora = lora or _zero_lora
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"] + lora("q", x)).reshape(B, S, H, hd)
    q = constrain(q, "batch", None, "model", None)
    if S == 1:
        M = k.shape[1]
        valid = jnp.ones((B, M), dtype=bool)
        o = attend_cache(q, k, v, valid)
    else:
        M = k.shape[1]
        o = flash_attention(q, k, v, causal=False,
                            q_positions=jnp.arange(S),
                            k_positions=jnp.arange(M))
    o = o.reshape(B, S, -1)
    out = o @ p["wo"] + lora("o", o)
    return constrain_resid(out)
