"""State-space blocks: Mamba2 (SSD recurrence, zamba2 hybrid) and RWKV-6
"Finch" (data-dependent decay WKV). Both expose full-sequence (scan over
time) and single-step decode forms with explicit state pytrees.

Simplifications (documented in DESIGN.md §4): Mamba2 omits the depthwise
conv-4 front; RWKV6 uses learned per-channel token-shift mixing vectors
(the ddlerp LoRA is kept only for the decay, which is the defining
data-dependent component of RWKV-6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import constrain, constrain_resid, dense_init, rmsnorm

# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba_dims(cfg):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    H = inner // s.head_dim
    return inner, H, s.head_dim, s.d_state


def init_mamba2(cfg, key, dtype=jnp.float32):
    d = cfg.d_model
    inner, H, hd, N = mamba_dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_xz": dense_init(ks[0], (d, 2 * inner), dtype=dtype),
        "w_bc": dense_init(ks[1], (d, 2 * N), dtype=dtype),
        "w_dt": dense_init(ks[2], (d, H), dtype=dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "ln_y": jnp.ones((inner,), dtype),
        "w_out": dense_init(ks[3], (inner, d), fan_in=inner, dtype=dtype),
    }


def mamba2_state(cfg, batch, dtype=jnp.float32):
    _, H, hd, N = mamba_dims(cfg)
    return jnp.zeros((batch, H, hd, N), dtype)


def _mamba_proj(cfg, p, u):
    """u: (B,S,d) -> x (B,S,H,hd), z (B,S,inner), b,c (B,S,N), a (B,S,H),
    dt (B,S,H)."""
    inner, H, hd, N = mamba_dims(cfg)
    xz = u @ p["w_xz"]
    xz = constrain(xz, "batch", None, "model")
    x, z = jnp.split(xz, 2, axis=-1)
    bc = u @ p["w_bc"]
    b, c = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(u @ p["w_dt"] + p["dt_bias"])      # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                  # decay in (0,1)
    x = x.reshape(*x.shape[:-1], H, hd)
    return x, z, b, c, a, dt


def _mamba_out(cfg, p, y, z, x, dt):
    """y: (B,S,H,hd) ssm output; gate and project."""
    B, S = y.shape[:2]
    inner, H, hd, N = mamba_dims(cfg)
    y = y + p["D"][:, None] * x                              # skip
    y = y.reshape(B, S, inner)
    y = rmsnorm(y * jax.nn.silu(z), p["ln_y"], cfg.rmsnorm_eps)
    out = y @ p["w_out"]
    return constrain_resid(out)


def mamba2_full(cfg, p, u, state):
    """u: (B,S,d); state: (B,H,hd,N). Returns (out, new_state)."""
    x, z, b, c, a, dt = _mamba_proj(cfg, p, u)
    dtx = x * dt[..., None]                                  # (B,S,H,hd)

    def step(s, inp):
        xt, bt, ct, at = inp                                 # (B,H,hd),(B,N),(B,H)
        s = s * at[..., None, None] + xt[..., None] * bt[:, None, None, :]
        yt = jnp.einsum("bhdn,bn->bhd", s, ct)
        return s, yt

    xs = (dtx.transpose(1, 0, 2, 3), b.transpose(1, 0, 2),
          c.transpose(1, 0, 2), a.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32),
                             jax.tree.map(lambda t: t.astype(jnp.float32), xs))
    y = ys.transpose(1, 0, 2, 3).astype(u.dtype)             # (B,S,H,hd)
    return _mamba_out(cfg, p, y, z, x, dt), state.astype(u.dtype)


def mamba2_step(cfg, p, u, state):
    """u: (B,1,d); state: (B,H,hd,N)."""
    x, z, b, c, a, dt = _mamba_proj(cfg, p, u)
    xt, bt, ct, at = x[:, 0], b[:, 0], c[:, 0], a[:, 0]
    dtx = xt * dt[:, 0, :, None]
    s32 = state.astype(jnp.float32)
    s32 = s32 * at[..., None, None] + \
        (dtx[..., None] * bt[:, None, None, :]).astype(jnp.float32)
    yt = jnp.einsum("bhdn,bn->bhd", s32, ct.astype(jnp.float32))
    y = yt[:, None].astype(u.dtype)
    return _mamba_out(cfg, p, y, z, x, dt), s32.astype(u.dtype)


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

_DECAY_LORA = 64


def rwkv_dims(cfg):
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd
    return H, hd


def init_rwkv6(cfg, key, dtype=jnp.float32):
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = rwkv_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.ones((d,), dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], (d, d), dtype=dtype),
        "w_k": dense_init(ks[1], (d, d), dtype=dtype),
        "w_v": dense_init(ks[2], (d, d), dtype=dtype),
        "w_g": dense_init(ks[3], (d, d), dtype=dtype),
        "w_o": dense_init(ks[4], (d, d), dtype=dtype),
        "w0": jnp.full((d,), -1.0, dtype),                 # decay base
        "wa1": dense_init(ks[5], (d, _DECAY_LORA), dtype=dtype),
        "wa2": dense_init(ks[6], (_DECAY_LORA, d),
                          fan_in=_DECAY_LORA, dtype=dtype),
        "u": jnp.zeros((H, hd), dtype),                    # bonus
        "ln_x": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
        "mu_cm": jnp.full((d,), 0.5, dtype),
        "wk_cm": dense_init(ks[7], (d, ff), dtype=dtype),
        "wv_cm": dense_init(jax.random.fold_in(key, 99), (ff, d),
                            fan_in=ff, dtype=dtype),
    }


def rwkv6_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    H, hd = rwkv_dims(cfg)
    return {
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),  # (k-dim, v-dim)
        "x_tm": jnp.zeros((batch, d), dtype),               # token-shift (time mix)
        "x_cm": jnp.zeros((batch, d), dtype),               # token-shift (channel mix)
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of previous chunk."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _rwkv_mix(p, x, xx, lora=None):
    def mix(mu):
        return x + (xx - x) * mu

    r = mix(p["mu_r"]) @ p["w_r"] + (lora("q", mix(p["mu_r"])) if lora else 0.0)
    k = mix(p["mu_k"]) @ p["w_k"] + (lora("k", mix(p["mu_k"])) if lora else 0.0)
    v = mix(p["mu_v"]) @ p["w_v"] + (lora("v", mix(p["mu_v"])) if lora else 0.0)
    g = mix(p["mu_g"]) @ p["w_g"]
    xw = mix(p["mu_w"])
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) +
                         (jnp.tanh(xw @ p["wa1"]) @ p["wa2"]).astype(jnp.float32)))
    return r, k, v, g, w


def _rwkv_wkv(cfg, r, k, v, w, u, s0):
    """WKV recurrence. r/k/v/w: (B,S,H,hd); s0: (B,H,hd,hd) fp32."""
    B, S, H, hd = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp                                # (B,H,hd)
        kv = kt[..., None] * vt[..., None, :]               # (B,H,hdk,hdv)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = jax.tree.map(lambda t: t.transpose(1, 0, 2, 3).astype(jnp.float32),
                      (r, k, v, w))
    s, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), s                    # (B,S,H,hd), state


def rwkv6_time_mix(cfg, p, x, state, lora=None):
    """x: (B,S,d) (post-ln). Returns (out, new_state pieces)."""
    B, S, d = x.shape
    H, hd = rwkv_dims(cfg)
    xx = _token_shift(x, state["x_tm"])
    r, k, v, g, w = _rwkv_mix(p, x, xx, lora)
    rs = r.reshape(B, S, H, hd)
    ks_ = k.reshape(B, S, H, hd)
    vs = v.reshape(B, S, H, hd)
    ws = w.reshape(B, S, H, hd)
    out, s = _rwkv_wkv(cfg, rs, ks_, vs, ws, p["u"].astype(jnp.float32),
                       state["wkv"])
    out = out.reshape(B, S, d).astype(x.dtype)
    out = rmsnorm(out, p["ln_x"], cfg.rmsnorm_eps) * jax.nn.silu(g)
    out = out @ p["w_o"] + (lora("o", out) if lora else 0.0)
    new_state = {"wkv": s, "x_tm": x[:, -1, :]}
    return constrain_resid(out), new_state


def rwkv6_channel_mix(cfg, p, x, state):
    xx = _token_shift(x, state["x_cm"])
    xm = x + (xx - x) * p["mu_cm"]
    h = jnp.square(jax.nn.relu(xm @ p["wk_cm"]))
    h = constrain(h, "batch", None, "model")
    out = h @ p["wv_cm"]
    return constrain_resid(out), {"x_cm": x[:, -1, :]}
