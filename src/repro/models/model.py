"""Model assembly: init / forward (train) / prefill / decode for every
assigned architecture family.

All stacks run as ``lax.scan`` over stacked layer params (with optional
per-layer ``jax.checkpoint`` remat for training) so HLO size stays bounded
at 64–100 layers. Caches are plain dict pytrees (stacked along a leading
layer axis) so they thread through jit/pjit and can be donated.

Cache dict keys (present depending on family):
  pos    : (B,) int32 — tokens currently in the cache per row
  k, v   : (L_attn, B, S, Kv, hd) self-attention KV
  c, kr  : (L, B, S, kv_lora) / (L, B, S, rope) MLA compressed cache
  xk, xv : (L_cross, B, M, Kv, hd) cross-attn KV (computed at prefill)
  ssm    : (L, B, H, hd, N) mamba2 state
  wkv/x_tm/x_cm : RWKV6 state (stacked over layers)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.lora.batched import make_lora_cb

from .attention import (cross_attend, cross_kv, gqa_decode, gqa_full,
                        init_cross_attn, init_gqa, init_mla, mla_decode,
                        mla_full)
from .common import (chunked_cross_entropy, constrain, constrain_resid,
                     dense_init, rmsnorm)
from .ffn import init_moe, init_swiglu, moe_ffn, swiglu
from .ssm import (init_mamba2, init_rwkv6, mamba2_full, mamba2_state,
                  mamba2_step, rwkv6_channel_mix, rwkv6_state, rwkv6_time_mix,
                  rwkv_dims, mamba_dims)

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(cfg, key, dtype):
    return init_mla(cfg, key, dtype) if cfg.mla else init_gqa(cfg, key, dtype)


def _init_dense_block(cfg, key, dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
         "attn": _init_attn(cfg, k1, dtype)}
    if cfg.moe is not None:
        p["ffn"] = init_moe(cfg, k2, dtype)
    else:
        p["ffn"] = init_swiglu(d, cfg.d_ff, k2, dtype)
    return p


def _init_cross_block(cfg, key, dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
            "attn": init_cross_attn(cfg, k1, dtype),
            "ffn": init_swiglu(d, cfg.d_ff, k2, dtype),
            "gate_attn": jnp.zeros((1,), dtype),
            "gate_ffn": jnp.zeros((1,), dtype)}


def _init_encdec_dec_block(cfg, key, dtype):
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((d,), dtype), "lnc": jnp.ones((d,), dtype),
            "ln2": jnp.ones((d,), dtype),
            "attn": _init_attn(cfg, k1, dtype),
            "cross": init_cross_attn(cfg, k2, dtype),
            "ffn": init_swiglu(d, cfg.d_ff, k3, dtype)}


def _init_mamba_block(cfg, key, dtype):
    return init_mamba2(cfg, key, dtype)


def _stacked(init_fn, n, key, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args))(keys)


def init_params(cfg, key, dtype=jnp.float32):
    d, V = cfg.d_model, cfg.vocab_size
    key, ke, kh, kb = jax.random.split(key, 4)
    p = {"embed": dense_init(ke, (V, d), fan_in=d, dtype=dtype),
         "ln_f": jnp.ones((d,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kh, (d, V), dtype=dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        p["blocks"] = _stacked(lambda k: _init_dense_block(cfg, k, dtype),
                               cfg.n_layers, kb)
    elif fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        k1, k2 = jax.random.split(kb)
        p["self_blocks"] = _stacked(
            lambda k: _init_dense_block(cfg, k, dtype), n_self, k1)
        p["cross_blocks"] = _stacked(
            lambda k: _init_cross_block(cfg, k, dtype), n_cross, k2)
    elif fam == "audio":
        k1, k2 = jax.random.split(kb)
        p["enc_blocks"] = _stacked(
            lambda k: _init_dense_block(cfg, k, dtype),
            cfg.encoder.n_layers, k1)
        p["enc_ln_f"] = jnp.ones((d,), dtype)
        p["dec_blocks"] = _stacked(
            lambda k: _init_encdec_dec_block(cfg, k, dtype),
            cfg.n_layers, k2)
    elif fam == "hybrid":
        k1, k2 = jax.random.split(kb)
        p["mamba_blocks"] = _stacked(
            lambda k: _init_mamba_block(cfg, k, dtype), cfg.n_layers, k1)
        p["shared_attn"] = _init_dense_block(cfg, k2, dtype)
    elif fam == "ssm":
        p["blocks"] = _stacked(lambda k: init_rwkv6(cfg, k, dtype),
                               cfg.n_layers, kb)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def lm_head(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def n_attn_applications(cfg) -> int:
    """Number of self-attention cache entries (stacked leading dim)."""
    if cfg.family == "hybrid":
        return -(-cfg.n_layers // cfg.attn_every)
    if cfg.family == "ssm":
        return 0
    if cfg.family == "vlm":
        return cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
    return cfg.n_layers


def n_cross_applications(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "audio":
        return cfg.n_layers
    return 0


# ---------------------------------------------------------------------------
# Blocks (full-sequence and decode forms)
# ---------------------------------------------------------------------------


def _dense_block_full(cfg, bp, x, positions, window, lora):
    attn_fn = mla_full if cfg.mla else gqa_full
    h, kv = attn_fn(cfg, bp["attn"], rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                    positions, window=window, lora=lora)
    x = x + h
    xn = rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps)
    if cfg.moe is not None:
        f, aux = moe_ffn(cfg, bp["ffn"], xn)
    else:
        f, aux = swiglu(bp["ffn"], xn), jnp.zeros((), jnp.float32)
    return x + f, kv, aux


def _dense_block_decode(cfg, bp, x, kc, vc, pos, window, lora,
                        mla_absorbed=False):
    if cfg.mla:
        h, (kc, vc) = mla_decode(cfg, bp["attn"],
                                 rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                                 kc, vc, pos, window=window, lora=lora,
                                 absorbed=mla_absorbed)
    else:
        h, (kc, vc) = gqa_decode(cfg, bp["attn"],
                                 rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                                 kc, vc, pos, window=window, lora=lora)
    x = x + h
    xn = rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps)
    if cfg.moe is not None:
        f, _ = moe_ffn(cfg, bp["ffn"], xn)
    else:
        f = swiglu(bp["ffn"], xn)
    return x + f, kc, vc


def _cross_block(cfg, bp, x, kc, vc, lora):
    g_a = jnp.tanh(bp["gate_attn"])
    g_f = jnp.tanh(bp["gate_ffn"])
    h = cross_attend(cfg, bp["attn"], rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                     kc, vc, lora)
    x = x + g_a * h
    x = x + g_f * swiglu(bp["ffn"], rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps))
    return x


def _rwkv_block(cfg, bp, x, st, lora):
    h, st_tm = rwkv6_time_mix(cfg, bp, rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                              st, lora)
    x = x + h
    h2, st_cm = rwkv6_channel_mix(
        cfg, bp, rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps), st)
    return x + h2, {**st_tm, **st_cm}


# ---------------------------------------------------------------------------
# Full-sequence runners (train forward / prefill). Return (h, caches, aux).
# ---------------------------------------------------------------------------


def _bank_slice(bank, i=None):
    if bank is None:
        return None
    return bank if i is None else jax.tree.map(lambda t: t[i], bank)


def _run_dense_full(cfg, params, x, positions, *, window, bank, lora_idx,
                    remat, collect, lora_kernel="einsum"):
    has_bank = bank is not None

    def body(carry, inp):
        x, aux = carry
        bp, bk = inp if has_bank else (inp, None)
        lora = make_lora_cb(bk, lora_idx, kernel=lora_kernel) \
            if bk is not None else None
        x, kv, a = _dense_block_full(cfg, bp, x, positions, window, lora)
        return (x, aux + a), (kv if collect else 0)

    body_fn = jax.checkpoint(body) if remat else body
    xs = (params["blocks"], bank) if has_bank else params["blocks"]
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 xs)
    return x, kvs, aux


def _run_vlm_full(cfg, params, x, positions, *, window, frontend, bank,
                  lora_idx, remat, collect, lora_kernel="einsum"):
    n_cross = cfg.n_layers // cfg.cross_attn_every
    per = cfg.cross_attn_every - 1          # self layers per period
    sb = jax.tree.map(
        lambda t: t.reshape((n_cross, per) + t.shape[1:]),
        params["self_blocks"])
    xkv = jax.vmap(lambda bp: cross_kv(cfg, bp["attn"], frontend))(
        params["cross_blocks"])              # (n_cross, B, M, Kv, hd) x2

    def self_body(carry, bp):
        x, aux = carry
        x, kv, a = _dense_block_full(cfg, bp, x, positions, window,
                                     make_lora_cb(None, lora_idx))
        return (x, aux + a), (kv if collect else 0)

    self_body_fn = jax.checkpoint(self_body) if remat else self_body

    def period_body(carry, inp):
        blocks_i, cross_bp, xk, xv = inp
        carry, kvs = jax.lax.scan(self_body_fn, carry, blocks_i)
        x, aux = carry
        x = _cross_block(cfg, cross_bp, x, xk, xv, None)
        return (x, aux), kvs

    (x, aux), kvs = jax.lax.scan(
        period_body, (x, jnp.zeros((), jnp.float32)),
        (sb, params["cross_blocks"], xkv[0], xkv[1]))
    if collect:
        kvs = jax.tree.map(
            lambda t: t.reshape((-1,) + t.shape[2:]), kvs)
    return x, (kvs, xkv), aux


def _run_audio_encoder(cfg, params, frames):
    pos = jnp.arange(frames.shape[1])

    def body(x, bp):
        x, _, _ = _dense_block_full(cfg, bp, x, pos, 0, None)
        return x, 0

    # encoder self-attn is bidirectional: reuse dense block with causal off
    def enc_block(x, bp):
        h, _ = gqa_full(cfg, bp["attn"],
                        rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps), pos,
                        causal=False)
        x = x + h
        x = x + swiglu(bp["ffn"], rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps))
        return x, 0

    x, _ = jax.lax.scan(enc_block, frames, params["enc_blocks"])
    return rmsnorm(x, params["enc_ln_f"], cfg.rmsnorm_eps)


def _run_audio_full(cfg, params, x, positions, *, window, frontend, bank,
                    lora_idx, remat, collect, lora_kernel="einsum"):
    memory = _run_audio_encoder(cfg, params, frontend)
    xkv = jax.vmap(lambda bp: cross_kv(cfg, bp["cross"], memory))(
        params["dec_blocks"])

    has_bank = bank is not None

    def body(carry, inp):
        x, aux = carry
        if has_bank:
            bp, xk, xv, bk = inp
        else:
            (bp, xk, xv), bk = inp, None
        lora = make_lora_cb(bk, lora_idx, kernel=lora_kernel) \
            if bk is not None else None
        h, kv = gqa_full(cfg, bp["attn"],
                         rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                         positions, window=window, lora=lora)
        x = x + h
        x = x + cross_attend(cfg, bp["cross"],
                             rmsnorm(x, bp["lnc"], cfg.rmsnorm_eps), xk, xv)
        x = x + swiglu(bp["ffn"], rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps))
        return (x, aux), (kv if collect else 0)

    body_fn = jax.checkpoint(body) if remat else body
    xs = (params["dec_blocks"], xkv[0], xkv[1], bank) if has_bank \
        else (params["dec_blocks"], xkv[0], xkv[1])
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 xs)
    return x, (kvs, xkv), aux


def _hybrid_segments(cfg):
    """[(n_mamba_layers, start_idx)] per shared-attn application."""
    segs = []
    start = 0
    while start < cfg.n_layers:
        size = min(cfg.attn_every, cfg.n_layers - start)
        segs.append((start, size))
        start += size
    return segs


def _run_hybrid_full(cfg, params, x, positions, *, window, bank, lora_idx,
                     remat, collect, lora_kernel="einsum"):
    B = x.shape[0]
    aux = jnp.zeros((), jnp.float32)
    kv_list = []
    state_list = []
    lora = make_lora_cb(_bank_slice(bank, 0) if bank is not None else None,
                        lora_idx, kernel=lora_kernel)

    def mamba_body(x, inp):
        bp, st = inp
        out, st2 = mamba2_full(cfg, bp, rmsnorm(x, bp["ln"], cfg.rmsnorm_eps),
                               st)
        return x + out, st2

    mamba_body_fn = jax.checkpoint(mamba_body) if remat else mamba_body

    for (start, size) in _hybrid_segments(cfg):
        x, kv, a = _dense_block_full(cfg, params["shared_attn"], x,
                                     positions, window, lora)
        aux = aux + a
        kv_list.append(kv)
        sub = jax.tree.map(
            lambda t: jax.lax.slice_in_dim(t, start, start + size),
            params["mamba_blocks"])
        st0 = jnp.zeros((size,) + mamba2_state(cfg, B).shape)
        x, sts = jax.lax.scan(mamba_body_fn, x, (sub, st0))
        state_list.append(sts)

    kvs = jax.tree.map(lambda *t: jnp.stack(t), *kv_list) if collect else None
    states = jnp.concatenate(state_list, axis=0)
    return x, (kvs, states), aux


def _run_rwkv_full(cfg, params, x, *, bank, lora_idx, remat, collect,
                   lora_kernel="einsum"):
    B = x.shape[0]
    L = cfg.n_layers
    st0 = jax.tree.map(lambda t: jnp.broadcast_to(t, (L,) + t.shape),
                       rwkv6_state(cfg, B, x.dtype))

    def body(x, inp):
        bp, st, bk = inp
        lora = make_lora_cb(bk, lora_idx, kernel=lora_kernel) \
            if bk is not None else None
        x, st2 = _rwkv_block(cfg, bp, x, st, lora)
        return x, st2

    body_fn = jax.checkpoint(body) if remat else body
    if bank is not None:
        xs = (params["blocks"], st0, bank)
    else:
        xs = (params["blocks"], st0)

    def body2(x, inp):
        if bank is not None:
            bp, st, bk = inp
        else:
            (bp, st), bk = inp, None
        return body_fn(x, (bp, st, bk))

    x, states = jax.lax.scan(body2, x, xs)
    return x, states, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Public API: forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain_resid(x)


def forward(cfg, params, tokens, *, frontend=None, bank=None, lora_idx=None,
            window=None, remat=False, lora_kernel="einsum"):
    """Teacher-forced full-sequence forward. Returns (h (B,S,d), aux)."""
    window = cfg.sliding_window if window is None else window
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed(cfg, params, tokens)
    kw = dict(window=window, bank=bank, lora_idx=lora_idx, remat=remat,
              collect=False, lora_kernel=lora_kernel)
    fam = cfg.family
    if fam in ("dense", "moe"):
        h, _, aux = _run_dense_full(cfg, params, x, positions, **kw)
    elif fam == "vlm":
        h, _, aux = _run_vlm_full(cfg, params, x, positions,
                                  frontend=frontend, **kw)
    elif fam == "audio":
        h, _, aux = _run_audio_full(cfg, params, x, positions,
                                    frontend=frontend, **kw)
    elif fam == "hybrid":
        h, _, aux = _run_hybrid_full(cfg, params, x, positions, **kw)
    elif fam == "ssm":
        h, _, aux = _run_rwkv_full(cfg, params, x, bank=bank,
                                   lora_idx=lora_idx, remat=remat,
                                   collect=False, lora_kernel=lora_kernel)
    else:
        raise ValueError(fam)
    return rmsnorm(h, params["ln_f"], cfg.rmsnorm_eps), aux


def loss_fn(cfg, params, batch, *, remat=True, aux_coef=0.01):
    h, aux = forward(cfg, params, batch["tokens"],
                     frontend=batch.get("frontend"), remat=remat)
    loss = chunked_cross_entropy(h, lm_head(cfg, params), batch["labels"])
    return loss + aux_coef * aux


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32,
               enc_len: Optional[int] = None):
    """Zeroed cache pytree. max_len should already account for any sliding
    window (callers pass min(seq, window))."""
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    n_attn = n_attn_applications(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        cache["c"] = jnp.zeros((cfg.n_layers, batch, max_len,
                                m.kv_lora_rank), dtype)
        cache["kr"] = jnp.zeros((cfg.n_layers, batch, max_len,
                                 m.qk_rope_head_dim), dtype)
    elif n_attn:
        cache["k"] = jnp.zeros((n_attn, batch, max_len, Kv, hd), dtype)
        cache["v"] = jnp.zeros((n_attn, batch, max_len, Kv, hd), dtype)
    n_cross = n_cross_applications(cfg)
    if n_cross:
        M = enc_len or (cfg.encoder.n_frames if cfg.encoder
                        else cfg.n_frontend_tokens)
        cache["xk"] = jnp.zeros((n_cross, batch, M, Kv, hd), dtype)
        cache["xv"] = jnp.zeros((n_cross, batch, M, Kv, hd), dtype)
    if cfg.family == "hybrid":
        cache["ssm"] = jnp.zeros((cfg.n_layers,) +
                                 mamba2_state(cfg, batch).shape, dtype)
    if cfg.family == "ssm":
        st = rwkv6_state(cfg, batch, dtype)
        cache["wkv"] = jnp.zeros((cfg.n_layers,) + st["wkv"].shape,
                                 jnp.float32)
        cache["x_tm"] = jnp.zeros((cfg.n_layers,) + st["x_tm"].shape, dtype)
        cache["x_cm"] = jnp.zeros((cfg.n_layers,) + st["x_cm"].shape, dtype)
    return cache


def _write_prefill_kv(kvs, cache_arr, window):
    """kvs: (L, B, S, ...) computed at prefill; write into cache (L,B,Smax,...)
    honoring ring layout when window > 0."""
    L, B, S = kvs.shape[:3]
    Smax = cache_arr.shape[2]
    if window and S > Smax:
        # keep the last `Smax` entries at their ring slots
        tail = kvs[:, :, S - Smax:]
        slots = (jnp.arange(S - Smax, S)) % Smax
        return cache_arr.at[:, :, slots].set(tail.astype(cache_arr.dtype))
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, kvs[:, :, :Smax].astype(cache_arr.dtype), 0, axis=2)


def prefill(cfg, params, tokens, *, frontend=None, bank=None, lora_idx=None,
            cache_len: Optional[int] = None, window: Optional[int] = None,
            cache_dtype=None, lora_kernel="einsum"):
    """Prefill a batch of same-length rows. Returns (last_logits (B,V), cache)."""
    window = cfg.sliding_window if window is None else window
    B, S = tokens.shape
    cache_len = cache_len or (min(S, window) if window else S)
    positions = jnp.arange(S)
    x = _embed(cfg, params, tokens)
    kw = dict(window=window, bank=bank, lora_idx=lora_idx, remat=False,
              collect=True, lora_kernel=lora_kernel)
    cache_dtype = cache_dtype or params["embed"].dtype
    enc_len = frontend.shape[1] if frontend is not None else None
    cache = init_cache(cfg, B, cache_len, cache_dtype, enc_len=enc_len)
    fam = cfg.family
    if fam in ("dense", "moe"):
        h, kvs, _ = _run_dense_full(cfg, params, x, positions, **kw)
        if cfg.mla is not None:
            cache["c"] = _write_prefill_kv(kvs[0], cache["c"], window)
            cache["kr"] = _write_prefill_kv(kvs[1], cache["kr"], window)
        else:
            cache["k"] = _write_prefill_kv(kvs[0], cache["k"], window)
            cache["v"] = _write_prefill_kv(kvs[1], cache["v"], window)
    elif fam == "vlm":
        h, (kvs, xkv), _ = _run_vlm_full(cfg, params, x, positions,
                                         frontend=frontend, **kw)
        cache["k"] = _write_prefill_kv(kvs[0], cache["k"], window)
        cache["v"] = _write_prefill_kv(kvs[1], cache["v"], window)
        cache["xk"] = xkv[0].astype(cache_dtype)
        cache["xv"] = xkv[1].astype(cache_dtype)
    elif fam == "audio":
        h, (kvs, xkv), _ = _run_audio_full(cfg, params, x, positions,
                                           frontend=frontend, **kw)
        cache["k"] = _write_prefill_kv(kvs[0], cache["k"], window)
        cache["v"] = _write_prefill_kv(kvs[1], cache["v"], window)
        cache["xk"] = xkv[0].astype(cache_dtype)
        cache["xv"] = xkv[1].astype(cache_dtype)
    elif fam == "hybrid":
        h, (kvs, states), _ = _run_hybrid_full(cfg, params, x, positions,
                                               **kw)
        cache["k"] = _write_prefill_kv(kvs[0], cache["k"], window)
        cache["v"] = _write_prefill_kv(kvs[1], cache["v"], window)
        cache["ssm"] = states.astype(cache_dtype)
    elif fam == "ssm":
        h, states, _ = _run_rwkv_full(cfg, params, x, bank=bank,
                                      lora_idx=lora_idx, remat=False,
                                      collect=True,
                                      lora_kernel=lora_kernel)
        cache["wkv"] = states["wkv"]
        cache["x_tm"] = states["x_tm"].astype(cache_dtype)
        cache["x_cm"] = states["x_cm"].astype(cache_dtype)
    else:
        raise ValueError(fam)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    h_last = rmsnorm(h[:, -1], params["ln_f"], cfg.rmsnorm_eps)
    logits = h_last.astype(jnp.float32) @ lm_head(cfg, params).astype(
        jnp.float32)
    return logits, cache


def decode_step(cfg, params, cache, tokens, *, bank=None, lora_idx=None,
                window: Optional[int] = None, mla_absorbed=False,
                lora_kernel="einsum"):
    """One decode step. tokens: (B,) int32. Returns (logits (B,V), cache)."""
    window = cfg.sliding_window if window is None else window
    pos = cache["pos"]
    x = _embed(cfg, params, tokens[:, None])
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "moe"):
        ck = cache["c"] if cfg.mla is not None else cache["k"]
        cv = cache["kr"] if cfg.mla is not None else cache["v"]

        # The stacked caches ride the scan CARRY (read/update one layer
        # slice per step) rather than xs/ys: while-loop carry state is
        # aliased in place by XLA, so the donated cache is updated without
        # double-buffering the full (L,B,S,...) arrays (§Perf iter 1c).
        def body(carry, inp):
            x, ck, cv, i = carry
            if bank is not None:
                bp, bk = inp
            else:
                bp, bk = inp, None
            lora = make_lora_cb(bk, lora_idx, kernel=lora_kernel) \
                if bk is not None else None
            kc = jax.lax.dynamic_index_in_dim(ck, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(cv, i, 0, keepdims=False)
            x, kc, vc = _dense_block_decode(cfg, bp, x, kc, vc, pos,
                                            window, lora, mla_absorbed)
            ck = jax.lax.dynamic_update_index_in_dim(
                ck, kc.astype(ck.dtype), i, 0)
            cv = jax.lax.dynamic_update_index_in_dim(
                cv, vc.astype(cv.dtype), i, 0)
            return (x, ck, cv, i + 1), None

        xs = (params["blocks"], bank) if bank is not None \
            else params["blocks"]
        (x, ck2, cv2, _), _ = jax.lax.scan(
            body, (x, ck, cv, jnp.zeros((), jnp.int32)), xs)
        if cfg.mla is not None:
            new_cache["c"], new_cache["kr"] = ck2, cv2
        else:
            new_cache["k"], new_cache["v"] = ck2, cv2
    elif fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        sb = jax.tree.map(
            lambda t: t.reshape((n_cross, per) + t.shape[1:]),
            params["self_blocks"])
        kk = cache["k"].reshape((n_cross, per) + cache["k"].shape[1:])
        vv = cache["v"].reshape((n_cross, per) + cache["v"].shape[1:])

        def self_body(x, inp):
            bp, kc, vc = inp
            x, kc, vc = _dense_block_decode(cfg, bp, x, kc, vc, pos, window,
                                            None)
            return x, (kc, vc)

        def period_body(x, inp):
            blocks_i, cross_bp, kci, vci, xk, xv = inp
            x, (kc2, vc2) = jax.lax.scan(self_body, x, (blocks_i, kci, vci))
            x = _cross_block(cfg, cross_bp, x, xk, xv, None)
            return x, (kc2, vc2)

        x, (k2, v2) = jax.lax.scan(
            period_body, x,
            (sb, params["cross_blocks"], kk, vv, cache["xk"], cache["xv"]))
        new_cache["k"] = k2.reshape(cache["k"].shape)
        new_cache["v"] = v2.reshape(cache["v"].shape)
    elif fam == "audio":
        def body(x, inp):
            bp, kc, vc, xk, xv = inp
            h, (kc, vc) = gqa_decode(cfg, bp["attn"],
                                     rmsnorm(x, bp["ln1"], cfg.rmsnorm_eps),
                                     kc, vc, pos, window=window)
            x = x + h
            x = x + cross_attend(cfg, bp["cross"],
                                 rmsnorm(x, bp["lnc"], cfg.rmsnorm_eps),
                                 xk, xv)
            x = x + swiglu(bp["ffn"], rmsnorm(x, bp["ln2"], cfg.rmsnorm_eps))
            return x, (kc, vc)

        x, (k2, v2) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = k2, v2
    elif fam == "hybrid":
        kv_k, kv_v = [], []
        states = []
        lora = make_lora_cb(_bank_slice(bank, 0) if bank is not None else
                            None, lora_idx, kernel=lora_kernel)
        segs = _hybrid_segments(cfg)

        def mamba_body(x, inp):
            bp, st = inp
            out, st2 = mamba2_step(cfg, bp,
                                   rmsnorm(x, bp["ln"], cfg.rmsnorm_eps), st)
            return x + out, st2

        for i, (start, size) in enumerate(segs):
            x, kc, vc = _dense_block_decode(
                cfg, params["shared_attn"], x, cache["k"][i], cache["v"][i],
                pos, window, lora)
            kv_k.append(kc)
            kv_v.append(vc)
            sub = jax.tree.map(
                lambda t: jax.lax.slice_in_dim(t, start, start + size),
                params["mamba_blocks"])
            st_in = jax.lax.slice_in_dim(cache["ssm"], start, start + size)
            x, st_out = jax.lax.scan(mamba_body, x, (sub, st_in))
            states.append(st_out)
        new_cache["k"] = jnp.stack(kv_k)
        new_cache["v"] = jnp.stack(kv_v)
        new_cache["ssm"] = jnp.concatenate(states, axis=0).astype(
            cache["ssm"].dtype)
    elif fam == "ssm":
        def body(x, inp):
            bp, wkv, x_tm, x_cm, bk = inp
            lora = make_lora_cb(bk, lora_idx, kernel=lora_kernel) \
                if bank is not None else None
            st = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
            x, st2 = _rwkv_block(cfg, bp, x, st, lora)
            return x, (st2["wkv"], st2["x_tm"], st2["x_cm"])

        if bank is not None:
            xs = (params["blocks"], cache["wkv"], cache["x_tm"],
                  cache["x_cm"], bank)
        else:
            xs = (params["blocks"], cache["wkv"], cache["x_tm"],
                  cache["x_cm"])

        def body2(x, inp):
            if bank is not None:
                bp, wkv, x_tm, x_cm, bk = inp
            else:
                (bp, wkv, x_tm, x_cm), bk = inp, None
            return body(x, (bp, wkv, x_tm, x_cm, bk))

        x, (wkv2, xtm2, xcm2) = jax.lax.scan(body2, x, xs)
        new_cache["wkv"], new_cache["x_tm"], new_cache["x_cm"] = \
            wkv2, xtm2, xcm2
    else:
        raise ValueError(fam)

    new_cache["pos"] = pos + 1
    h_last = rmsnorm(x[:, 0], params["ln_f"], cfg.rmsnorm_eps)
    logits = h_last.astype(jnp.float32) @ lm_head(cfg, params).astype(
        jnp.float32)
    return logits, new_cache
