"""FFN layers: SwiGLU and Mixture-of-Experts.

Two MoE dispatch paths:
  * GSPMD sort-scatter (paper-faithful-first baseline): tokens sorted by
    expert, packed into (E, C, d) capacity buffers, expert dim sharded.
    The SPMD partitioner turns the global scatter into replication-scale
    collectives — the measured collective wall in §Perf pair 2.
  * shard_map expert-parallel (beyond-paper, §Perf iter 2): the sequence
    dim is already sharded over the model axis; each shard routes its own
    tokens locally, `all_to_all` exchanges capacity buffers so each shard
    runs only its E/n experts, and a reverse `all_to_all` brings outputs
    home. Collective volume drops from O(E*C*d) replication to
    O(K*N_local*d) exchange per layer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (SHARDING_MODE, constrain, constrain_resid,
                     current_axis_env, dense_init)


def init_swiglu(d: int, ff: int, key, dtype=jnp.float32, prefix=""):
    ks = jax.random.split(key, 3)
    shared = prefix == "s"
    return {
        ("ws1" if shared else "w1"): dense_init(ks[0], (d, ff), dtype=dtype),
        ("ws3" if shared else "w3"): dense_init(ks[1], (d, ff), dtype=dtype),
        ("ws2" if shared else "w2"): dense_init(ks[2], (ff, d), fan_in=ff,
                                                dtype=dtype),
    }


def swiglu(p, x, shared: bool = False):
    w1 = p["ws1" if shared else "w1"]
    w3 = p["ws3" if shared else "w3"]
    w2 = p["ws2" if shared else "w2"]
    h = jax.nn.silu(x @ w1) * (x @ w3)
    h = constrain(h, "batch", None, "model")
    out = h @ w2
    return constrain_resid(out)


def init_moe(cfg, key, dtype=jnp.float32):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e.n_experts), dtype=jnp.float32),
        "we1": dense_init(ks[1], (e.n_experts, d, e.d_ff_expert),
                          fan_in=d, dtype=dtype),
        "we3": dense_init(ks[2], (e.n_experts, d, e.d_ff_expert),
                          fan_in=d, dtype=dtype),
        "we2": dense_init(ks[3], (e.n_experts, e.d_ff_expert, d),
                          fan_in=e.d_ff_expert, dtype=dtype),
    }
    if e.n_shared_experts:
        p.update(init_swiglu(d, e.n_shared_experts * e.d_ff_expert,
                             ks[4], dtype=dtype, prefix="s"))
    return p


def _route_pack(cfg, router, xf, capacity_factor, exact_small=True):
    """Shared routing: top-k, aux loss, sort-pack into (E, C, d).
    Returns (xg, tok_s, w_s, keep, dest, C, aux)."""
    e = cfg.moe
    N, d = xf.shape
    K, E = e.top_k, e.n_experts
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    M = N * K
    eid = topi.reshape(M)
    tok = jnp.repeat(jnp.arange(N), K)
    w = topw.reshape(M)
    order = jnp.argsort(eid)
    eid_s, tok_s, w_s = eid[order], tok[order], w[order]
    counts = jnp.bincount(eid, length=E)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(M) - offsets[eid_s]
    if exact_small and N <= 8192:
        C = N      # drop-free (decode determinism in the GSPMD path)
    else:
        C = max(8, math.ceil(K * N / E * capacity_factor))
    keep = rank < C
    dest = jnp.where(keep, eid_s * C + rank, E * C)
    xg = jnp.zeros((E * C + 1, d), xf.dtype).at[dest].set(xf[tok_s])
    return xg[:E * C].reshape(E, C, d), tok_s, w_s, keep, dest, C, aux


def _combine(xf_shape, y, tok_s, w_s, keep, dest, C, dtype):
    N, d = xf_shape
    yf = y.reshape(-1, d)
    gathered = yf[jnp.where(keep, dest, 0)] * keep[:, None]
    return jnp.zeros((N, d), dtype).at[tok_s].add(
        (w_s[:, None] * gathered).astype(dtype))


def moe_ffn_ep(cfg, p, x, capacity_factor: float = 1.25):
    """shard_map expert-parallel MoE (§Perf iter 2). x: (B,S,d) with the
    sequence dim sharded over the model axis inside the map."""
    env = current_axis_env()
    mesh = env.mesh
    m = env.model
    e = cfg.moe
    B, S, d = x.shape
    n = mesh.shape[m]
    import numpy as _np
    bsz = int(_np.prod([mesh.shape[a] for a in env.batch])) \
        if env.batch else 1
    bspec = (env.batch if len(env.batch) > 1 else env.batch[0]) \
        if env.batch and B % bsz == 0 else None

    def local_fn(xl, router, we1, we3, we2):
        # xl: (B_loc, S/n, d); we*: (E/n, ...)
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(Bl * Sl, d)
        # capacity-based even for small local N: the exchange volume is
        # E*C*d, so C must track the mean load, not the worst case
        xg, tok_s, w_s, keep, dest, C, aux = _route_pack(
            cfg, router, xf, 1.5, exact_small=False)
        # exchange: every shard sends expert-slice j to shard j
        xg = jax.lax.all_to_all(xg, m, split_axis=0, concat_axis=1,
                                tiled=True)            # (E/n, C*n, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, we1)) * \
            jnp.einsum("ecd,edf->ecf", xg, we3)
        y = jnp.einsum("ecf,efd->ecd", h, we2)         # (E/n, C*n, d)
        y = jax.lax.all_to_all(y, m, split_axis=1, concat_axis=0,
                               tiled=True)             # (E, C, d)
        out = _combine((Bl * Sl, d), y, tok_s, w_s, keep, dest, C, xl.dtype)
        axes = tuple(env.batch) + (m,)
        aux = jax.lax.pmean(aux, axes)
        return out.reshape(Bl, Sl, d), aux

    from jax import shard_map
    mapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, m, None), P(None, None),
                  P(m, None, None), P(m, None, None), P(m, None, None)),
        out_specs=(P(bspec, m, None), P()), check_vma=False)
    y, aux = mapped(x, p["router"], p["we1"], p["we3"], p["we2"])
    if e.n_shared_experts:
        y = y + swiglu(p, x, shared=True)
    return constrain_resid(y), aux


def _ep_applicable(cfg, x) -> bool:
    env = current_axis_env()
    if SHARDING_MODE == "baseline" or env.mesh is None or env.model is None:
        return False
    n = env.mesh.shape[env.model]
    return (cfg.moe.n_experts % n == 0 and x.shape[1] % n == 0
            and x.shape[1] > 1)


def moe_ffn(cfg, p, x, capacity_factor: float = 1.25):
    """Sort-based ragged MoE. x: (B,S,d) -> (y, aux_loss).

    Dispatches to the shard_map expert-parallel path when the ambient
    mesh allows it (see module docstring), else the GSPMD scatter path.
    """
    if cfg.moe is not None and _ep_applicable(cfg, x):
        return moe_ffn_ep(cfg, p, x, capacity_factor)
    e = cfg.moe
    B, S, d = x.shape
    N = B * S
    K = e.top_k
    E = e.n_experts
    xf = x.reshape(N, d)

    logits = xf.astype(jnp.float32) @ p["router"]          # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                   # (N,K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    M = N * K
    eid = topi.reshape(M)
    tok = jnp.repeat(jnp.arange(N), K)
    w = topw.reshape(M)

    order = jnp.argsort(eid)                               # stable
    eid_s, tok_s, w_s = eid[order], tok[order], w[order]
    counts = jnp.bincount(eid, length=E)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(M) - offsets[eid_s]

    if N <= 8192:
        C = N            # exact (drop-free): worst case all tokens 1 expert
    else:
        C = max(1, math.ceil(K * N / E * capacity_factor))
    keep = rank < C
    dest = jnp.where(keep, eid_s * C + rank, E * C)        # E*C = drop slot

    xg = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(xf[tok_s])
    xg = xg[:E * C].reshape(E, C, d)
    xg = constrain(xg, "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["we1"])) * \
        jnp.einsum("ecd,edf->ecf", xg, p["we3"])
    y = jnp.einsum("ecf,efd->ecd", h, p["we2"])
    y = constrain(y, "model", None, None)

    yf = y.reshape(E * C, d)
    gathered = yf[jnp.where(keep, dest, 0)] * keep[:, None]
    out = jnp.zeros((N, d), x.dtype).at[tok_s].add(
        (w_s[:, None] * gathered).astype(x.dtype))

    out = out.reshape(B, S, d)
    if e.n_shared_experts:
        out = out + swiglu(p, x, shared=True)
    return constrain_resid(out), aux
