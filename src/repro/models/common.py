"""Shared model machinery: sharding axis environment, norms, rope,
pure-JAX flash attention (chunked online-softmax), chunked cross-entropy,
and parameter PartitionSpec rules.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Optional, Tuple

# Sharding mode for the serving path (§Perf hillclimbing):
#   "baseline" — paper-faithful-first layout: KV cache sharded on kv-heads
#                (replicates when heads % model != 0), LoRA banks
#                TP-sharded on the rank dim (S-LoRA style, paper §III-A.3).
#   "opt"      — beyond-paper: KV cache sharded on the *sequence* dim
#                (context-parallel decode), LoRA banks replicated and
#                applied locally (no per-layer all-reduce).
# Recorded separately in EXPERIMENTS.md §Perf.
SHARDING_MODE = os.environ.get("REPRO_SHARDING", "opt")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Axis environment: which mesh axes shard batch / model dims. When inactive
# (unit tests, single device) all constraints are no-ops.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    batch: Tuple[str, ...] = ()
    model: Optional[str] = None
    mesh: Optional[object] = None      # physical Mesh (for shard_map paths)
    # LoRA sharding scheme for the serving path. None follows
    # SHARDING_MODE (replicated banks in "opt", rank-TP in "baseline");
    # "coshard" is the mesh-sharded engine's scheme: A sharded on
    # d_model, B on d_out, so each shard computes a partial rank-r
    # intermediate that is reduced with ONE psum and the expand output
    # comes out column-sharded like the base projection — the full-width
    # delta is never gathered.
    lora: Optional[str] = None

    @property
    def active(self) -> bool:
        return bool(self.batch) or self.model is not None


_LOCAL = threading.local()


def current_axis_env() -> AxisEnv:
    return getattr(_LOCAL, "env", AxisEnv())


@contextlib.contextmanager
def axis_env(batch: Tuple[str, ...] = (), model: Optional[str] = None,
             mesh=None, lora: Optional[str] = None):
    prev = current_axis_env()
    _LOCAL.env = AxisEnv(tuple(batch), model, mesh, lora)
    try:
        yield _LOCAL.env
    finally:
        _LOCAL.env = prev


def _resolve(dim, env: AxisEnv):
    if dim == "batch":
        return env.batch if len(env.batch) != 1 else env.batch[0]
    if dim == "model":
        return env.model
    return None


def constrain(x: jax.Array, *dims) -> jax.Array:
    """with_sharding_constraint under the ambient axis env.

    dims entries: "batch" | "model" | None, one per array dim.
    """
    env = current_axis_env()
    if not env.active:
        return x
    spec = P(*[_resolve(d, env) for d in dims])
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_resid(x: jax.Array) -> jax.Array:
    """Residual-stream (B,S,d) constraint. In "sp" mode the sequence dim
    is sharded over the model axis (Megatron sequence parallelism):
    norms/adds run 1/n-local and each block boundary is an all-gather +
    reduce-scatter pair instead of a full all-reduce of a replicated
    stream (§Perf iter 3a)."""
    env = current_axis_env()
    if not env.active:
        return x
    if SHARDING_MODE == "sp" and env.model is not None and x.ndim == 3 \
            and env.mesh is not None \
            and x.shape[1] % env.mesh.shape[env.model] == 0:
        return jax.lax.with_sharding_constraint(
            x, P(_resolve("batch", env), env.model, None))
    return jax.lax.with_sharding_constraint(
        x, P(_resolve("batch", env), None, None))


# ---------------------------------------------------------------------------
# Param PartitionSpec rules, keyed on leaf name (last path component).
# Spec applies to TRAILING dims; leading (stacked-layer) dims get None.
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "w1", "w3", "w_xz", "w_r", "w_k", "w_v", "w_g",
        "wk_cm", "w_uk", "w_uv", "lm_head", "ws1", "ws3"}
_ROW = {"wo", "w2", "w_out", "w_o", "wv_cm", "ws2"}
_EXPERT = {"we1", "we2", "we3"}
_EMBED = {"embed"}
_VEC_COL = {"bq", "bk", "bv", "ln_y"}


def _tail_spec(name: str, ndim_tail: int):
    if name == "A":                      # LoRA shrink bank (Na, d, r)
        # baseline: S-LoRA TP split on the rank dim; opt: replicated
        # (banks are tiny; local application avoids a (B,S,out)
        # all-reduce per target per layer — §Perf iteration 3)
        return (None, None, "model") if SHARDING_MODE == "baseline" \
            else (None, None, None)
    if name == "B":                      # LoRA expand bank (Na, r, out)
        return (None, "model", None) if SHARDING_MODE == "baseline" \
            else (None, None, None)
    if name in _COL:
        return (None, "model")
    if name in _ROW:
        return ("model", None)
    if name in _EXPERT:
        return ("model", None, None)
    if name in _EMBED:
        return ("model", None)
    if name in _VEC_COL:
        return ("model",)
    return ()


def param_pspecs(params, model_axis: str = "model"):
    """PartitionSpec tree for a param tree, from leaf-name rules."""

    def spec_for(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        tail = _tail_spec(name, leaf.ndim) if name else ()
        tail = tail[-leaf.ndim:] if leaf.ndim < len(tail) else tail
        full = (None,) * (leaf.ndim - len(tail)) + tuple(
            model_axis if t == "model" else None for t in tail)
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# LoRA callback contract
# ---------------------------------------------------------------------------
# Blocks call ``lora(name, x) -> delta`` with x: (B, S, d_target) for a
# projection target name in {"q","k","v","o"}; the callback owns the
# adapter gather and returns the batched LoRA delta in x.dtype.
# ``repro.lora.batched.make_lora_cb`` builds the callback from a bank
# layer slice in either execution form (gather-einsum, or the fused
# Pallas SGMV kernels over the token-major flattening below).

LoRACallback = "Callable[[str, jax.Array], jax.Array]"


def rows_to_tokens(x: jax.Array):
    """(B, S, d) -> ((B*S, d), (B, S)): the token-major flattening the
    SGMV kernel path consumes (row-major, so token t of row b sits at
    b*S + t and per-row adapter ids repeat S times)."""
    B, S, d = x.shape
    return x.reshape(B * S, d), (B, S)


def tokens_to_rows(y: jax.Array, B: int, S: int) -> jax.Array:
    """Inverse of ``rows_to_tokens`` for the (B*S, d_out) kernel output."""
    return y.reshape(B, S, y.shape[-1])


# ---------------------------------------------------------------------------
# Norms / rope / init
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention (pure JAX, chunked online softmax). Bounds peak memory to
# O(B * H * chunk_q * chunk_k) so 32k prefill lowers within HBM.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def flash_attention(q, k, v, *, causal: bool, q_positions, k_positions,
                    window: int = 0, chunk_q: int = 512, chunk_k: int = 1024,
                    scale: Optional[float] = None, extra_qk=None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,Kv,hd). GQA via head grouping.

    Masking: causal (q_pos >= k_pos) and optional sliding window
    (q_pos - k_pos < window). Positions are int arrays (Sq,), (Sk,).
    Returns (B,Sq,H,hd) in q.dtype.

    extra_qk: optional (q2 (B,Sq,H,hd2), k2 (B,Sk,hd2)) pair added to the
    scores — MLA's shared rope key. Scoring it as a separate einsum (k2
    has no head dim) avoids materializing broadcast+concat keys, which
    otherwise reshards a (B,*,H,ck) scores tensor inside the kv scan
    (§Perf iter 2d).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Kv, _ = k.shape
    hdv = v.shape[-1]
    G = H // Kv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)

    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    pad_q = (-Sq) % cq
    pad_k = (-Sk) % ck
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vpd = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if extra_qk is not None:
        q2, k2 = extra_qk
        hd2 = q2.shape[-1]
        q2p = jnp.pad(q2, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k2p = jnp.pad(k2, ((0, 0), (0, pad_k), (0, 0)))
    qpos = jnp.pad(q_positions.astype(jnp.int32), (0, pad_q),
                   constant_values=-1)
    kpos = jnp.pad(k_positions.astype(jnp.int32), (0, pad_k),
                   constant_values=2 ** 30)
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ck

    # (B, nq, cq, Kv, G, hd)
    # fp32 score path (iter 2c tried storage-dtype K/V with per-chunk
    # upcast: it regressed dense GQA training 35% — XLA's backward adds
    # convert+reshard pairs around the scan — so fp32 stays; the
    # shard_map path (run_flash) keeps everything local either way)
    qp = (qp.reshape(B, nq, cq, Kv, G, hd).astype(jnp.float32) * scale)
    kp = kp.reshape(B, nk, ck, Kv, hd).astype(jnp.float32)
    vp = vpd.reshape(B, nk, ck, Kv, hdv).astype(jnp.float32)
    if extra_qk is not None:
        q2p = (q2p.reshape(B, nq, cq, Kv, G, hd2).astype(jnp.float32)
               * scale)
        k2p = k2p.reshape(B, nk, ck, hd2).astype(jnp.float32)
    qpos = qpos.reshape(nq, cq)
    kpos = kpos.reshape(nk, ck)

    def body(carry, inp):
        m, l, acc = carry                       # (B,nq,cq,Kv,G) / +hd
        if extra_qk is not None:
            kc, vc, k2c, kposc = inp
        else:
            kc, vc, kposc = inp                 # (B,ck,Kv,hd), (ck,)
        s = jnp.einsum("bqckgh,bzkh->bqckgz", qp, kc.astype(qp.dtype),
                       preferred_element_type=jnp.float32)   # z = ck
        if extra_qk is not None:
            s = s + jnp.einsum("bqckgh,bzh->bqckgz", q2p,
                               k2c.astype(q2p.dtype),
                               preferred_element_type=jnp.float32)
        mask = jnp.ones((nq, cq, ck), dtype=bool)
        if causal:
            mask &= qpos[:, :, None] >= kposc[None, None, :]
        if window:
            mask &= (qpos[:, :, None] - kposc[None, None, :]) < window
        mask &= kposc[None, None, :] < 2 ** 30
        s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqckgz,bzkh->bqckgh", p.astype(vc.dtype),
                        vc, preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, cq, Kv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, cq, Kv, G), jnp.float32)
    a0 = jnp.zeros((B, nq, cq, Kv, G, hdv), jnp.float32)
    if extra_qk is not None:
        xs = (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
              k2p.transpose(1, 0, 2, 3), kpos)
    else:
        xs = (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
              kpos)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, nq * cq, H, hdv)[:, :Sq]
    return out.astype(q.dtype)


def attend_cache(q, k_cache, v_cache, valid_mask, scale=None):
    """Single-token decode attention against a KV cache.

    q: (B,1,H,hd); caches: (B,S,Kv,hd); valid_mask: (B,S) bool.
    """
    B, _, H, hd = q.shape
    Kv = k_cache.shape[2]
    hdv = v_cache.shape[-1]
    G = H // Kv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    # keep the big cache operands in their storage dtype; accumulate the
    # contractions in fp32 (§Perf iter 1b: materializing fp32 copies of a
    # sequence-length cache doubles decode HBM traffic)
    qf = (q.reshape(B, Kv, G, hd) * scale).astype(k_cache.dtype)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked cross entropy — never materializes (B,S,V) logits.
# ---------------------------------------------------------------------------


def chunked_cross_entropy(h, lm_head, labels, chunk: int = 256):
    """h: (B,S,d); lm_head: (d,V); labels: (B,S) int32. Mean NLL."""
    B, S, d = h.shape
    c = min(chunk, S)
    pad = (-S) % c
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = hp.shape[1] // c
    hp = hp.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lp = lp.reshape(B, n, c).transpose(1, 0, 2)

    def body(tot, inp):
        hc, lc = inp
        logits = jnp.einsum("bcd,dv->bcv", hc.astype(jnp.float32),
                            lm_head.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return tot + jnp.sum(nll), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hp, lp))
    return tot / (B * S)
