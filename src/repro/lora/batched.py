"""Batched heterogeneous-adapter application.

Two execution paths, each with a padded and a bucketed form:
  * gather-einsum (default, lowerable on any backend; used by the dry-run
    and the CPU engine) — padded: per-row adapter index gathers its A/B
    from the bank, everything padded to the bank's max rank (the paper's
    co-batch padding tax, faithfully); bucketed: one masked pass per rank
    bucket at the bucket's own rank (rows outside the bucket are zeroed),
    numerically identical to padded because padding is inert;
  * Pallas SGMV (``repro.kernels.ops``) — TPU kernel path for token-major
    flattened layouts, ``apply_bank_sgmv`` dispatching ``sgmv`` (padded)
    or the token-compacting ``sgmv_rank_bucketed`` (bucketed).

``make_lora_cb`` is layout-polymorphic: a dict bank slice selects the
padded path with ``idx: (Bt,)`` global adapter rows; a tuple of per-
bucket slices selects the bucketed path with ``idx: (Bt, 2)`` carrying
(bucket, local-row) per request — the shape ``LoRABank.lora_idx``
produces.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import constrain


def lora_delta(x, A, B, idx, scaling: float = 1.0):
    """x: (Bt, S, d); A: (Na, d, r); B: (Na, r, out); idx: (Bt,) int32.

    Every row pays max-rank (r = bank rank) cost regardless of its
    adapter's true rank — zero-padded banks make the extra columns
    numerically inert but computationally present (BGMV semantics).
    """
    from repro.models.common import SHARDING_MODE
    a = A[idx]                                   # (Bt, d, r)
    b = B[idx]                                   # (Bt, r, out)
    h = jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype))
    if SHARDING_MODE == "baseline":
        # S-LoRA TP: rank dim sharded -> partial sums all-reduced
        h = constrain(h, "batch", None, "model")
    out = jnp.einsum("bsr,bro->bso", h, b.astype(x.dtype))
    return constrain(out * scaling, "batch", None, None)


def lora_delta_bucketed(x, bucket_targets, idx, scaling: float = 1.0):
    """x: (Bt, S, d); bucket_targets: sequence of per-bucket {"A","B"}
    slices (bucket b at rank r_b); idx: (Bt, 2) int32 of (bucket, local).

    Each bucket runs a gather-einsum at its own rank over the full row
    set with out-of-bucket rows masked to zero — static shapes, and each
    row's *numerics* come only from its own bucket.  (The compute saving
    of bucketing lives on the token-compacting SGMV path and in the cost
    model; this dense form trades a masked pass per bucket for backend
    portability.)
    """
    bucket, local = idx[..., 0], idx[..., 1]
    out = None
    for b, t in enumerate(bucket_targets):
        sel = bucket == b
        y = lora_delta(x, t["A"], t["B"], jnp.where(sel, local, 0), scaling)
        y = jnp.where(sel[:, None, None], y, 0.0)
        out = y if out is None else out + y
    return out


def make_lora_cb(bank_layer, idx, scaling: float = 1.0):
    """Bind one layer's bank slice and per-row adapter indices into the
    projection hook used by the attention/ssm blocks.

    ``bank_layer`` is {target: {"A","B"}} for a padded bank, or a tuple
    of such dicts (one per rank bucket) for a bucketed bank; ``idx`` is
    the matching ``LoRABank.lora_idx`` output."""
    if bank_layer is None:
        return None

    if isinstance(bank_layer, (tuple, list)):
        def cb_bucketed(name, x):
            targets = [bk.get(name) for bk in bank_layer]
            if any(t is None for t in targets):
                return 0.0
            return lora_delta_bucketed(x, targets, idx, scaling)

        return cb_bucketed

    def cb(name, x):
        t = bank_layer.get(name)
        if t is None:
            return 0.0
        return lora_delta(x, t["A"], t["B"], idx, scaling)

    return cb


def apply_bank_sgmv(x, bank, name: str, layer: int, token_adapter, *,
                    scaling: float = 1.0, block_t: int = 16,
                    interpret: bool = True):
    """Pallas path for token-major flattened layouts: x: (T, d) tokens,
    token_adapter: (T,) *global* adapter rows of ``bank`` (a LoRABank).

    Padded banks dispatch one ``sgmv`` over the full token set at the
    bank max rank; bucketed banks dispatch ``sgmv_rank_bucketed``, which
    compacts each bucket's tokens and runs them at the bucket's own rank
    (FLOPs = sum_b T_b * r_b * (d + o) instead of T * max_r * (d + o)).
    """
    from repro.kernels.ops import sgmv, sgmv_rank_bucketed
    if bank.mode == "padded":
        t = bank.data[name]
        return sgmv(x, t["A"][layer], t["B"][layer], token_adapter,
                    scaling=scaling, block_t=block_t, interpret=interpret)
    banks = [(bk[name]["A"][layer], bk[name]["B"][layer])
             for bk in bank.data]
    return sgmv_rank_bucketed(x, banks, token_adapter, bank.adapter_bucket,
                              adapter_local=bank.adapter_local,
                              scaling=scaling, block_t=block_t,
                              interpret=interpret)
