"""Batched heterogeneous-adapter application.

Two execution paths, each with a padded and a bucketed form:
  * gather-einsum (default, lowerable on any backend; used by the dry-run
    and the CPU engine) — padded: per-row adapter index gathers its A/B
    from the bank, everything padded to the bank's max rank (the paper's
    co-batch padding tax, faithfully); bucketed: one masked pass per rank
    bucket at the bucket's own rank (rows outside the bucket are zeroed),
    numerically identical to padded because padding is inert;
  * Pallas SGMV (``repro.kernels.ops``) — TPU kernel path. The fused v2
    kernels are jittable end-to-end, so they serve BOTH the token-major
    flattened entry point (``apply_bank_sgmv``) and the model's in-scan
    LoRA callback: ``make_lora_cb(..., kernel="sgmv")`` flattens the
    (B, S, d) activation to token-major rows and dispatches one fused
    kernel per target — ``sgmv_fused`` for padded banks,
    ``sgmv_bucketed_fused`` (single dispatch, every bucket at its own
    rank) for bucketed banks.

``make_lora_cb`` is layout-polymorphic: a dict bank slice selects the
padded path with ``idx: (Bt,)`` global adapter rows; a tuple of per-
bucket slices selects the bucketed path with ``idx: (Bt, 2)`` carrying
(bucket, local-row) per request — the shape ``LoRABank.lora_idx``
produces.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import constrain, rows_to_tokens, tokens_to_rows


def lora_delta(x, A, B, idx, scaling: float = 1.0):
    """x: (Bt, S, d); A: (Na, d, r); B: (Na, r, out); idx: (Bt,) int32.

    Every row pays max-rank (r = bank rank) cost regardless of its
    adapter's true rank — zero-padded banks make the extra columns
    numerically inert but computationally present (BGMV semantics).
    """
    from repro.models.common import SHARDING_MODE, current_axis_env
    coshard = current_axis_env().lora == "coshard"
    a = A[idx]                                   # (Bt, d, r)
    b = B[idx]                                   # (Bt, r, out)
    h = jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype))
    if coshard:
        # mesh-sharded engine: A is d-sharded, so each shard holds a
        # partial rank-r sum — replicating h here is ONE psum of the
        # tiny (Bt, S, r) intermediate, never the (Bt, S, out) delta
        h = constrain(h, "batch", None, None)
    elif SHARDING_MODE == "baseline":
        # S-LoRA TP: rank dim sharded -> partial sums all-reduced
        h = constrain(h, "batch", None, "model")
    out = jnp.einsum("bsr,bro->bso", h, b.astype(x.dtype))
    if coshard:
        # B is d_out-sharded: the delta comes out column-sharded exactly
        # like the base projection output it is added to — no gather
        return constrain(out * scaling, "batch", None, "model")
    return constrain(out * scaling, "batch", None, None)


def lora_delta_bucketed(x, bucket_targets, idx, scaling: float = 1.0):
    """x: (Bt, S, d); bucket_targets: sequence of per-bucket {"A","B"}
    slices (bucket b at rank r_b); idx: (Bt, 2) int32 of (bucket, local).

    Each bucket runs a gather-einsum at its own rank over the full row
    set with out-of-bucket rows masked to zero — static shapes, and each
    row's *numerics* come only from its own bucket.  (The compute saving
    of bucketing lives on the token-compacting SGMV path and in the cost
    model; this dense form trades a masked pass per bucket for backend
    portability.)
    """
    bucket, local = idx[..., 0], idx[..., 1]
    out = None
    for b, t in enumerate(bucket_targets):
        sel = bucket == b
        y = lora_delta(x, t["A"], t["B"], jnp.where(sel, local, 0), scaling)
        y = jnp.where(sel[:, None, None], y, 0.0)
        out = y if out is None else out + y
    return out


def _coshard_env():
    """The active mesh-sharded LoRA environment, or None. Returns
    (mesh, model_axis, n_shards) when the engine runs in "coshard" mode
    with a real model axis to split over."""
    from repro.models.common import current_axis_env
    env = current_axis_env()
    if env.lora != "coshard" or env.mesh is None or env.model is None:
        return None
    s = env.mesh.shape[env.model]
    if s <= 1:
        return None
    return env.mesh, env.model, s


def _lora_delta_sgmv(x, target, idx, scaling, block_t, interpret):
    """Padded-bank fused-kernel form of ``lora_delta``: token-major
    flatten, one ``sgmv_fused`` dispatch, unflatten. Under the mesh-
    sharded engine ("coshard" axis env) the dispatch becomes a
    shard_map: each shard runs the shrink kernel on its local
    d/n_shards slice of A, the (T_pad, r) partials are reduced with ONE
    psum, and the expand kernel emits the d_out-sharded delta — full
    weights and the full-width delta never materialize on one device."""
    from repro.kernels.ops import padded_len, prepare_segments, sgmv_fused
    x2, (B_, S_) = rows_to_tokens(x)
    tok = jnp.repeat(idx, S_)
    bt = 16 if block_t is None else block_t
    A = target["A"].astype(x.dtype)
    B = target["B"].astype(x.dtype)
    co = _coshard_env()
    if co is not None and A.shape[1] % co[2] == 0 \
            and B.shape[2] % co[2] == 0:
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels import resolve_interpret
        from repro.kernels.sgmv import sgmv_expand, sgmv_shrink
        mesh, axis, _ = co
        T, d = x2.shape
        Na = A.shape[0]
        dest, block_adapter = prepare_segments(tok, Na, bt)
        x_pad = jnp.zeros((padded_len(T, Na, bt), d), x.dtype
                          ).at[dest].set(x2)
        interp = resolve_interpret(interpret)

        def per_shard(xp, As, Bs, blk):
            h = sgmv_shrink(xp, As, blk, block_t=bt, interpret=interp)
            h = jax.lax.psum(h, axis)
            return sgmv_expand(h, Bs, blk, block_t=bt, interpret=interp)

        y_pad = shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(None, axis), P(None, axis, None),
                      P(None, None, axis), P(None)),
            out_specs=P(None, axis), check_rep=False,
        )(x_pad, A, B, block_adapter)
        y = y_pad[dest] * scaling
        return constrain(tokens_to_rows(y, B_, S_), "batch", None,
                         "model")
    y = sgmv_fused(x2, A, B, tok, scaling=scaling, block_t=bt,
                   interpret=interpret)
    return constrain(tokens_to_rows(y, B_, S_), "batch", None, None)


def _lora_delta_sgmv_bucketed(x, bucket_targets, idx, scaling, block_t,
                              interpret):
    """Bucketed fused-kernel form: every batch row is its own "adapter"
    (adapter_bucket/adapter_local taken straight from the (Bt, 2) idx),
    so the whole heterogeneous delta is ONE ``sgmv_bucketed_fused``
    dispatch with each row's tokens at its own bucket's rank. Under the
    "coshard" axis env the dispatch is a shard_map over the split
    multibank kernels: per-shard shrink on local d slices of every
    bucket's A bank, one psum of the (T_pad, max_r) intermediate, then
    the expand kernel against local d_out slices of the B banks (see
    the per-shard reduction contract in ``repro.kernels.sgmv``)."""
    from repro.kernels.ops import (padded_len, prepare_segments_bucketed,
                                   sgmv_bucketed_fused)
    x2, (B_, S_) = rows_to_tokens(x)
    tok = jnp.repeat(jnp.arange(B_, dtype=jnp.int32), S_)
    co = _coshard_env()
    if co is not None \
            and all(t["A"].shape[1] % co[2] == 0
                    and t["B"].shape[2] % co[2] == 0
                    for t in bucket_targets):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels import resolve_interpret
        from repro.kernels.sgmv import (sgmv_multibank_expand,
                                        sgmv_multibank_shrink)
        mesh, axis, _ = co
        bt = 16 if block_t is None else block_t
        T, d = x2.shape
        Na = B_
        nb = len(bucket_targets)
        dest, block_adapter = prepare_segments_bucketed(
            tok, idx[:, 0], Na, nb, bt)
        block_bucket = idx[:, 0][block_adapter]
        block_row = idx[:, 1][block_adapter]
        x_pad = jnp.zeros((padded_len(T, Na, bt), d), x.dtype
                          ).at[dest].set(x2)
        A_banks = tuple(t["A"].astype(x.dtype) for t in bucket_targets)
        B_banks = tuple(t["B"].astype(x.dtype) for t in bucket_targets)
        interp = resolve_interpret(interpret)

        def per_shard(xp, As, Bs, bkt, row):
            h = sgmv_multibank_shrink(xp, As, bkt, row, block_t=bt,
                                      interpret=interp)
            h = jax.lax.psum(h, axis)
            return sgmv_multibank_expand(h, Bs, bkt, row, block_t=bt,
                                         interpret=interp)

        y_pad = shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(None, axis),
                      tuple(P(None, axis, None) for _ in A_banks),
                      tuple(P(None, None, axis) for _ in B_banks),
                      P(None), P(None)),
            out_specs=P(None, axis), check_rep=False,
        )(x_pad, A_banks, B_banks, block_bucket, block_row)
        y = y_pad[dest] * scaling
        return constrain(tokens_to_rows(y, B_, S_), "batch", None,
                         "model")
    banks = tuple((t["A"].astype(x.dtype), t["B"].astype(x.dtype))
                  for t in bucket_targets)
    y = sgmv_bucketed_fused(x2, banks, tok, idx[:, 0], idx[:, 1],
                            scaling=scaling, block_t=block_t,
                            interpret=interpret)
    return constrain(tokens_to_rows(y, B_, S_), "batch", None, None)


def make_lora_cb(bank_layer, idx, scaling: float = 1.0, *,
                 kernel: str = "einsum", block_t=None,
                 interpret=None):
    """Bind one layer's bank slice and per-row adapter indices into the
    projection hook used by the attention/ssm blocks.

    ``bank_layer`` is {target: {"A","B"}} for a padded bank, or a tuple
    of such dicts (one per rank bucket) for a bucketed bank; ``idx`` is
    the matching ``LoRABank.lora_idx`` output. ``kernel`` selects the
    execution form: "einsum" (gather-einsum, any backend) or "sgmv"
    (fused Pallas kernels over the token-major flattening — jittable, so
    it works inside the layer scan; compiled on TPU, interpreted
    elsewhere per ``repro.kernels.default_interpret``). ``block_t=None``
    defers to the ``kernels.tune`` heuristic table (bucketed path) or
    the default 16 (padded path)."""
    if bank_layer is None:
        return None
    if kernel not in ("einsum", "sgmv"):
        raise ValueError(f"unknown lora kernel {kernel!r}")

    if isinstance(bank_layer, (tuple, list)):
        def cb_bucketed(name, x):
            targets = [bk.get(name) for bk in bank_layer]
            if any(t is None for t in targets):
                return 0.0
            if kernel == "sgmv":
                return _lora_delta_sgmv_bucketed(x, targets, idx, scaling,
                                                 block_t, interpret)
            return lora_delta_bucketed(x, targets, idx, scaling)

        return cb_bucketed

    def cb(name, x):
        t = bank_layer.get(name)
        if t is None:
            return 0.0
        if kernel == "sgmv":
            return _lora_delta_sgmv(x, t, idx, scaling, block_t, interpret)
        return lora_delta(x, t["A"], t["B"], idx, scaling)

    return cb


def apply_bank_sgmv(x, bank, name: str, layer: int, token_adapter, *,
                    scaling: float = 1.0, block_t=None,
                    interpret=None, fused: bool = True):
    """Pallas path for token-major flattened layouts: x: (T, d) tokens,
    token_adapter: (T,) *global* adapter rows of ``bank`` (a LoRABank).

    Padded banks dispatch one ``sgmv_fused`` over the full token set at
    the bank max rank; bucketed banks dispatch ``sgmv_bucketed_fused``,
    a SINGLE traced kernel sweep in which each bucket's tokens run at
    the bucket's own rank (FLOPs = sum_b T_b * r_b * (d + o) instead of
    T * max_r * (d + o)). ``fused=False`` selects the legacy two-kernel
    / host-loop dispatchers (kept for A/Bs; bit-identical outputs).
    """
    from repro.kernels.ops import (sgmv, sgmv_bucketed_fused, sgmv_fused,
                                   sgmv_rank_bucketed)
    if bank.mode == "padded":
        t = bank.data[name]
        fn = sgmv_fused if fused else sgmv
        return fn(x, t["A"][layer], t["B"][layer], token_adapter,
                  scaling=scaling,
                  block_t=16 if block_t is None else block_t,
                  interpret=interpret)
    banks = [(bk[name]["A"][layer], bk[name]["B"][layer])
             for bk in bank.data]
    if fused:
        return sgmv_bucketed_fused(x, banks, token_adapter,
                                   bank.adapter_bucket,
                                   bank.adapter_local, scaling=scaling,
                                   block_t=block_t, interpret=interpret)
    return sgmv_rank_bucketed(x, banks, token_adapter, bank.adapter_bucket,
                              adapter_local=bank.adapter_local,
                              scaling=scaling,
                              block_t=16 if block_t is None else block_t,
                              interpret=interpret)
