"""Batched heterogeneous-adapter application.

Two execution paths:
  * gather-einsum (default, lowerable on any backend; used by the dry-run
    and the CPU engine) — per-row adapter index gathers its A/B from the
    bank, everything padded to the bank's max rank (the paper's co-batch
    padding tax, faithfully);
  * Pallas SGMV (``repro.kernels.ops``) — TPU kernel path, validated in
    interpret mode, selected via ``use_pallas=True`` for token-major
    flattened layouts.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import constrain


def lora_delta(x, A, B, idx, scaling: float = 1.0):
    """x: (Bt, S, d); A: (Na, d, r); B: (Na, r, out); idx: (Bt,) int32.

    Every row pays max-rank (r = bank rank) cost regardless of its
    adapter's true rank — zero-padded banks make the extra columns
    numerically inert but computationally present (BGMV semantics).
    """
    from repro.models.common import SHARDING_MODE
    a = A[idx]                                   # (Bt, d, r)
    b = B[idx]                                   # (Bt, r, out)
    h = jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype))
    if SHARDING_MODE == "baseline":
        # S-LoRA TP: rank dim sharded -> partial sums all-reduced
        h = constrain(h, "batch", None, "model")
    out = jnp.einsum("bsr,bro->bso", h, b.astype(x.dtype))
    return constrain(out * scaling, "batch", None, None)


def make_lora_cb(bank_layer, idx, scaling: float = 1.0):
    """Bind one layer's bank slice {target: {"A","B"}} and per-row adapter
    indices into the projection hook used by the attention/ssm blocks."""
    if bank_layer is None:
        return None

    def cb(name, x):
        t = bank_layer.get(name)
        if t is None:
            return 0.0
        return lora_delta(x, t["A"], t["B"], idx, scaling)

    return cb
