"""LoRABank: one descriptor for a server's stacked adapter bank, in
either of two layouts.

``padded`` — the paper-faithful baseline: every adapter zero-padded to
the hosted subset's max rank, one stacked bank, every co-batched request
pays max-rank compute (§III-A.5's padding tax, reproduced faithfully).

``bucketed`` — the beyond-paper mode: adapters grouped into power-of-two
rank buckets, each bucket its own stacked bank at the *bucket* rank.  A
rank-8 request co-batched with a rank-128 one pays rank-8 compute on the
bucketed paths (CaraServe-style rank-aware serving).  Both layouts hold
numerically identical adapter weights (padding is inert), so switching
``bank_mode`` changes cost, never tokens.

``LoRABank.data`` is what the model consumes:
  padded   — {target: {"A": (L, Na, d, r), "B": (L, Na, r, o)}}
  bucketed — tuple of such pytrees, one per bucket (ascending bucket
             rank), each stacked over only that bucket's adapters at the
             bucket's rank.
Both thread through ``lax.scan`` over the layer axis unchanged (a tuple
of pytrees is itself a pytree).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .adapter import adapter_key, bank_nbytes, init_adapter, pad_rank


def rank_bucket(rank: int) -> int:
    """Smallest power of two >= rank (bucket 8 serves ranks 5..8)."""
    if rank <= 0:
        raise ValueError(f"rank must be positive, got {rank}")
    return 1 << (rank - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class LoRABank:
    """Descriptor + device data for one server's hosted adapter subset."""
    mode: str                          # "padded" | "bucketed"
    adapter_ids: Tuple[str, ...]       # sorted; index = model adapter idx
    ranks: Tuple[int, ...]             # aligned with adapter_ids
    data: Any                          # model-facing bank pytree(s)
    bucket_ranks: Tuple[int, ...] = () # ascending; empty for padded
    bucket_counts: Tuple[int, ...] = ()  # adapters per bucket
    adapter_bucket: Optional[jax.Array] = None   # (Na,) adapter -> bucket
    adapter_local: Optional[jax.Array] = None    # (Na,) row within bucket

    # -- introspection ---------------------------------------------------
    @property
    def n_adapters(self) -> int:
        return len(self.adapter_ids)

    @property
    def max_rank(self) -> int:
        return max(self.ranks)

    @property
    def signature(self) -> tuple:
        """Layout identity for jit-cache keys: prefill functions traced
        against one signature are reusable until the bank reshapes."""
        if self.mode == "padded":
            return ("padded", self.max_rank, self.n_adapters)
        return ("bucketed",
                tuple(zip(self.bucket_ranks, self.bucket_counts)))

    def nbytes(self) -> int:
        return bank_nbytes(self.data)

    def index(self, adapter_id: str) -> int:
        return self.adapter_ids.index(adapter_id)

    # -- model-facing indices -------------------------------------------
    def lora_idx(self, adapter_idx: jax.Array) -> jax.Array:
        """Turn global adapter indices (B,) into the index array the
        model callback consumes: the same (B,) for padded, a stacked
        (B, 2) of (bucket, local-row) for bucketed."""
        adapter_idx = jnp.asarray(adapter_idx, jnp.int32)
        if self.mode == "padded":
            return adapter_idx
        return jnp.stack([self.adapter_bucket[adapter_idx],
                          self.adapter_local[adapter_idx]], axis=-1)

    # -- per-adapter weight access (GDR remote-read data plane) ----------
    def _rows(self, adapter_id: str):
        """(bank pytree holding the adapter, its stack row, its rank)."""
        i = self.index(adapter_id)
        r = self.ranks[i]
        if self.mode == "padded":
            return self.data, i, r
        return (self.data[int(self.adapter_bucket[i])],
                int(self.adapter_local[i]), r)

    def get_adapter(self, adapter_id: str):
        """Extract one adapter's unpadded weights
        ``{target: {"A": (L, d, r), "B": (L, r, o)}}`` — what a peer
        serves over GDR when this bank's copy is read remotely."""
        tree, row, r = self._rows(adapter_id)
        return {t: {"A": tree[t]["A"][:, row, :, :r],
                    "B": tree[t]["B"][:, row, :r, :]}
                for t in tree}

    def set_adapter(self, adapter_id: str, weights) -> "LoRABank":
        """Return a bank with ``adapter_id``'s rows overwritten by
        ``weights`` (the peer-read install path; padding beyond the
        adapter's rank is untouched and must stay zero)."""
        tree, row, r = self._rows(adapter_id)
        new = {t: {"A": tree[t]["A"].at[:, row, :, :r].set(
                       weights[t]["A"]),
                   "B": tree[t]["B"].at[:, row, :r, :].set(
                       weights[t]["B"])}
               for t in tree}
        if self.mode == "padded":
            return dataclasses.replace(self, data=new)
        b = int(self.adapter_bucket[self.index(adapter_id)])
        data = tuple(new if j == b else d
                     for j, d in enumerate(self.data))
        return dataclasses.replace(self, data=data)


def build_bank(cfg, adapter_ranks: Dict[str, int], key, *,
               mode: str = "padded", n_layers=None,
               dtype=jnp.float32) -> LoRABank:
    """Build a bank over ``sorted(adapter_ranks)`` in the given layout.

    Weights are keyed per adapter id via ``adapter_key`` in both modes,
    so the same adapter carries bit-identical weights whether it lands in
    a padded bank, a bucketed bank, or a rebuilt bank after a placement
    change — the parity guarantee the padded-vs-bucketed A/Bs rest on.
    """
    ids = sorted(adapter_ranks)
    if not ids:
        raise ValueError("build_bank needs at least one adapter")
    ranks = [adapter_ranks[a] for a in ids]
    if mode == "padded":
        from .adapter import init_bank_from
        data = init_bank_from(cfg, adapter_ranks, key, n_layers=n_layers,
                              dtype=dtype)
        return LoRABank("padded", tuple(ids), tuple(ranks), data)
    if mode != "bucketed":
        raise ValueError(f"unknown bank_mode {mode!r}")

    buckets = sorted({rank_bucket(r) for r in ranks})
    members: Dict[int, list] = {b: [] for b in buckets}
    bucket_of, local_of = [], []
    for aid, r in zip(ids, ranks):
        b = rank_bucket(r)
        bucket_of.append(buckets.index(b))
        local_of.append(len(members[b]))
        members[b].append(aid)
    data = []
    for b in buckets:
        singles = []
        for aid in members[b]:
            a = init_adapter(cfg, adapter_ranks[aid], adapter_key(key, aid),
                             n_layers=n_layers, dtype=dtype)
            singles.append(jax.tree.map(lambda t: pad_rank(t, b), a))
        data.append(jax.tree.map(lambda *xs: jnp.stack(xs, axis=1),
                                 *singles))
    return LoRABank("bucketed", tuple(ids), tuple(ranks), tuple(data),
                    bucket_ranks=tuple(buckets),
                    bucket_counts=tuple(len(members[b]) for b in buckets),
                    adapter_bucket=jnp.asarray(bucket_of, jnp.int32),
                    adapter_local=jnp.asarray(local_of, jnp.int32))
