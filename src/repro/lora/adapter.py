"""LoRA adapters: single-adapter pytrees and stacked multi-adapter banks.

A *bank* holds ``n_adapters`` adapters padded to a common ``max_rank`` —
exactly the layout Punica/S-LoRA kernels consume, and the layout in which
the padding tax the paper analyzes (§III-A.5) arises: every request in a
co-batch pays ``max_rank`` compute. Adapters of rank r < max_rank are
zero-padded (rows/cols beyond r contribute nothing numerically but fully
participate in the matmuls).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class Adapter:
    """Metadata for one serving adapter (the unit the orchestrator places)."""
    adapter_id: str
    rank: int
    base_model: str = "llama-7b-paper"

    def nbytes(self, cfg) -> int:
        """Host-memory footprint (bf16): A+B on every target, all layers."""
        total = 0
        for t in cfg.lora.targets:
            in_dim = _target_in_dim(cfg, t)
            out_dim = _target_out_dim(cfg, t)
            total += in_dim * self.rank + self.rank * out_dim
        return 2 * total * cfg.n_layers  # 2 bytes / param


def _target_out_dim(cfg, target: str) -> int:
    hd = cfg.resolved_head_dim or cfg.d_model
    H, Kv = cfg.n_heads or 1, cfg.n_kv_heads or 1
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        return {"q": H * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                "k": m.kv_lora_rank + m.qk_rope_head_dim,
                "v": m.kv_lora_rank + m.qk_rope_head_dim,
                "o": cfg.d_model}[target]
    return {"q": H * hd, "k": Kv * hd, "v": Kv * hd, "o": cfg.d_model}[target]


def _target_in_dim(cfg, target: str) -> int:
    if target != "o":
        return cfg.d_model
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return cfg.d_model
    if cfg.mla is not None:
        return cfg.n_heads * cfg.mla.v_head_dim
    return cfg.n_heads * cfg.resolved_head_dim


def init_adapter(cfg, rank: int, key, n_layers=None, dtype=jnp.float32):
    """Single adapter: {target: {"A": (L,d,r), "B": (L,r,out)}}.

    A ~ N(0, 1/d); B = 0 (standard LoRA init).
    """
    L = n_layers if n_layers is not None else cfg.n_layers
    d = cfg.d_model
    out = {}
    for t in cfg.lora.targets:
        key, ka = jax.random.split(key)
        o = _target_out_dim(cfg, t)
        in_dim = _target_in_dim(cfg, t)
        out[t] = {
            "A": dense_init(ka, (L, in_dim, rank), fan_in=in_dim, dtype=dtype),
            "B": jnp.zeros((L, rank, o), dtype),
        }
    return out


def init_bank(cfg, ranks, key, n_layers=None, dtype=jnp.float32):
    """Stacked bank: {target: {"A": (L, Na, d, max_r), "B": (L, Na, max_r, o)}}.

    Adapters with rank < max(ranks) are zero-padded to max rank — the
    max-rank padding semantics of BGMV/MBGMV.
    """
    max_r = max(ranks)
    singles = []
    for r in ranks:
        key, k2 = jax.random.split(key)
        a = init_adapter(cfg, r, k2, n_layers=n_layers, dtype=dtype)
        # pad rank dim to max_r
        a = jax.tree.map(lambda t: pad_rank(t, max_r), a)
        singles.append(a)
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *singles)


def adapter_key(base_key, adapter_id: str):
    """Deterministic per-adapter PRNG key: the same adapter id always
    yields the same weights, no matter which bank subset it lands in."""
    return jax.random.fold_in(base_key,
                              zlib.crc32(adapter_id.encode()) & 0x7FFFFFFF)


def init_bank_from(cfg, adapter_ranks: Dict[str, int], key, n_layers=None,
                   dtype=jnp.float32):
    """Bank over ``sorted(adapter_ranks)``, padded to the *subset's* max
    rank (not a global one): a server hosting only ranks {8, 16} pays a
    16-wide bank. Weights are keyed per adapter id via ``adapter_key``,
    so rebuilding a bank for a different hosted subset (after a
    placement change) reproduces identical weights for every adapter it
    keeps."""
    ids = sorted(adapter_ranks)
    if not ids:
        raise ValueError("init_bank_from needs at least one adapter")
    max_r = max(adapter_ranks.values())
    singles = []
    for aid in ids:
        a = init_adapter(cfg, adapter_ranks[aid], adapter_key(key, aid),
                         n_layers=n_layers, dtype=dtype)
        singles.append(jax.tree.map(lambda t: pad_rank(t, max_r), a))
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *singles)


def pad_rank(t: jax.Array, max_r: int) -> jax.Array:
    # A: (L, in, r) -> pad last; B: (L, r, out) -> pad middle
    if t.shape[-1] <= max_r and t.shape[-2] > t.shape[-1]:
        return jnp.pad(t, ((0, 0), (0, 0), (0, max_r - t.shape[-1])))
    return jnp.pad(t, ((0, 0), (0, max_r - t.shape[-2]), (0, 0)))


def merge_adapter(params, adapter, cfg, scaling: float = 1.0):
    """Merge a single adapter into base weights (the paper's §II-B note:
    zero-overhead serving for very hot adapters merged into a dedicated
    instance)."""
    import copy
    merged = jax.tree.map(lambda x: x, params)  # shallow structural copy
    name_map = {"q": "wq", "k": "wk", "v": "wv", "o": "wo"}
    blocks = merged.get("blocks")
    if blocks is None:
        raise ValueError("merge_adapter supports uniform-stack archs")
    attn = dict(blocks["attn"])
    for t, w_name in name_map.items():
        if t not in adapter:
            continue
        delta = jnp.einsum("ldr,lro->ldo", adapter[t]["A"], adapter[t]["B"])
        if w_name in attn:
            attn[w_name] = attn[w_name] + scaling * delta.astype(
                attn[w_name].dtype)
    blocks = dict(blocks)
    blocks["attn"] = attn
    merged = dict(merged)
    merged["blocks"] = blocks
    return merged


def bank_nbytes(bank) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(bank))
