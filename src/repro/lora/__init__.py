from .adapter import (Adapter, adapter_key, init_adapter, init_bank,
                      init_bank_from, merge_adapter, bank_nbytes)
from .batched import lora_delta, make_lora_cb

__all__ = ["Adapter", "adapter_key", "init_adapter", "init_bank",
           "init_bank_from", "merge_adapter", "bank_nbytes", "lora_delta",
           "make_lora_cb"]
