from .adapter import (Adapter, init_adapter, init_bank, merge_adapter,
                      bank_nbytes)
from .batched import lora_delta, make_lora_cb

__all__ = ["Adapter", "init_adapter", "init_bank", "merge_adapter",
           "bank_nbytes", "lora_delta", "make_lora_cb"]
