from .adapter import (Adapter, adapter_key, init_adapter, init_bank,
                      init_bank_from, merge_adapter, bank_nbytes, pad_rank)
from .bank import LoRABank, build_bank, rank_bucket
from .batched import (apply_bank_sgmv, lora_delta, lora_delta_bucketed,
                      make_lora_cb)

__all__ = ["Adapter", "adapter_key", "init_adapter", "init_bank",
           "init_bank_from", "merge_adapter", "bank_nbytes", "pad_rank",
           "LoRABank", "build_bank", "rank_bucket",
           "apply_bank_sgmv", "lora_delta", "lora_delta_bucketed",
           "make_lora_cb"]
