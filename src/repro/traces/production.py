"""Production-like trace matching the paper's Company X characterization:

  * 5 base production adapters of distinct ranks with the rank-wise
    request/token shares of Fig 15;
  * heavy-tailed adapter popularity: top-5 adapters > 70% of traffic
    (Fig 8), the long tail gets the rest;
  * per-adapter arrival drift: varying-load, diurnal, stable, and
    late-surge patterns (Fig 10);
  * annotated into 50/100/200 total adapters via a power law (alpha=1)
    within each rank (§V-E).
"""
from __future__ import annotations

import math
import random
from typing import List, Optional

from repro.core.types import AdapterInfo
from repro.cluster.server import SimRequest

from .synth import make_adapters

# Fig 15 rank-wise request share of the production trace (normalized).
RANK_REQUEST_SHARE = {8: 0.38, 16: 0.27, 32: 0.18, 64: 0.11, 128: 0.06}
# Fig 8: top-5 adapters take ~72.4% of traffic.
TOP5_SHARE = 0.724


def _drift(pattern: str, progress: float) -> float:
    """Relative intensity multiplier over the trace (Fig 10 shapes)."""
    if pattern == "rising":
        return 0.5 + 1.0 * progress
    if pattern == "falling":
        return 1.5 - 1.0 * progress
    if pattern == "diurnal":
        return 1.0 + 0.6 * math.sin(2 * math.pi * progress)
    if pattern == "stable":
        return 1.0
    if pattern == "surge":
        return 1.0 if progress < 0.8 else 3.0
    return 1.0


def production_trace(n_adapters: int, rps: float, duration: float,
                     prompt_len: int = 512, output_len: int = 128,
                     seed: int = 0,
                     load_profile: Optional[str] = None) -> List[SimRequest]:
    reqs, _ = production_trace_with_meta(
        n_adapters, rps, duration, prompt_len=prompt_len,
        output_len=output_len, seed=seed, load_profile=load_profile)
    return reqs


def production_trace_with_meta(n_adapters: int, rps: float,
                               duration: float, prompt_len: int = 512,
                               output_len: int = 128, seed: int = 0,
                               load_profile: Optional[str] = None):
    """Like :func:`production_trace` but also returns the generator's
    ground truth: the per-adapter drift-pattern assignment (adapter_id
    -> Fig 10 pattern; tail adapters are "stable") so drift detectors
    can be validated against what the trace actually does, plus the
    aggregate load profile. ``load_profile`` optionally modulates the
    *aggregate* arrival rate with one of the ``_drift`` shapes (e.g.
    "diurnal" for the day-night swing autoscaling exploits) via
    Poisson thinning."""
    rng = random.Random(seed)
    adapters = make_adapters(n_adapters, seed=seed)
    by_rank = {}
    for a in adapters:
        by_rank.setdefault(a.rank, []).append(a)

    # top-5: most popular adapter of each rank, drifting per Fig 10
    top5 = [by_rank[r][0] for r in sorted(by_rank)]
    drifts = ["rising", "falling", "diurnal", "stable", "surge"]
    patterns = {a.adapter_id: "stable" for a in adapters}
    for j, a in enumerate(top5):
        patterns[a.adapter_id] = drifts[j % len(drifts)]

    ranks = sorted(RANK_REQUEST_SHARE)

    def rank_weights(progress: float) -> List[float]:
        """Fig 15 rank share scaled by each rank-head's Fig 10 drift:
        a surging adapter *adds* arrival intensity instead of merely
        shifting within-rank share, so its absolute rate really surges
        while stable adapters stay stable (the detector ground truth)."""
        return [RANK_REQUEST_SHARE[r]
                * ((1 - TOP5_SHARE) + TOP5_SHARE
                   * _drift(patterns[by_rank[r][0].adapter_id], progress))
                for r in ranks]

    def load(progress: float) -> float:
        return _drift(load_profile, progress) if load_profile else 1.0

    # thinning peaks (drift shapes are bounded, 3x at most)
    grid = [p / 100.0 for p in range(101)]
    peak_i = max(sum(rank_weights(p)) for p in grid)
    peak_l = max(load(p) for p in grid)

    reqs: List[SimRequest] = []
    t, i = 0.0, 0
    while t < duration:
        t += rng.expovariate(rps * peak_i * peak_l)
        if t >= duration:
            break
        progress = t / duration
        rw = rank_weights(progress)
        accept = (sum(rw) / peak_i) * (load(progress) / peak_l)
        if rng.random() >= accept:
            continue    # thinned: instantaneous intensity below peak
        rank = rng.choices(ranks, weights=rw)[0]
        pool = by_rank[rank]
        head = pool[0]
        head_idx = top5.index(head) if head in top5 else 0
        head_w = TOP5_SHARE * _drift(drifts[head_idx % len(drifts)],
                                     progress)
        tail_w = (1 - TOP5_SHARE)
        if len(pool) == 1 or rng.random() < head_w / (head_w + tail_w):
            a = head
        else:
            tail = pool[1:]
            aw = [(j + 1) ** (-1.0) for j in range(len(tail))]
            a = rng.choices(tail, weights=aw)[0]
        pl = max(16, int(rng.lognormvariate(math.log(prompt_len), 0.4)))
        ol = max(4, int(rng.lognormvariate(math.log(output_len), 0.4)))
        reqs.append(SimRequest(req_id=i, adapter_id=a.adapter_id, rank=rank,
                               prompt_len=pl, output_len=ol, arrival=t))
        i += 1
    meta = {"patterns": patterns, "adapters": adapters,
            "load_profile": load_profile or "flat"}
    return reqs, meta


def production_adapters(n_adapters: int, seed: int = 0):
    return make_adapters(n_adapters, seed=seed)
