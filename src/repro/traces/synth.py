"""Trace generators matching the paper's evaluation traces (§V-E).

Dimensions:
  * arrival pattern: uniform or Poisson;
  * adapter-rank popularity: uniform, shifting-skew (Fig 16), exponential,
    or power-law with exponent alpha (Fig 22);
  * adapter counts per rank: power law (alpha=1) within rank, as the paper
    annotates its production trace.

Requests carry (adapter, prompt_len, output_len, timestamp). Default
lengths follow the paper's Fig 6 workload (input 512 / output 128) with
lognormal jitter.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence

from repro.core.types import AdapterInfo
from repro.cluster.server import SimRequest

DEFAULT_RANKS = (8, 16, 32, 64, 128)


def make_adapters(n_adapters: int, ranks: Sequence[int] = DEFAULT_RANKS,
                  nbytes_per_rank: Optional[Dict[int, int]] = None,
                  alpha: float = 1.0, seed: int = 0) -> List[AdapterInfo]:
    """Split `n_adapters` across ranks following a power law on counts
    (alpha=1 as in §V-E), rank order ascending in popularity count."""
    weights = [(i + 1) ** (-alpha) for i in range(len(ranks))]
    tot = sum(weights)
    counts = [max(1, round(n_adapters * w / tot)) for w in weights]
    while sum(counts) > n_adapters:
        counts[counts.index(max(counts))] -= 1
    while sum(counts) < n_adapters:
        counts[counts.index(min(counts))] += 1
    out = []
    for rank, cnt in zip(ranks, counts):
        for i in range(cnt):
            nbytes = (nbytes_per_rank or {}).get(
                rank, 2 * 4 * 2 * 4096 * rank * 32)   # qkvo A+B, 32L, bf16
            out.append(AdapterInfo(f"r{rank}-a{i}", rank, nbytes))
    return out


def _arrivals(rps: float, duration: float, pattern: str, rng) -> List[float]:
    out = []
    if pattern == "uniform":
        n = int(rps * duration)
        out = [i / rps for i in range(n)]
    elif pattern == "poisson":
        t = 0.0
        while t < duration:
            t += rng.expovariate(rps)
            if t < duration:
                out.append(t)
    else:
        raise ValueError(pattern)
    return out


def _rank_weights(popularity: str, ranks: Sequence[int], progress: float,
                  alpha: float = 1.0) -> List[float]:
    n = len(ranks)
    if popularity == "uniform":
        return [1.0 / n] * n
    if popularity == "exponential":
        w = [math.exp(-i) for i in range(n)]          # small ranks popular
        tot = sum(w)
        return [x / tot for x in w]
    if popularity == "powerlaw":
        w = [(i + 1) ** (-alpha) for i in range(n)]   # ranks ascending
        tot = sum(w)
        return [x / tot for x in w]
    if popularity == "shifting":
        # Fig 16: starts with rank-max at 50%, ends with rank-min at 50%
        hi = [0.5 / (n - 1)] * n
        hi[-1] = 0.5
        lo = [0.5 / (n - 1)] * n
        lo[0] = 0.5
        return [h * (1 - progress) + l * progress for h, l in zip(hi, lo)]
    raise ValueError(popularity)


def synth_trace(adapters: List[AdapterInfo], rps: float, duration: float,
                arrival: str = "poisson", popularity: str = "uniform",
                alpha: float = 1.0, prompt_len: int = 512,
                output_len: int = 128, jitter: float = 0.3,
                seed: int = 0) -> List[SimRequest]:
    rng = random.Random(seed)
    by_rank: Dict[int, List[AdapterInfo]] = {}
    for a in adapters:
        by_rank.setdefault(a.rank, []).append(a)
    ranks = sorted(by_rank)
    times = _arrivals(rps, duration, arrival, rng)
    reqs = []
    for i, t in enumerate(times):
        w = _rank_weights(popularity, ranks, t / duration, alpha)
        rank = rng.choices(ranks, weights=w)[0]
        # within a rank: power-law adapter popularity (alpha=1)
        pool = by_rank[rank]
        aw = [(j + 1) ** (-1.0) for j in range(len(pool))]
        a = rng.choices(pool, weights=aw)[0]
        pl = max(16, int(rng.lognormvariate(math.log(prompt_len), jitter)))
        ol = max(4, int(rng.lognormvariate(math.log(output_len), jitter)))
        reqs.append(SimRequest(req_id=i, adapter_id=a.adapter_id,
                               rank=rank, prompt_len=pl, output_len=ol,
                               arrival=t))
    return reqs


def six_traces(adapters, rps: float, duration: float, seed: int = 0):
    """The paper's 2 arrival x 3 popularity grid (§V-E)."""
    out = {}
    for arrival in ("uniform", "poisson"):
        for pop in ("uniform", "shifting", "exponential"):
            out[f"{arrival}-{pop}"] = synth_trace(
                adapters, rps, duration, arrival=arrival, popularity=pop,
                seed=seed)
    return out
