from .production import (production_adapters, production_trace,
                         production_trace_with_meta)
from .synth import make_adapters, six_traces, synth_trace
