from .production import production_adapters, production_trace
from .synth import make_adapters, six_traces, synth_trace
