"""Analytic cost model for a LoRA-serving LLM inference server, calibrated
to the paper's measurements (§III-A). The cluster simulator uses it for
iteration times; the orchestrator uses it for operating points.

Calibration (derivation):
  * Fig 3 — single request, Llama-7B, input 2000: rank-128 prefill is
    2.7x rank-8. With lora overhead l(r) = x*r*base:
    (1+128x)/(1+8x) = 2.7  =>  x = 0.016 at TP=1.
  * Fig 5 — same at TP=8: ratio 1.2  =>  x(8) = 0.00169. Fitting
    x(tp) = x1 * tp^-beta gives beta = log(0.016/0.00169)/log(8) ~ 1.08
    (the LoRA BGMV/MBGMV path loses efficiency slower than 1/tp).
  * Fig 4 — Llama-70B TP=8: ratio 1.45 => x70(8) ~ 0.0039 ~ 2.3x the 7B
    value; consistent with x scaling linearly in d_model (8192/4096 = 2).
  =>  lora_factor(r, d, tp) = 0.016 * r * (d/4096) / tp^1.08
  * Fig 1 — co-serving r8 with r128 inflates the whole batch to max-rank
    cost: iteration cost uses max(rank in batch), which yields the +84%
    P95 TTFT skew in simulation.
  * Fig 3 bottom — decode (TBT) rank sensitivity is "subtle" (memory
    bound): decode lora factor is scaled by DECODE_LORA_DAMP = 0.15.
  * Beyond-paper: ``prefill_time_bucketed`` / ``decode_time_bucketed``
    charge the *sum of per-rank-bucket* costs instead of max(rank) — the
    cost-model mirror of rank-bucketed banks, used by ``SimServer`` when
    ``bank_mode="bucketed"``.
  * Fused-kernel terms (SGMV v2): the calibration above IS the fused
    single-dispatch kernel (one pass over the bank, LoRA intermediate
    resident in on-chip memory). ``fused=False`` charges what the
    legacy two-kernel / host-loop dispatchers additionally pay: the
    rank-r shrink output round-tripping HBM (write+read per token per
    target per layer) and the extra kernel launches (2 per application
    unfused, 2·n_buckets for the host-loop bucketed dispatcher, vs 1
    fused). ``steps=k`` amortizes the per-iteration scheduling floor
    ITER_OVERHEAD over a k-token fused decode dispatch
    (``ServingEngine.decode_steps``) — one host round-trip per k tokens.

  * Mesh-sharded engine terms: when ``mesh_shape=(dp, tp)`` is set the
    model charges explicit ICI ring-all-reduce time per iteration
    (``iteration_ici_time``): 2 activation all-reduces per layer plus
    the co-sharded LoRA rank-r psum per target per layer — the exact
    collectives the sharded ``ServingEngine`` issues. Zero at tp=1 and
    when ``mesh_shape`` is None (legacy abstract-TP behavior unchanged).

Hardware reference: A100 SXM 40GB (312 TF bf16, ~1.55 TB/s HBM), the
paper's Standard_ND96asr_v4 nodes. The TPU deployment path of this repo
uses the v5e constants in launch/roofline instead; the simulator keeps the
paper's GPUs so its figures are comparable with the paper's.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

A100_FLOPS = 312e12          # bf16 peak / GPU
A100_HBM = 1.55e12           # bytes/s
# Absolute-scale calibration: the paper's stack (S-LoRA on A100, Fig 3/6)
# achieves far below peak — Fig 6 shows a single TP=4 server *saturating*
# at ~4 RPS (input 512 / output 128) for rank>=64. Backing that through
# the iteration model gives an effective prefill MFU ~0.07 and decode HBM
# efficiency ~0.35 (decode-bound saturation at ~550 tok/s/server).
MFU_PREFILL = 0.07           # achieved fraction during prefill
HBM_EFF_DECODE = 0.35        # achieved fraction during decode
X1 = 0.016                   # lora factor per unit rank at TP=1, d=4096
TP_BETA = 1.08
DECODE_LORA_DAMP = 0.15
ITER_OVERHEAD = 4.0e-3       # scheduling/kernel-launch floor per iteration
DISPATCH_OVERHEAD = 5e-6     # per extra kernel launch (unfused paths)
LORA_TARGETS = 4             # q/k/v/o LoRA applications per layer
# Interconnect constants for the mesh-sharded engine mode, mirrored from
# launch/mesh.py (kept import-light: the simulator must not touch jax
# device state by importing the mesh builders).
ICI_BW = 50e9                # bytes/s per link
ICI_LATENCY = 1e-6           # seconds per hop (per collective step)


@dataclasses.dataclass(frozen=True)
class ServerModel:
    """One LLM inference server (one base-model instance, TP over tp GPUs)."""
    n_params: float = 6.7e9          # Llama-7B
    d_model: int = 4096
    tp: int = 4
    max_batch_tokens: int = 8192     # prefill token budget per iteration
    max_decode_batch: int = 64
    # Engine mesh shape (dp, tp) for the mesh-sharded serving mode. None
    # (the default) keeps the legacy single-device model: `tp` above then
    # only scales compute/bandwidth (the paper's abstract TP) and NO ICI
    # collective cost is charged. When set, the last entry is the tensor-
    # parallel degree over the "model" axis and every iteration pays the
    # explicit ring-all-reduce terms below.
    mesh_shape: tuple | None = None

    # -- mesh / interconnect ---------------------------------------------
    @property
    def tp_degree(self) -> int:
        """Tensor-parallel degree over the "model" mesh axis."""
        return self.mesh_shape[-1] if self.mesh_shape else self.tp

    @property
    def dp_degree(self) -> int:
        return self.mesh_shape[0] if self.mesh_shape else 1

    def ici_collective_time(self, nbytes: float) -> float:
        """Ring all-reduce of an ``nbytes`` buffer over the "model" axis:
        2(tp-1) hops of latency plus 2(tp-1)/tp of the buffer crossing
        ICI. Exactly zero at tp=1 (no collective is issued) and when no
        mesh is configured; strictly monotone in ``nbytes`` otherwise."""
        tp = self.tp_degree
        if self.mesh_shape is None or tp <= 1:
            return 0.0
        return (2 * (tp - 1) * ICI_LATENCY
                + (2.0 * (tp - 1) / tp) * nbytes / ICI_BW)

    def iteration_ici_time(self, n_tokens: int,
                           bucket_tokens: Mapping[int, int] | None = None
                           ) -> float:
        """Per-iteration collective cost of the mesh-sharded engine: two
        activation all-reduces per layer (attention o-proj + MLP down-
        proj, (n_tokens, d_model) bf16) plus the co-sharded LoRA rank-r
        psum — one per target per layer, sized (T_b, r_b) per bucket
        (never the full d_model delta: the expand output is already
        column-sharded like the base projection)."""
        layers = self._n_layers()
        t = 2 * layers * self.ici_collective_time(
            2.0 * n_tokens * self.d_model)
        for r, nt in (bucket_tokens or {}).items():
            if r > 0 and nt > 0:
                t += layers * LORA_TARGETS * self.ici_collective_time(
                    2.0 * nt * r)
        return t

    # -- primitives ------------------------------------------------------
    def lora_factor(self, rank: int) -> float:
        if rank <= 0:
            return 0.0
        return X1 * rank * (self.d_model / 4096.0) / (self.tp ** TP_BETA)

    def _prefill_per_token(self) -> float:
        return 2.0 * self.n_params / (self.tp * A100_FLOPS * MFU_PREFILL)

    def _n_layers(self) -> float:
        return 32 * (self.d_model / 4096.0)

    def unfused_penalty(self, bucket_tokens: Mapping[int, int]) -> float:
        """Extra seconds per iteration the legacy (pre-fused) SGMV
        dispatchers pay vs the fused single dispatch: the rank-r shrink
        intermediate round-tripping HBM (write + read, bf16, per token
        per target per layer) plus the extra kernel launches — 2 per
        LoRA application per bucket (shrink + expand, host-loop
        dispatched per bucket) where the fused path launches 1 total."""
        apps = self._n_layers() * LORA_TARGETS
        inter_bytes = sum(2 * 2 * r * nt
                          for r, nt in bucket_tokens.items()) * apps
        launches = (2 * max(1, len(bucket_tokens)) - 1) * apps
        return (inter_bytes / (self.tp * A100_HBM)
                + launches * DISPATCH_OVERHEAD)

    def prefill_time(self, n_tokens: int, max_rank: int, *,
                     fused: bool = True) -> float:
        """Seconds for one prefill iteration of `n_tokens` total tokens,
        co-batched with max adapter rank `max_rank` (everyone pays it).
        The calibration is the fused single-dispatch kernel;
        ``fused=False`` adds the legacy dispatchers' penalty."""
        base = self._prefill_per_token() * n_tokens
        t = ITER_OVERHEAD + base * (1.0 + self.lora_factor(max_rank))
        t += self.iteration_ici_time(n_tokens, {max_rank: n_tokens})
        if not fused:
            t += self.unfused_penalty({max_rank: n_tokens})
        return t

    def prefill_time_bucketed(self, bucket_tokens: Mapping[int, int], *,
                              fused: bool = True) -> float:
        """Rank-bucketed prefill: `bucket_tokens` maps bucket rank ->
        token count in that bucket. The base model pass covers all tokens
        once; each bucket's LoRA overhead applies only to its own tokens
        at its own rank (sum of per-bucket costs), instead of every token
        paying `max(rank)` — strictly cheaper than `prefill_time` for any
        batch mixing >= 2 rank buckets. ``fused=False`` models the
        host-loop dispatcher (2 launches per bucket + HBM round-trip)."""
        per_tok = self._prefill_per_token()
        total = sum(bucket_tokens.values())
        lora = sum(nt * self.lora_factor(r)
                   for r, nt in bucket_tokens.items())
        t = ITER_OVERHEAD + per_tok * (total + lora)
        t += self.iteration_ici_time(total, dict(bucket_tokens))
        if not fused:
            t += self.unfused_penalty(dict(bucket_tokens))
        return t

    def adapter_read_bytes(self, rank: int) -> float:
        """BGMV gather per request per decode iteration: A+B on 4 targets,
        every layer, bf16 — padded to the batch max rank (Punica BGMV
        semantics, §III-A.5)."""
        return (2 * 2 * LORA_TARGETS * self.d_model * rank
                * self._n_layers())

    def kv_read_bytes(self, seq_len: int = 512) -> float:
        """Per-request KV read per decode iteration: K+V, bf16, every
        layer, GQA KV width d_model/4 (8 KV heads x head_dim d/32 at the
        Llama-7B reference shape)."""
        kv_width = self.d_model / 4.0
        return 2 * 2 * self._n_layers() * kv_width * seq_len

    def decode_time(self, batch: int, max_rank: int,
                    seq_len: int = 512, *, steps: int = 1,
                    fused: bool = True) -> float:
        """Seconds for one decode iteration (1 token for every running
        request). Weight-read bound; KV + per-request max-rank adapter
        gathers grow with batch. ``steps=k`` models a k-token fused
        decode dispatch (``decode_steps``): the per-iteration scheduling
        floor is paid once per dispatch, i.e. ITER_OVERHEAD/k per
        token-iteration."""
        weight_bytes = 2.0 * self.n_params
        kv_bytes = batch * self.kv_read_bytes(seq_len)
        lora_bytes = batch * self.adapter_read_bytes(max_rank)
        base = (weight_bytes + kv_bytes + lora_bytes) / (
            self.tp * A100_HBM * HBM_EFF_DECODE)
        t = ITER_OVERHEAD / max(1, steps) + base
        t += self.iteration_ici_time(batch, {max_rank: batch})
        if not fused:
            t += self.unfused_penalty({max_rank: batch})
        return t

    def decode_time_bucketed(self, bucket_batch: Mapping[int, int],
                             seq_len: int = 512, *, steps: int = 1,
                             fused: bool = True) -> float:
        """Rank-bucketed decode: `bucket_batch` maps bucket rank ->
        number of running requests in that bucket. Each request's adapter
        gather is at its own bucket rank (sum of per-bucket reads)
        instead of the batch max. ``steps`` / ``fused`` as in
        ``decode_time``."""
        batch = sum(bucket_batch.values())
        weight_bytes = 2.0 * self.n_params
        kv_bytes = batch * self.kv_read_bytes(seq_len)
        lora_bytes = sum(cnt * self.adapter_read_bytes(r)
                         for r, cnt in bucket_batch.items())
        base = (weight_bytes + kv_bytes + lora_bytes) / (
            self.tp * A100_HBM * HBM_EFF_DECODE)
        t = ITER_OVERHEAD / max(1, steps) + base
        t += self.iteration_ici_time(batch, dict(bucket_batch))
        if not fused:
            t += self.unfused_penalty(dict(bucket_batch))
        return t

    # -- aggregates -------------------------------------------------------
    def prefill_token_rate(self, rank: int) -> float:
        """Sustained prefill tokens/s when serving only rank-`rank` load."""
        t = self.prefill_time(self.max_batch_tokens, rank)
        return self.max_batch_tokens / t

    def decode_token_rate(self, rank: int, batch: int = 32) -> float:
        return batch / self.decode_time(batch, rank)

    def operating_point(self, rank: int, headroom: float = 0.8,
                        ref_prompt: int = 512, ref_output: int = 128
                        ) -> float:
        """Max total TPS (prompt+output tokens) under SLO for a server
        dedicated to rank-`rank` load (paper: profiled a priori). Combines
        the prefill and decode phases for the reference request shape;
        `headroom` keeps queues stable (P95 under SLO needs rho<1)."""
        t_req = (ref_prompt / self.prefill_token_rate(rank)
                 + ref_output / self.decode_token_rate(rank))
        rate = (ref_prompt + ref_output) / t_req
        return headroom * rate


def profile_operating_points(server: ServerModel,
                             ranks: Iterable[int],
                             headroom: float = 0.8):
    """The paper's a-priori profiling step (§IV-A)."""
    return {r: server.operating_point(r, headroom) for r in sorted(set(ranks))}


def co_serving_slowdown(server: ServerModel, rank_a: int, rank_b: int
                        ) -> float:
    """Fig 1 reproduction: relative prefill slowdown of rank_a requests
    when co-batched with rank_b (vs a pure rank_a batch)."""
    t_mixed = server.prefill_time(server.max_batch_tokens,
                                  max(rank_a, rank_b))
    t_pure = server.prefill_time(server.max_batch_tokens, rank_a)
    return t_mixed / t_pure


MODEL_PRESETS = {
    "llama-7b": dict(n_params=6.7e9, d_model=4096),
    "llama-30b": dict(n_params=32.5e9, d_model=6656),
    "llama-70b": dict(n_params=70e9, d_model=8192),
}


def make_server(model: str = "llama-7b", tp: int = 4, **kw) -> ServerModel:
    preset = dict(MODEL_PRESETS[model])
    preset.update(kw)
    return ServerModel(tp=tp, **preset)
