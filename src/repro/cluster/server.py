"""Iteration-level simulated LLM inference server (continuous batching à
la Orca/S-LoRA): each iteration is either a prefill batch (token-budget
bound) or a decode step for all running requests.

In the default ``bank_mode="padded"`` co-batched iterations pay the cost
of the *maximum* adapter rank present — the interference mechanism the
paper analyzes (§III-A.5). ``bank_mode="bucketed"`` mirrors the
rank-bucketed bank layout of the real engine: each iteration costs the
sum of per-bucket charges (``prefill_time_bucketed`` /
``decode_time_bucketed``), eliminating the padding tax.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.request import SimRequest  # noqa: F401  (re-export)
from repro.lora.bank import rank_bucket

from .costmodel import ServerModel


def _bucket_sums(reqs, value) -> Dict[int, int]:
    """Aggregate `value(r)` per power-of-two rank bucket."""
    out: Dict[int, int] = {}
    for r in reqs:
        b = rank_bucket(max(1, r.rank))
        out[b] = out.get(b, 0) + value(r)
    return out


class SimServer:
    """State machine advanced by the cluster simulator's event loop."""

    def __init__(self, server_id: int, model: ServerModel,
                 bank_mode: str = "padded", decode_block: int = 1,
                 tracer=None):
        self.sid = server_id
        self.model = model
        self.bank_mode = bank_mode
        # mirrors ServingEngine(decode_block=): decode iterations are
        # dispatched k at a time, amortizing the per-dispatch floor
        self.decode_block = decode_block
        # obs.Tracer: iteration spans carry the already-charged cost as
        # attrs["predicted"] so the drift meter never re-runs the model
        # in the sim hot loop (sim drift is exactly 0 by construction)
        self.tracer = tracer
        self._track = f"server:{server_id}"
        # staged decode span: contiguous same-batch decode iterations
        # coalesce into one span ([start, end, predicted, batch, iters])
        # — mirrors the engine's decode_steps(k) emitting iters=k, and
        # keeps tracing cost off the per-iteration hot path
        self._dec_span: Optional[list] = None
        self.waiting: List[SimRequest] = []
        self.running: List[SimRequest] = []
        self.finished: List[SimRequest] = []   # completion feed; the
        # event loop drains this into telemetry/SLO trackers
        self.busy_until: float = 0.0
        self.iterations = 0
        self.prefill_tokens = 0
        self.busy_time = 0.0

    # -- iteration costs (bank-layout aware) ------------------------------
    def _remote_surcharge(self, reqs: List[SimRequest], now: float
                          ) -> float:
        """GDR remote-read tax: requests whose adapter still lives on a
        peer (local warm copy lands at ``remote_until``) stream weights
        over the fabric each iteration."""
        return sum(r.remote_penalty for r in reqs
                   if now < r.remote_until)

    def _prefill_cost(self, batch: List[SimRequest], tokens: int,
                      now: float = 0.0) -> float:
        pen = self._remote_surcharge(batch, now)
        if self.bank_mode == "bucketed":
            return pen + self.model.prefill_time_bucketed(
                _bucket_sums(batch, lambda r: r.prompt_len))
        return pen + self.model.prefill_time(tokens,
                                             max(r.rank for r in batch))

    def _decode_cost(self, running: List[SimRequest],
                     now: float = 0.0) -> float:
        pen = self._remote_surcharge(running, now)
        if self.bank_mode == "bucketed":
            return pen + self.model.decode_time_bucketed(
                _bucket_sums(running, lambda r: 1),
                steps=self.decode_block)
        return pen + self.model.decode_time(len(running),
                                            max(r.rank for r in running),
                                            steps=self.decode_block)

    # -- load introspection (used by Toppings routing) --------------------
    def estimated_work(self, now: float) -> float:
        """Seconds of outstanding work: queued prefills + remaining decode."""
        w = max(0.0, self.busy_until - now)
        for r in self.waiting:
            w += self._prefill_cost([r], r.prompt_len, now)
        if self.running:
            remaining = max((r.output_len - r.decoded) for r in self.running)
            w += remaining * self._decode_cost(self.running, now) / \
                max(1, len(self.running))
        return w

    def enqueue(self, req: SimRequest) -> None:
        self.waiting.append(req)

    def has_work(self, now: float) -> bool:
        return bool(self.running) or any(r.ready <= now for r in self.waiting)

    def next_event_time(self, now: float) -> Optional[float]:
        if self.busy_until > now:
            return self.busy_until
        if self.running:
            return now
        ready = [r.ready for r in self.waiting]
        if not ready:
            return None
        t = min(ready)
        return max(t, now)

    def flush_spans(self) -> None:
        """Emit the staged decode span (a run of contiguous same-batch
        decode iterations coalesced into one span with ``iters=N`` —
        the same shape the engine's ``decode_steps(k)`` emits)."""
        st = self._dec_span
        if st is None or self.tracer is None:
            return
        self._dec_span = None
        self.tracer.record(
            "decode", st[0], st[1], cat="iteration", track=self._track,
            attrs={"predicted": st[2], "batch": st[3],
                   "steps": self.decode_block, "iters": st[4],
                   "bank_mode": self.bank_mode})

    def step(self, now: float) -> float:
        """Run one iteration starting at `now`; returns its finish time.
        Prefill-prioritized (matches S-LoRA's scheduler)."""
        ready = [r for r in self.waiting if r.ready <= now]
        if ready and len(self.running) < self.model.max_decode_batch:
            batch: List[SimRequest] = []
            tokens = 0
            for r in sorted(ready, key=lambda r: r.ready):
                if tokens + r.prompt_len > self.model.max_batch_tokens \
                        and batch:
                    break
                if len(self.running) + len(batch) >= \
                        self.model.max_decode_batch:
                    break
                batch.append(r)
                tokens += r.prompt_len
            if batch:
                t_iter = self._prefill_cost(batch, tokens, now)
                end = now + t_iter
                for r in batch:
                    self.waiting.remove(r)
                    r.prefill_start = now
                    r.prefill_done = end
                    r.decoded = 1        # first token out of prefill
                    if r.output_len <= 1:
                        r.finish = end
                        self.finished.append(r)
                    else:
                        self.running.append(r)
                self.iterations += 1
                self.prefill_tokens += tokens
                self.busy_time += t_iter
                self.busy_until = end
                if self.tracer is not None:
                    self.flush_spans()
                    self.tracer.record(
                        "prefill", now, end, cat="iteration",
                        track=self._track,
                        attrs={"predicted": t_iter, "tokens": tokens,
                               "batch": len(batch),
                               "bank_mode": self.bank_mode})
                return end
        if self.running:
            t_iter = self._decode_cost(self.running, now)
            end = now + t_iter
            if self.tracer is not None:
                # stage rather than record: back-to-back decode
                # iterations at the same batch size extend the staged
                # span instead of paying the full record cost per iter
                st = self._dec_span
                if st is not None and st[3] == len(self.running) \
                        and now - st[1] <= 1e-12:
                    st[1] = end
                    st[2] += t_iter
                    st[4] += 1
                else:
                    if st is not None:
                        self.flush_spans()
                    self._dec_span = [now, end, t_iter,
                                      len(self.running), 1]
            done = []
            for r in self.running:
                r.decoded += 1
                if r.decoded >= r.output_len:
                    r.finish = end
                    done.append(r)
            for r in done:
                self.running.remove(r)
            self.finished.extend(done)
            self.iterations += 1
            self.busy_time += t_iter
            self.busy_until = end
            return end
        # nothing ready: idle until next request becomes ready
        nxt = self.next_event_time(now)
        return nxt if nxt is not None else now
