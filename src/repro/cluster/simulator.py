"""Discrete-event cluster simulator: trace in, per-request TTFT/TBT out.

Wires together the LORASERVE orchestrator (placement policy + routing
table + tiered adapter store + demand estimator) with a pool of
iteration-level SimServers, advancing time with a simple event loop.
Rebalancing timesteps fire every `rebalance_period` seconds for dynamic
policies (paper Fig 11 step 6-7).

Adapter movement is asynchronous: a miss starts a transfer through the
``AdapterStore`` that occupies link bandwidth until a "fetch" event
completes it. ``access_mode="migrate"`` blocks the request until the
copy lands (``ready = eta``); ``"remote-read"`` starts serving
immediately from a peer's copy over GDR, paying a per-iteration penalty
until the background warm fetch finishes. ``prefetch=True`` warms
newly-placed copies at each rebalance instead of migrating lazily.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

from repro.core.baselines import POLICIES
from repro.core.demand import DemandEstimator
from repro.core.pool import AdapterStore
from repro.core.routing import RoutingTable
from repro.core.types import AdapterInfo, PlacementContext

from .costmodel import ServerModel, profile_operating_points
from .network import NetworkModel
from .server import SimRequest, SimServer


@dataclasses.dataclass
class SimResult:
    requests: List[SimRequest]
    fetches: int
    fetch_bytes: int
    max_adapters_per_server: int
    total_adapter_bytes: int
    server_busy: List[float]
    rebalances: int
    timed_out: int
    per_server_p95_ttft: List[float]
    warmup: float = 0.0     # requests arriving before this are excluded
    # adapter data-plane telemetry
    remote_reads: int = 0        # misses served via peer GDR reads
    prefetches: int = 0          # rebalance-driven proactive warms
    coalesced_fetches: int = 0   # duplicate fetches joined in flight

    def _eligible(self):
        return [r for r in self.requests if r.arrival >= self.warmup]

    def _ttfts(self):
        return sorted(r.ttft for r in self._eligible()
                      if r.prefill_done >= 0)

    def p95_ttft(self) -> float:
        t = self._ttfts()
        return t[int(0.95 * (len(t) - 1))] if t else float("inf")

    def p50_ttft(self) -> float:
        t = self._ttfts()
        return t[len(t) // 2] if t else float("inf")

    def mean_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible()
              if r.finish >= 0 and r.tbt > 0]
        return sum(ts) / len(ts) if ts else 0.0

    def p95_tbt(self) -> float:
        ts = sorted(r.tbt for r in self._eligible()
                    if r.finish >= 0 and r.tbt > 0)
        return ts[int(0.95 * (len(ts) - 1))] if ts else 0.0

    def completed(self) -> int:
        return sum(1 for r in self.requests if r.finish >= 0)

    def meets_slo(self, slo_ttft: float) -> bool:
        return self.timed_out == 0 and self.p95_ttft() <= slo_ttft


class ClusterSimulator:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 policy: str = "loraserve",
                 server_model: Optional[ServerModel] = None,
                 rebalance_period: float = 15.0,
                 timeout: float = 120.0,
                 warmup: float = 0.0,
                 seed: int = 0,
                 bank_mode: str = "padded",
                 access_mode: str = "migrate",
                 prefetch: bool = False,
                 network: Optional[NetworkModel] = None):
        if access_mode not in ("migrate", "remote-read"):
            raise ValueError(f"unknown access_mode {access_mode!r}")
        self.warmup = warmup
        self.bank_mode = bank_mode
        self.access_mode = access_mode
        self.prefetch = prefetch
        self.n = n_servers
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.model = server_model or ServerModel()
        self.policy = POLICIES[policy]() if isinstance(policy, str) \
            else policy
        self.network = network or NetworkModel()
        self.rebalance_period = rebalance_period
        self.timeout = timeout
        self.seed = seed
        ranks = {a.rank for a in adapters}
        self.operating_points = profile_operating_points(self.model, ranks)

    def run(self, trace: List[SimRequest]) -> SimResult:
        servers = [SimServer(i, self.model, bank_mode=self.bank_mode)
                   for i in range(self.n)]
        demand = DemandEstimator()
        # initial placement from uniform demand prior
        ctx = PlacementContext(
            n_servers=self.n, adapters=self.adapters,
            demand_tps={a.adapter_id: 1.0 for a in self.adapters},
            operating_points=self.operating_points)
        placement = self.policy.place(ctx)
        router = RoutingTable(placement, seed=self.seed)
        pool = AdapterStore(self.n, self.adapters, self.network)
        pool.seed(placement)
        max_adapters = pool.max_adapters_per_server()
        total_bytes = pool.total_bytes()

        trace = sorted(trace, key=lambda r: r.arrival)
        window_tokens: Dict[str, float] = {}
        next_rebalance = self.rebalance_period
        rebalances = 0
        timed_out = 0

        # event heap entries: (time, seq, kind, payload)
        heap: list = []
        seq = 0
        for r in trace:
            heapq.heappush(heap, (r.arrival, seq, "arrival", r))
            seq += 1
        if self.policy.dynamic:
            heapq.heappush(heap, (next_rebalance, seq, "rebalance", None))
            seq += 1

        def schedule_server(s: SimServer, now: float):
            nonlocal seq
            t = s.next_event_time(now)
            if t is not None:
                heapq.heappush(heap, (max(t, now), seq, "server", s.sid))
                seq += 1

        def push_fetch(eta: float):
            nonlocal seq
            heapq.heappush(heap, (eta, seq, "fetch", None))
            seq += 1

        now = 0.0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == "arrival":
                req: SimRequest = payload
                if self.policy.replicate_all:
                    sid = min(range(self.n),
                              key=lambda i: servers[i].estimated_work(now))
                    router.request_counts[req.adapter_id] = \
                        router.request_counts.get(req.adapter_id, 0) + 1
                    req.ready = now
                    req.fetch_latency = 0.0
                else:
                    sid, entry = router.route_detailed(
                        req.adapter_id,
                        tokens=req.prompt_len + req.output_len)
                    plan = pool.plan_access(
                        sid, req.adapter_id, now=now,
                        access_mode=self.access_mode,
                        preferred_peers=[s for s, _ in entry])
                    req.apply_fetch_plan(plan, now)
                    if not plan.hit:
                        push_fetch(plan.eta)
                req.server = sid
                req.rank = self.meta[req.adapter_id].rank
                servers[sid].enqueue(req)
                window_tokens[req.adapter_id] = \
                    window_tokens.get(req.adapter_id, 0.0) + \
                    req.prompt_len + req.output_len
                schedule_server(servers[sid], now)
            elif kind == "fetch":
                pool.poll(now)
            elif kind == "server":
                s = servers[payload]
                if s.busy_until > now + 1e-12:
                    heapq.heappush(heap, (s.busy_until, seq, "server", s.sid))
                    seq += 1
                    continue
                # drop timed-out waiting requests
                for r in list(s.waiting):
                    if now - r.arrival > self.timeout:
                        s.waiting.remove(r)
                        timed_out += 1
                if s.has_work(now):
                    end = s.step(now)
                    if end > now or s.waiting or s.running:
                        heapq.heappush(heap, (end, seq, "server", s.sid))
                        seq += 1
                else:
                    schedule_server(s, now + 1e-9) if s.waiting else None
            elif kind == "rebalance":
                rebalances += 1
                for aid in self.meta:
                    tps = window_tokens.get(aid, 0.0) / self.rebalance_period
                    demand.observe(aid, tps)
                window_tokens = {}
                ctx = PlacementContext(
                    n_servers=self.n, adapters=self.adapters,
                    demand_tps=demand.demands(list(self.meta)),
                    operating_points=self.operating_points,
                    prev_placement=placement)
                placement = self.policy.place(ctx)
                router.update(placement)
                for p in pool.apply_placement(placement, now=now,
                                              prefetch=self.prefetch):
                    push_fetch(p.eta)
                max_adapters = max(max_adapters,
                                   pool.max_adapters_per_server())
                if heap:   # only keep rebalancing while work remains
                    heapq.heappush(
                        heap, (now + self.rebalance_period, seq,
                               "rebalance", None))
                    seq += 1

        if self.policy.replicate_all:
            max_adapters = len(self.adapters)
            total_bytes = sum(a.nbytes for a in self.adapters) * self.n
        else:
            max_adapters = max(max_adapters, pool.max_adapters_per_server())
            total_bytes = max(total_bytes, pool.total_bytes())

        per_server = []
        for s in servers:
            ts = sorted(r.ttft for r in trace
                        if r.server == s.sid and r.prefill_done >= 0)
            per_server.append(ts[int(0.95 * (len(ts) - 1))] if ts else 0.0)
        return SimResult(
            requests=trace,
            fetches=pool.fetches,
            fetch_bytes=pool.fetch_bytes,
            max_adapters_per_server=max_adapters,
            total_adapter_bytes=total_bytes,
            server_busy=[s.busy_time for s in servers],
            rebalances=rebalances,
            timed_out=timed_out,
            per_server_p95_ttft=per_server,
            warmup=self.warmup,
            remote_reads=pool.remote_reads,
            prefetches=pool.prefetches,
            coalesced_fetches=pool.coalesced,
        )


def max_rps_under_slo(make_trace, n_servers: int, adapters, policy: str,
                      slo_ttft: float = 10.0, rps_grid=None, **sim_kw):
    """Paper's 'throughput under SLO' metric: max RPS whose P95 TTFT
    meets the SLO. `make_trace(rps)` builds the trace."""
    best = 0.0
    for rps in (rps_grid or [4, 8, 12, 16, 20, 24, 28, 32, 36, 40]):
        sim = ClusterSimulator(n_servers, adapters, policy=policy, **sim_kw)
        res = sim.run(make_trace(rps))
        if res.meets_slo(slo_ttft):
            best = rps
        else:
            break
    return best


def min_servers_under_slo(make_trace, adapters, policy: str, rps: float,
                          slo_ttft: float = 10.0, max_servers: int = 16,
                          **sim_kw):
    """Paper's GPU-savings metric: smallest cluster meeting the SLO."""
    for n in range(1, max_servers + 1):
        sim = ClusterSimulator(n, adapters, policy=policy, **sim_kw)
        res = sim.run(make_trace(rps))
        if res.meets_slo(slo_ttft):
            return n
    return max_servers + 1
