"""Discrete-event cluster simulator: trace in, per-request TTFT/TBT out.

Wires together the LORASERVE orchestrator (placement policy + routing
table + tiered adapter store + demand estimator) with a pool of
iteration-level SimServers, advancing time with a simple event loop.
Rebalancing timesteps fire every `rebalance_period` seconds for dynamic
policies (paper Fig 11 step 6-7).

Adapter movement is asynchronous: a miss starts a transfer through the
``AdapterStore`` that occupies link bandwidth until a "fetch" event
completes it. ``access_mode="migrate"`` blocks the request until the
copy lands (``ready = eta``); ``"remote-read"`` starts serving
immediately from a peer's copy over GDR, paying a per-iteration penalty
until the background warm fetch finishes. ``prefetch=True`` warms
newly-placed copies at each rebalance instead of migrating lazily.

With a ``ClusterController`` attached the fleet itself becomes dynamic:
"ctick" events on the event clock feed windowed telemetry into the
drift detector and SLO tracker, and the returned actions provision new
``SimServer``s (after ``provision_delay``), drain servers (placement
re-solved without them, holdings migrated out through the store, no new
routes), and retire emptied ones. ``SimResult.gpu_seconds`` bills each
server from provisioning to retirement (or end of run) — the paper's
fewer-GPUs-under-SLO metric.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Set

from repro.core.baselines import POLICIES
from repro.core.demand import DemandEstimator
from repro.core.pool import AdapterStore, runtime_checks_enabled
from repro.core.routing import RoutingTable
from repro.core.types import AdapterInfo, PlacementContext
from repro.faults.plan import (KIND_CRASH, KIND_LINK_DEGRADE,
                               KIND_LINK_DOWN, KIND_LINK_UP,
                               KIND_RESTORE, KIND_STALL_FETCH)
from repro.faults.recovery import (RecoveryRecord, make_continuation,
                                   merge_continuation, remaining_tokens)

from .costmodel import ServerModel, profile_operating_points
from .network import NetworkModel
from .server import SimRequest, SimServer


@dataclasses.dataclass
class SimResult:
    requests: List[SimRequest]
    fetches: int
    fetch_bytes: int
    max_adapters_per_server: int
    total_adapter_bytes: int
    server_busy: List[float]
    rebalances: int
    timed_out: int
    per_server_p95_ttft: List[float]
    warmup: float = 0.0     # requests arriving before this are excluded
    # adapter data-plane telemetry
    remote_reads: int = 0        # misses served via peer GDR reads
    prefetches: int = 0          # rebalance-driven proactive warms
    coalesced_fetches: int = 0   # duplicate fetches joined in flight
    # control-plane telemetry (controller runs only)
    scale_ups: int = 0
    drains: int = 0
    retires: int = 0
    controller_rebalances: int = 0   # out-of-band (drift/SLO) rebalances
    gpu_seconds: float = 0.0         # sum over servers of billed time
    final_servers: int = 0           # active fleet size at end of run
    drift_events: List = dataclasses.field(default_factory=list)
    actions: List = dataclasses.field(default_factory=list)
    # observability (tracer-attached runs only): per-phase modeled vs
    # measured iteration error — exactly 0 on this substrate (sim time
    # IS the model; nonzero means the span plumbing broke)
    cost_drift: dict = dataclasses.field(default_factory=dict)
    trace_spans: int = 0
    flight_dumps: int = 0
    # chaos plane (fault_plan runs only)
    server_failures: int = 0
    recoveries: int = 0
    redispatched: int = 0            # stranded requests re-issued
    fetch_retries: int = 0
    fetch_timeouts: int = 0
    breaker_opens: int = 0
    recovery_records: List = dataclasses.field(default_factory=list)

    def _eligible(self):
        return [r for r in self.requests if r.arrival >= self.warmup]

    def _ttfts(self):
        return sorted(r.ttft for r in self._eligible()
                      if r.prefill_done >= 0)

    def p95_ttft(self) -> float:
        t = self._ttfts()
        return t[int(0.95 * (len(t) - 1))] if t else float("inf")

    def p50_ttft(self) -> float:
        t = self._ttfts()
        return t[len(t) // 2] if t else float("inf")

    def mean_tbt(self) -> float:
        ts = [r.tbt for r in self._eligible()
              if r.finish >= 0 and r.tbt > 0]
        return sum(ts) / len(ts) if ts else 0.0

    def p95_tbt(self) -> float:
        ts = sorted(r.tbt for r in self._eligible()
                    if r.finish >= 0 and r.tbt > 0)
        return ts[int(0.95 * (len(ts) - 1))] if ts else 0.0

    def completed(self) -> int:
        return sum(1 for r in self.requests if r.finish >= 0)

    def meets_slo(self, slo_ttft: float) -> bool:
        return self.timed_out == 0 and self.p95_ttft() <= slo_ttft

    def slo_attainment(self, slo_ttft: float) -> float:
        """Fraction of eligible requests finishing prefill within the
        TTFT target; dropped/unfinished requests count as misses."""
        elig = self._eligible()
        if not elig:
            return 1.0
        ok = sum(1 for r in elig
                 if r.prefill_done >= 0 and r.ttft <= slo_ttft)
        return ok / len(elig)

    def gpu_hours(self) -> float:
        return self.gpu_seconds / 3600.0


class ClusterSimulator:
    def __init__(self, n_servers: int, adapters: List[AdapterInfo],
                 policy: str = "loraserve",
                 server_model: Optional[ServerModel] = None,
                 rebalance_period: float = 15.0,
                 timeout: float = 120.0,
                 warmup: float = 0.0,
                 seed: int = 0,
                 bank_mode: str = "padded",
                 decode_block: int = 1,
                 access_mode: str = "migrate",
                 prefetch: bool = False,
                 network: Optional[NetworkModel] = None,
                 controller=None,
                 provision_delay: float = 0.0,
                 tracer=None, flight_recorder=None,
                 fault_plan=None,
                 detector_window: float = 0.5,
                 durable_ssd: bool = False,
                 retry_policy=None):
        if access_mode not in ("migrate", "remote-read"):
            raise ValueError(f"unknown access_mode {access_mode!r}")
        self.warmup = warmup
        # closed-loop control plane (repro.controlplane): fed telemetry
        # on the event clock, may grow/drain/retire the fleet mid-run
        self.controller = controller
        self.provision_delay = provision_delay
        self.bank_mode = bank_mode
        self.decode_block = decode_block
        self.access_mode = access_mode
        self.prefetch = prefetch
        self.n = n_servers
        self.adapters = adapters
        self.meta = {a.adapter_id: a for a in adapters}
        self.model = server_model or ServerModel()
        self.policy = POLICIES[policy]() if isinstance(policy, str) \
            else policy
        self.network = network or NetworkModel()
        self.rebalance_period = rebalance_period
        self.timeout = timeout
        self.seed = seed
        ranks = {a.rank for a in adapters}
        self.operating_points = profile_operating_points(self.model, ranks)
        # observability: span tracing on the event clock, per-phase
        # modeled-vs-measured drift, and flight-recorder dumps on
        # controller scale decisions / timeouts
        # chaos plane: seeded fault schedule on the event clock; crashes
        # are detected after one heartbeat window (the wall-clock facade
        # runs a real FailureDetector — here detection latency is
        # modeled directly as `detector_window` seconds of silence)
        self.fault_plan = fault_plan
        self.detector_window = detector_window
        self.durable_ssd = durable_ssd
        self.retry_policy = retry_policy
        self.tracer = tracer
        self.flight_recorder = flight_recorder
        self.cost_drift = None
        if tracer is not None:
            from repro.obs import CostModelDrift
            self.cost_drift = CostModelDrift(self.model)
            tracer.add_listener(self.cost_drift.observe)
            if flight_recorder is not None:
                tracer.add_listener(flight_recorder.observe)

    def run(self, trace: List[SimRequest]) -> SimResult:
        tracer = self.tracer
        recorder = self.flight_recorder
        record_spans = None
        clock_adv = (getattr(tracer.clock, "advance", None)
                     if tracer is not None else None)
        if tracer is not None:
            from repro.obs import record_request_spans
            record_spans = record_request_spans
        servers = [SimServer(i, self.model, bank_mode=self.bank_mode,
                             decode_block=self.decode_block,
                             tracer=tracer)
                   for i in range(self.n)]
        ctrl = self.controller
        if ctrl is not None:   # lazy: keeps controller-less sims light
            from repro.controlplane import ClusterState
            # hand the controller the paper's capacity model so its
            # drain gate can run Algorithm-1 demand math
            if ctrl.operating_points is None:
                ctrl.operating_points = self.operating_points
            if not ctrl.adapter_ranks:
                ctrl.adapter_ranks = {a.adapter_id: a.rank
                                      for a in self.adapters}
        active: Set[int] = set(range(self.n))      # serving servers
        draining: Set[int] = set()                 # emptying, no routes
        provisioned_at: Dict[int, float] = {i: 0.0 for i in range(self.n)}
        retired_at: Dict[int, float] = {}
        prev_busy: Dict[int, float] = {}    # ctick utilization baseline
        demand = DemandEstimator()
        # initial placement from uniform demand prior
        ctx = PlacementContext(
            n_servers=self.n, adapters=self.adapters,
            demand_tps={a.adapter_id: 1.0 for a in self.adapters},
            operating_points=self.operating_points)
        placement = self.policy.place(ctx)
        router = RoutingTable(placement, seed=self.seed)
        pool = AdapterStore(self.n, self.adapters, self.network,
                            retry=self.retry_policy,
                            durable_ssd=self.durable_ssd)
        pool.tracer = tracer
        pool.seed(placement)
        max_adapters = pool.max_adapters_per_server()
        total_bytes = pool.total_bytes()

        trace = sorted(trace, key=lambda r: r.arrival)
        window_tokens: Dict[str, float] = {}
        rebalances = 0
        ctrl_rebalances = 0
        scale_ups = drains = retires = 0
        timed_out = 0
        last_rb = 0.0
        # chaos plane: crashed servers freeze (fail-stop — stranded work
        # neither runs nor times out) until detection one heartbeat
        # window later; recovery re-places adapters and re-dispatches
        # stranded requests as same-req_id continuations
        failed: Set[int] = set()            # crashed (detected or not)
        dead_detected: Set[int] = set()     # recovery already ran
        failed_at: Dict[int, float] = {}
        cont_orig: Dict[int, SimRequest] = {}   # req_id -> original
        server_failures = recoveries = redispatched_n = 0
        recovery_records: List = []

        # event heap entries: (time, seq, kind, payload)
        heap: list = []
        seq = 0
        remaining_arrivals = len(trace)
        for r in trace:
            heapq.heappush(heap, (r.arrival, seq, "arrival", r))
            seq += 1
        if self.policy.dynamic:
            heapq.heappush(heap, (self.rebalance_period, seq,
                                  "rebalance", None))
            seq += 1
        if ctrl is not None:
            heapq.heappush(heap, (ctrl.config.tick_period, seq,
                                  "ctick", None))
            seq += 1
        if self.fault_plan is not None:
            self.fault_plan.reset()
            for ev in self.fault_plan.events:
                heapq.heappush(heap, (ev.time, seq, "fault", ev))
                seq += 1

        def schedule_server(s: SimServer, now: float):
            nonlocal seq
            t = s.next_event_time(now)
            if t is not None:
                heapq.heappush(heap, (max(t, now), seq, "server", s.sid))
                seq += 1

        def push(t: float, kind: str, payload=None):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        def push_fetch(eta: float):
            push(eta, "fetch")

        def work_remains() -> bool:
            """Whether recurring events (rebalance/ctick) should keep
            firing: arrivals still due, requests in flight, or adapter
            transfers on the wire. (`if heap:` is not enough once two
            recurring events coexist — they would sustain each other
            forever.)"""
            return (remaining_arrivals > 0
                    or any(s.waiting or s.running for s in servers)
                    or pool.inflight_count() > 0)

        def feed_completions():
            """Drain per-server completion feeds into the controller
            (stamped at the request's own finish time) and the tracer
            (canonical per-request span trees — the same helper the
            engine facade uses, so span names match across substrates).

            Also the event clock's pace point: spans carry explicit
            timestamps, so the tracer clock only needs to track event
            time here — advancing it on every heap pop costs ~10% of
            the whole sim (most pops are busy-wait re-pushes)."""
            if clock_adv is not None:
                clock_adv(now)
            for s in servers:
                if not s.finished:
                    continue
                for r in s.finished:
                    # a finished continuation folds back into the
                    # original trace object (same req_id, full output)
                    orig = cont_orig.pop(r.req_id, None)
                    if orig is not None and orig is not r:
                        merge_continuation(orig, r)
                        r = orig
                    if ctrl is not None:
                        ctrl.observe_completion(r, r.finish)
                    if record_spans is not None:
                        record_spans(tracer, r)
                s.finished.clear()

        def do_rebalance(now: float):
            """Close the demand window and re-solve placement over the
            currently-active fleet (paper Fig 11 steps 6-7, on whatever
            servers the control plane has left us). A second call at the
            same instant (controller rebalance coinciding with the
            periodic one, or rebalance+drain in one tick) re-solves but
            must not feed a spurious zero-demand sample."""
            nonlocal last_rb, max_adapters, placement
            period = now - last_rb
            if period > 1e-9:
                for aid in self.meta:
                    demand.observe(aid,
                                   window_tokens.get(aid, 0.0) / period)
                window_tokens.clear()
                last_rb = now
            placeable = sorted(active)
            ctx = PlacementContext(
                n_servers=len(placeable), adapters=self.adapters,
                demand_tps=demand.demands(list(self.meta)),
                operating_points=self.operating_points,
                prev_placement=placement, server_ids=placeable)
            placement = self.policy.place(ctx)
            router.update(placement)
            for p in pool.apply_placement(placement, now=now,
                                          prefetch=self.prefetch):
                push_fetch(p.eta)
            max_adapters = max(max_adapters,
                               pool.max_adapters_per_server())

        def drained_servers(now: float) -> List[int]:
            """Draining servers that are now empty: no queued/running
            work, no HBM copies, not feeding or receiving transfers."""
            out = []
            for sid in sorted(draining):
                s = servers[sid]
                if s.waiting or s.running:
                    continue
                if pool.server_adapter_count(sid) or \
                        pool.inflight_from(sid) or pool.inflight_to(sid):
                    continue
                out.append(sid)
            return out

        def execute(actions, now: float):
            nonlocal ctrl_rebalances, scale_ups, drains, retires
            if recorder is not None:
                inputs = getattr(ctrl, "last_inputs", {})
                for a in actions:
                    if a.kind in ("scale-up", "drain"):
                        recorder.dump(a.kind, now,
                                      {**dataclasses.asdict(a), **inputs})
            for a in actions:
                if a.kind == "rebalance":
                    ctrl_rebalances += 1
                    do_rebalance(now)
                elif a.kind == "scale-up":
                    scale_ups += 1
                    # billed from the request; serving from provision
                    push(now + self.provision_delay, "provision", now)
                elif a.kind == "drain":
                    drains += 1
                    active.discard(a.server)
                    draining.add(a.server)
                    do_rebalance(now)       # re-place without the victim
                    for p in pool.drain_server(a.server, now):
                        push_fetch(p.eta)
                elif a.kind == "retire":
                    retires += 1
                    pool.retire_server(a.server)
                    router.block_server(a.server)
                    draining.discard(a.server)
                    retired_at[a.server] = now

        def dispatch(req: SimRequest, now: float) -> int:
            """Route + enqueue one request (fresh arrival or recovery
            continuation) on the currently-active fleet."""
            if self.policy.replicate_all:
                sid = min(sorted(active),
                          key=lambda i: servers[i].estimated_work(now))
                router.request_counts[req.adapter_id] = \
                    router.request_counts.get(req.adapter_id, 0) + 1
                req.ready = now
                req.fetch_latency = 0.0
            else:
                sid, entry = router.route_detailed(
                    req.adapter_id,
                    tokens=req.prompt_len + req.output_len)
                plan = pool.plan_access(
                    sid, req.adapter_id, now=now,
                    access_mode=self.access_mode,
                    preferred_peers=[s for s, _ in entry])
                req.apply_fetch_plan(plan, now)
                if not plan.hit:
                    push_fetch(plan.eta)
            req.server = sid
            req.rank = self.meta[req.adapter_id].rank
            servers[sid].enqueue(req)
            schedule_server(servers[sid], now)
            return sid

        def redispatch(req: SimRequest, now: float) -> bool:
            """Exactly-once re-dispatch of a stranded request: issue a
            same-``req_id`` continuation for the undelivered suffix on
            a survivor; a request that already decoded every token is
            finalized in place."""
            nonlocal redispatched_n, timed_out
            orig = cont_orig.pop(req.req_id, None)
            if orig is not None and orig is not req:
                # a continuation itself stranded: fold its progress back
                # and re-continue from the original
                merge_continuation(orig, req)
                orig.finish = -1.0
                req = orig
            if remaining_tokens(req) <= 0:
                req.finish = now
                if req.prefill_done < 0:
                    req.prefill_done = now
                if ctrl is not None:
                    ctrl.observe_completion(req, now)
                return False
            cont = make_continuation(req, now)
            cont_orig[cont.req_id] = req
            dispatch(cont, now)
            redispatched_n += 1
            return True

        def recover(sid: int, now: float):
            """Detection fired one heartbeat window after the crash:
            block routing, re-place the dead server's adapters onto
            survivors (prefetch re-warms, SSD recovers last-copy loss
            when ``durable_ssd``), re-dispatch its stranded requests."""
            nonlocal recoveries
            feed_completions()
            s = servers[sid]
            stranded = list(s.waiting) + list(s.running)
            s.waiting.clear()
            s.running.clear()
            s.busy_until = 0.0
            active.discard(sid)
            draining.discard(sid)
            orphans = pool.fail_server(sid, now)
            keep_prefetch = self.prefetch
            self.prefetch = True      # recovery re-warm is never lazy
            try:
                do_rebalance(now)
            finally:
                self.prefetch = keep_prefetch
            router.block_server(sid)
            dead_detected.add(sid)
            if ctrl is not None and hasattr(ctrl, "observe_failure"):
                ctrl.observe_failure(sid, now)
            redone = 0
            for req in sorted(stranded, key=lambda r: r.req_id):
                if redispatch(req, now):
                    redone += 1
            recoveries += 1
            recovery_records.append(RecoveryRecord(
                server=sid, detected_at=now, recovered_at=now,
                redispatched=redone, orphaned_adapters=len(orphans)))
            if recorder is not None:
                recorder.dump("fault-recover", now,
                              {"server": sid, "stranded": len(stranded),
                               "redispatched": redone,
                               "orphans": len(orphans),
                               "crashed_at": failed_at.get(sid, now)})

        def apply_fault(ev, now: float):
            """One FaultPlan event on the sim's virtual clock. Crash
            semantics are fail-stop: the backend freezes immediately,
            but placement/routing only learn at detection."""
            nonlocal server_failures, seq
            sid = ev.target
            if ev.kind == KIND_CRASH:
                if not (0 <= sid < len(servers)) or sid in failed \
                        or sid in retired_at:
                    return
                failed.add(sid)
                failed_at[sid] = now
                server_failures += 1
                push(now + self.detector_window, "recover", sid)
                if recorder is not None:
                    recorder.dump("fault-crash", now, {"server": sid})
            elif ev.kind == KIND_RESTORE:
                if sid in failed and sid not in dead_detected:
                    # flapped back inside the detection window: frozen
                    # work simply resumes, no recovery ran
                    failed.discard(sid)
                    failed_at.pop(sid, None)
                    schedule_server(servers[sid], now)
                elif sid in dead_detected:
                    failed.discard(sid)
                    dead_detected.discard(sid)
                    pool.restore_server(sid)
                    router.unblock_server(sid)
                    active.add(sid)
                    do_rebalance(now)   # fold the survivor back in
                if recorder is not None:
                    recorder.dump("fault-restore", now, {"server": sid})
            elif ev.kind == KIND_LINK_DOWN:
                self.network.set_link_down(sid)
            elif ev.kind == KIND_LINK_UP:
                self.network.set_link_up(sid)
                self.network.reset_link(sid)
            elif ev.kind == KIND_LINK_DEGRADE:
                self.network.degrade_link(sid, max(1.0, ev.arg))
            elif ev.kind == KIND_STALL_FETCH:
                for (dest, aid), p in sorted(pool._inflight.items()):
                    if p.retry_at >= 0 or p.stalled:
                        continue
                    if sid >= 0 and dest != sid and p.src_server != sid:
                        continue
                    pool.stall_transfer(
                        dest, aid,
                        ev.arg if ev.arg > 0 else float("inf"))
                    t = pool.next_event_time(now)
                    if t is not None:
                        push_fetch(t)   # drive the timeout/retry path
                    break

        now = 0.0
        last_activity = 0.0
        # REPRO_CHECK_INVARIANTS=1: re-check the protocol checker's
        # store+routing invariants on a stride of events (debug-only;
        # the env gate keeps the hot loop free of the sweep otherwise).
        # The store already self-checks on every poll/start_fetch edge,
        # so the stride only paces the routing-table cross-check — a
        # full sweep per event is O(adapters x servers) and makes the
        # large sims unusably slow.
        debug_invariants = runtime_checks_enabled()
        debug_stride = 64
        n_events = 0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == "provision" and not work_remains():
                continue    # run drained while the server booted:
                #             nothing to serve, nothing to bill
            last_activity = now
            n_events += 1
            if debug_invariants and n_events % debug_stride == 0:
                pool.check_invariants(now, routing=router,
                                      raise_on_violation=True)
            if kind == "arrival":
                req: SimRequest = payload
                remaining_arrivals -= 1
                sid = dispatch(req, now)
                tokens = req.prompt_len + req.output_len
                window_tokens[req.adapter_id] = \
                    window_tokens.get(req.adapter_id, 0.0) + tokens
                if ctrl is not None:
                    ctrl.observe_arrival(req.adapter_id, sid, tokens, now)
            elif kind == "fetch":
                for p in pool.poll(now):
                    # the retry path moves landings past the ETA
                    # stamped at dispatch (a stalled attempt even
                    # quotes eta=inf to coalescing requests): now that
                    # the copy actually landed, re-stamp any request
                    # still waiting on the stale quote and wake the
                    # server, or it blocks forever on a time that
                    # never comes
                    if p.dest >= len(servers):
                        continue
                    s = servers[p.dest]
                    woke = False
                    for r in s.waiting:
                        if r.adapter_id == p.adapter_id and \
                                r.ready > now + 1e-12:
                            r.fetch_latency = max(0.0, now - r.arrival)
                            r.ready = now
                            woke = True
                    if woke:
                        schedule_server(s, now)
                # retries (timeout -> backoff -> relaunch) move the next
                # wakeup off any plan's original eta: chain the next
                # pending store event so the retry path always fires
                t = pool.next_event_time(now)
                if t is not None and t > now + 1e-12:
                    push_fetch(t)
            elif kind == "fault":
                apply_fault(payload, now)
            elif kind == "recover":
                if payload in failed and payload not in dead_detected:
                    recover(payload, now)
            elif kind == "server":
                if payload in failed:
                    continue    # fail-stop freeze: nothing runs, and
                    #             stranded work does not time out
                s = servers[payload]
                if s.busy_until > now + 1e-12:
                    push(s.busy_until, "server", s.sid)
                    continue
                # drop timed-out waiting requests
                for r in list(s.waiting):
                    if now - r.arrival > self.timeout:
                        s.waiting.remove(r)
                        timed_out += 1
                        if ctrl is not None:
                            ctrl.observe_timeout(now)
                        if recorder is not None:
                            recorder.dump(
                                "timeout", now,
                                {"req_id": r.req_id,
                                 "adapter_id": r.adapter_id,
                                 "server": r.server,
                                 "arrival": r.arrival})
                if s.has_work(now):
                    end = s.step(now)
                    feed_completions()
                    if end > now or s.waiting or s.running:
                        push(end, "server", s.sid)
                else:
                    if s.waiting:
                        schedule_server(s, now + 1e-9)
            elif kind == "rebalance":
                rebalances += 1
                do_rebalance(now)
                # reschedule only while *request* work remains. The
                # work_remains() predicate also counts in-flight
                # transfers — including the ones do_rebalance itself
                # just launched — so gating on it lets a near-zero
                # demand window ping-pong placement forever after the
                # trace drains (each rebalance's own transfers keep the
                # next one alive). Transfers complete through the fetch
                # event chain regardless.
                if remaining_arrivals > 0 or \
                        any(s.waiting or s.running for s in servers):
                    push(now + self.rebalance_period, "rebalance")
            elif kind == "ctick":
                feed_completions()
                # queue depth = *waiting* requests only: with continuous
                # batching a healthy server legitimately runs a full
                # decode batch; backlog is what gates drains
                period = ctrl.config.tick_period
                util = {}
                for s in servers:
                    if s.sid in retired_at:
                        continue
                    prev = prev_busy.get(s.sid, 0.0)
                    util[s.sid] = min(1.0, max(
                        0.0, (s.busy_time - prev) / period))
                    prev_busy[s.sid] = s.busy_time
                state = ClusterState(
                    now=now, active=sorted(active),
                    draining=sorted(draining),
                    drained=drained_servers(now),
                    queue_depth={s.sid: float(len(s.waiting))
                                 for s in servers
                                 if s.sid not in retired_at},
                    utilization=util)
                execute(ctrl.tick(state), now)
                if work_remains() or draining:
                    push(now + ctrl.config.tick_period, "ctick")
            elif kind == "provision":
                sid = pool.add_server()
                servers.append(SimServer(sid, self.model,
                                         bank_mode=self.bank_mode,
                                         decode_block=self.decode_block,
                                         tracer=tracer))
                active.add(sid)
                provisioned_at[sid] = payload    # billed from request
                do_rebalance(now)   # fold the new server into placement
        for s in servers:
            s.flush_spans()          # staged (coalesced) decode spans
        feed_completions()           # trailing finishes, if any

        if self.policy.replicate_all:
            max_adapters = len(self.adapters)
            total_bytes = sum(a.nbytes for a in self.adapters) * self.n
        else:
            max_adapters = max(max_adapters, pool.max_adapters_per_server())
            total_bytes = max(total_bytes, pool.total_bytes())

        end_time = last_activity
        gpu_seconds = sum(retired_at.get(sid, end_time) - t0
                          for sid, t0 in provisioned_at.items())
        per_server = []
        for s in servers:
            ts = sorted(r.ttft for r in trace
                        if r.server == s.sid and r.prefill_done >= 0)
            per_server.append(ts[int(0.95 * (len(ts) - 1))] if ts else 0.0)
        return SimResult(
            requests=trace,
            fetches=pool.fetches,
            fetch_bytes=pool.fetch_bytes,
            max_adapters_per_server=max_adapters,
            total_adapter_bytes=total_bytes,
            server_busy=[s.busy_time for s in servers],
            rebalances=rebalances,
            timed_out=timed_out,
            per_server_p95_ttft=per_server,
            warmup=self.warmup,
            remote_reads=pool.remote_reads,
            prefetches=pool.prefetches,
            coalesced_fetches=pool.coalesced,
            scale_ups=scale_ups,
            drains=drains,
            retires=retires,
            controller_rebalances=ctrl_rebalances,
            gpu_seconds=gpu_seconds,
            final_servers=len(active),
            drift_events=(list(ctrl.detector.events)
                          if ctrl is not None else []),
            actions=list(ctrl.actions) if ctrl is not None else [],
            cost_drift=(self.cost_drift.summary()
                        if self.cost_drift is not None else {}),
            trace_spans=tracer.n_spans if tracer is not None else 0,
            flight_dumps=recorder.n_dumps if recorder is not None else 0,
            server_failures=server_failures,
            recoveries=recoveries,
            redispatched=redispatched_n,
            fetch_retries=pool.fetch_retries,
            fetch_timeouts=pool.fetch_timeouts,
            breaker_opens=sum(b.opens for b in pool.breakers.values()),
            recovery_records=recovery_records,
        )


def max_rps_under_slo(make_trace, n_servers: int, adapters, policy: str,
                      slo_ttft: float = 10.0, rps_grid=None, **sim_kw):
    """Paper's 'throughput under SLO' metric: max RPS whose P95 TTFT
    meets the SLO. `make_trace(rps)` builds the trace."""
    best = 0.0
    for rps in (rps_grid or [4, 8, 12, 16, 20, 24, 28, 32, 36, 40]):
        sim = ClusterSimulator(n_servers, adapters, policy=policy, **sim_kw)
        res = sim.run(make_trace(rps))
        if res.meets_slo(slo_ttft):
            best = rps
        else:
            break
    return best


def min_servers_under_slo(make_trace, adapters, policy: str, rps: float,
                          slo_ttft: float = 10.0, max_servers: int = 16,
                          **sim_kw):
    """Paper's GPU-savings metric: smallest cluster meeting the SLO."""
    for n in range(1, max_servers + 1):
        sim = ClusterSimulator(n, adapters, policy=policy, **sim_kw)
        res = sim.run(make_trace(rps))
        if res.meets_slo(slo_ttft):
            return n
    return max_servers + 1
