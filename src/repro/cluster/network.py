"""Adapter-transfer model (paper Fig 14) with live link state.

Latency of fetching a tensor from local host memory, a remote server
over GPUDirect-RDMA/InfiniBand, or local SSD. The paper's observation:
IB GDR ~ local host->GPU latency; SSD is prohibitive. The TPU
deployment mapping (DESIGN.md §3) adds an "ici" source with v5e-class
inter-host bandwidth.

Beyond the flat Fig-14 table, the model now carries *link state* for the
adapter data plane (``repro.core.pool.AdapterStore``):

* every peer-sourced transfer occupies the source server's egress link
  until its ETA; concurrent transfers on one link divide bandwidth, so
  ``plan_latency`` quotes a load-dependent figure and the store picks
  the cheapest source instead of a hardcoded one;
* ``remote_read_penalty`` prices the GDR *remote-read* access mode: a
  request served from a peer's HBM copy streams adapter weights over
  the fabric every iteration until the local copy warms. Reads overlap
  compute (``remote_read_overlap``), so only the non-hidden fraction of
  the wire time is charged — the Fig-14 "IB GDR ~ local host" economics
  that make serving-before-migrating worthwhile.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

# bytes/s bandwidth and seconds of base latency per source
_SOURCES: Dict[str, tuple] = {
    # local host memory -> GPU over PCIe4 x16
    "local_host": (25e9, 50e-6),
    # remote host: src host->GPU copy then GPUDirect RDMA over 200Gb IB
    "ib_gdr": (22e9, 180e-6),
    # local NVMe SSD (the paper found this prohibitive)
    "ssd": (1.8e9, 120e-6),
    # TPU host-to-host over ICI (deployment mapping)
    "ici": (45e9, 60e-6),
}


class NetworkModel:
    """Transfer latency + per-link contention state.

    ``fabric`` names the peer-to-peer source ("ib_gdr" for the paper's
    GPU clusters, "ici" for the TPU deployment mapping); ``contention``
    is a global slowdown on all wire time (shared spine).
    """

    def __init__(self, contention: float = 1.0, fabric: str = "ib_gdr",
                 remote_read_overlap: float = 0.6):
        if fabric not in _SOURCES:
            raise ValueError(f"unknown fabric {fabric!r}")
        self.contention = contention
        self.fabric = fabric
        self.remote_read_overlap = remote_read_overlap
        # src_server -> ETAs of transfers currently leaving that server
        self._egress: Dict[int, List[float]] = {}
        # fault state (repro.faults): downed links quote infinite latency
        # and refuse new transfers; degraded links multiply wire time
        self._link_down: Set[int] = set()
        self._link_degrade: Dict[int, float] = {}

    def sources(self):
        return sorted(_SOURCES)

    # -- flat Fig-14 latency (no link state) ----------------------------
    def transfer_latency(self, nbytes: int, source: str) -> float:
        bw, lat = _SOURCES[source]
        return lat + self.contention * nbytes / bw

    # -- fault state (injected by repro.faults) --------------------------
    def set_link_down(self, src_server: int) -> None:
        """Flap a peer's egress link down: in-flight transfers keep
        their slots (the store's retry path re-sources them), but the
        link quotes infinite latency and refuses new transfers."""
        self._link_down.add(src_server)

    def set_link_up(self, src_server: int) -> None:
        self._link_down.discard(src_server)

    def degrade_link(self, src_server: int, factor: float) -> None:
        """Multiply the link's wire time by ``factor`` (>= 1); use
        ``reset_link`` / factor 1.0 to restore full bandwidth."""
        if factor < 1.0:
            raise ValueError(f"degrade factor {factor} < 1")
        self._link_degrade[src_server] = factor

    def reset_link(self, src_server: int) -> None:
        self._link_down.discard(src_server)
        self._link_degrade.pop(src_server, None)

    def link_up(self, src_server: int) -> bool:
        return src_server not in self._link_down

    def link_factor(self, src_server: int) -> float:
        return self._link_degrade.get(src_server, 1.0)

    # -- link state ------------------------------------------------------
    def link_load(self, src_server: int, now: float = 0.0) -> int:
        """Transfers currently in flight out of ``src_server``."""
        etas = self._egress.get(src_server)
        if not etas:
            return 0
        live = [t for t in etas if t > now + 1e-12]
        self._egress[src_server] = live
        return len(live)

    def plan_latency(self, nbytes: int, source: str, now: float = 0.0,
                     src_server: Optional[int] = None) -> float:
        """Quoted latency for a transfer starting at ``now``: base wire
        time scaled by how many transfers already share the source link
        (fair-share bandwidth division)."""
        if src_server is None:
            return self.transfer_latency(nbytes, source)
        if src_server in self._link_down:
            return float("inf")
        bw, lat = _SOURCES[source]
        load = self.link_load(src_server, now)
        factor = self._link_degrade.get(src_server, 1.0)
        return lat + factor * (1 + load) * self.contention * nbytes / bw

    def begin_transfer(self, nbytes: int, source: str, now: float = 0.0,
                       src_server: Optional[int] = None
                       ) -> Tuple[float, float]:
        """Start a transfer; returns (latency, eta) and — for peer
        sources — occupies the source's egress link until the ETA."""
        if src_server is not None and src_server in self._link_down:
            raise RuntimeError(f"transfer from downed link {src_server}")
        latency = self.plan_latency(nbytes, source, now, src_server)
        eta = now + latency
        if src_server is not None:
            self._egress.setdefault(src_server, []).append(eta)
        return latency, eta

    def end_transfer(self, src_server: int, eta: float) -> None:
        """Release the link slot of a completed transfer."""
        etas = self._egress.get(src_server)
        if etas and eta in etas:
            etas.remove(eta)

    def move_transfer(self, src_server: int, old_eta: float,
                      new_eta: float) -> None:
        """Re-time an occupied link slot (a stalled transfer keeps its
        slot, so link-occupancy accounting stays exact)."""
        etas = self._egress.get(src_server)
        if etas and old_eta in etas:
            etas.remove(old_eta)
            etas.append(new_eta)

    # -- remote-read access mode ----------------------------------------
    def remote_read_penalty(self, nbytes: int,
                            source: Optional[str] = None) -> float:
        """Per-iteration surcharge for executing with adapter weights
        resident on a peer: the fabric streams the adapter's bytes each
        iteration, overlapped with compute so only the non-hidden
        fraction is charged on top of the iteration time."""
        bw, lat = _SOURCES[source or self.fabric]
        hidden = max(0.0, min(1.0, self.remote_read_overlap))
        return lat + (1.0 - hidden) * self.contention * nbytes / bw
