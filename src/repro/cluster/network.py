"""Adapter-transfer model (paper Fig 14): latency of fetching a tensor
from local host memory, a remote server over GPUDirect-RDMA/InfiniBand,
or local SSD. The paper's observation: IB GDR ~ local host->GPU latency;
SSD is prohibitive.

The TPU deployment mapping (DESIGN.md §3) adds an "ici" source with
v5e-class inter-host bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# bytes/s bandwidth and seconds of base latency per source
_SOURCES: Dict[str, tuple] = {
    # local host memory -> GPU over PCIe4 x16
    "local_host": (25e9, 50e-6),
    # remote host: src host->GPU copy then GPUDirect RDMA over 200Gb IB
    "ib_gdr": (22e9, 180e-6),
    # local NVMe SSD (the paper found this prohibitive)
    "ssd": (1.8e9, 120e-6),
    # TPU host-to-host over ICI (deployment mapping)
    "ici": (45e9, 60e-6),
}


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    contention: float = 1.0     # >1 slows all transfers (shared links)

    def transfer_latency(self, nbytes: int, source: str) -> float:
        bw, lat = _SOURCES[source]
        return lat + self.contention * nbytes / bw

    def sources(self):
        return sorted(_SOURCES)
