from .costmodel import (ServerModel, co_serving_slowdown, make_server,
                        profile_operating_points)
from .network import NetworkModel
from .server import SimRequest, SimServer
from .simulator import (ClusterSimulator, SimResult, max_rps_under_slo,
                        min_servers_under_slo)
