"""Cluster simulator + calibrated cost model.

Lazy exports (PEP 562): ``repro.cluster.network`` is a pure-Python
module the import-light ``repro.analysis`` protocol checker loads in a
bare venv; eager re-exports here would pull the numpy/jax-backed
simulator stack with it.
"""
_EXPORTS = {
    "ServerModel": "costmodel", "co_serving_slowdown": "costmodel",
    "make_server": "costmodel", "profile_operating_points": "costmodel",
    "NetworkModel": "network",
    "SimRequest": "server", "SimServer": "server",
    "ClusterSimulator": "simulator", "SimResult": "simulator",
    "max_rps_under_slo": "simulator", "min_servers_under_slo": "simulator",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    import importlib
    mod = _EXPORTS.get(name)
    if mod is not None:
        return getattr(importlib.import_module(f".{mod}", __name__), name)
    try:                         # plain submodule access (pkg.network)
        return importlib.import_module(f".{name}", __name__)
    except ImportError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
