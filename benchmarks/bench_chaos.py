"""Chaos harness — kill / flap / stall under live traffic on both
substrates, measuring what the recovery machinery actually buys.

Each scenario replays the *same* trace twice: once fault-free
(baseline) and once with a seeded ``FaultPlan``. Reported per scenario:

- **recovery time** — crash -> confirmed-dead (one detector window) and
  crash -> survivors re-placed + stranded work re-dispatched, from the
  run's ``RecoveryRecord``s;
- **SLO dip / restore** — windowed TTFT attainment bucketed by arrival
  into pre-fault / fault / post-restore windows. Loss-free recovery
  means the post window returns to the pre-fault level;
- **retried vs lost** — re-dispatched continuations, fetch retries /
  timeouts, circuit-breaker opens, and the number of requests that
  never finished (must be 0: a crash may slow requests, never eat them).

Substrates: the discrete-event ``ClusterSimulator`` (virtual clock,
fault events on the sim heap) and the ``LoRAServeCluster`` facade over
``SimBackend`` (incremental submit/poll loop, wall-style injector +
heartbeat ``FailureDetector``) — the same ``FaultPlan`` drives both.
"""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator, NetworkModel
from repro.faults import FaultPlan
from repro.serving import LoRAServeCluster, SimBackend
from repro.traces import make_adapters, synth_trace

from .common import emit

# tight enough that a crash's detection window + re-queue visibly
# dents the fault-window attainment, loose enough that the healthy
# baseline sits at ~1.0 (sim TTFTs are tens of milliseconds)
SLO_TTFT = 0.25


# ---------------------------------------------------------------------
# shared metric helpers (both substrates reduce to (arrival, ok) pairs)
# ---------------------------------------------------------------------
def _sim_pairs(res):
    """(arrival, finished, ttft) per request of a SimResult."""
    return [(r.arrival, r.finish >= 0, r.ttft if r.prefill_done >= 0
             else None) for r in res.requests]


def _facade_pairs(report):
    return [(r.arrival, r.finished, r.ttft) for r in report.results]


def _attainment(pairs, t0=0.0, t1=float("inf")):
    w = [(fin, t) for (a, fin, t) in pairs if t0 <= a < t1]
    if not w:
        return 1.0
    return sum(1 for fin, t in w
               if fin and t is not None and t <= SLO_TTFT) / len(w)


def _lost(pairs):
    return sum(1 for _, fin, _ in pairs if not fin)


def _recovery(records, t_kill):
    """(detect_s, recover_s) — crash->confirmed and crash->recovered,
    worst case over records (one kill => one record)."""
    if not records:
        return float("nan"), float("nan")
    det = max(r.detected_at - t_kill for r in records)
    rec = max(r.recovered_at - t_kill for r in records)
    return det, rec


def _windows_derived(pairs, t_kill, t_restore):
    pre = _attainment(pairs, 0.0, t_kill)
    dip = _attainment(pairs, t_kill, t_restore)
    post = _attainment(pairs, t_restore)
    return (f"slo_pre={pre:.4f};slo_fault={dip:.4f};slo_post={post:.4f};"
            f"slo_restored={int(post >= pre - 1e-9)}", pre, post)


# ---------------------------------------------------------------------
# discrete-event substrate
# ---------------------------------------------------------------------
def _sim(adapters, plan=None, window=0.5):
    # periodic rebalances + a shifting trace keep adapter transfers in
    # flight throughout the run, so link/stall faults have something to
    # bite; timeouts are off so any lost request is the chaos plane's
    # fault, not the reaper's
    return ClusterSimulator(
        3, adapters, policy="loraserve", seed=7, timeout=1e9,
        rebalance_period=6.0, prefetch=True, fault_plan=plan,
        detector_window=window, durable_ssd=True)


def _sim_rows(rows, fast):
    n_adapters = 8 if fast else 24
    duration = 30.0 if fast else 90.0
    rps = 14.0 if fast else 20.0
    t_kill, t_restore = duration / 3, 2 * duration / 3
    window = 0.5
    adapters = make_adapters(n_adapters, seed=3)
    trace = synth_trace(adapters, rps=rps, duration=duration,
                        popularity="shifting", prompt_len=128,
                        output_len=64, seed=11)

    base = _sim(adapters).run(copy.deepcopy(trace))
    base_pairs = _sim_pairs(base)
    rows.append(emit(
        "chaos/sim/baseline", 0.0,
        f"requests={len(trace)};completed={len(trace) - _lost(base_pairs)};"
        f"lost={_lost(base_pairs)};"
        f"slo_attainment={_attainment(base_pairs):.4f}"))

    # kill-a-server: crash at T/3, restore at 2T/3, everything in
    # flight on the victim re-dispatched from its last emitted token
    res = _sim(adapters, FaultPlan.kill_one(t_kill, 0, t_restore),
               window).run(copy.deepcopy(trace))
    pairs = _sim_pairs(res)
    det, rec = _recovery(res.recovery_records, t_kill)
    win, _, _ = _windows_derived(pairs, t_kill, t_restore)
    rows.append(emit(
        "chaos/sim/kill-one", rec * 1e6,
        f"detect_s={det:.3f};recover_s={rec:.3f};"
        f"detector_window_s={window};failures={res.server_failures};"
        f"recoveries={res.recoveries};redispatched={res.redispatched};"
        f"lost={_lost(pairs)};{win};"
        f"fetch_retries={res.fetch_retries};"
        f"fetch_timeouts={res.fetch_timeouts};"
        f"breaker_opens={res.breaker_opens}"))

    # link flap: server 0's egress NIC goes dark mid-run; fetches that
    # would source from it are excluded and pick an alternate peer/tier
    res = _sim(adapters, FaultPlan.link_flap(t_kill, 0, t_restore),
               window).run(copy.deepcopy(trace))
    pairs = _sim_pairs(res)
    win, _, _ = _windows_derived(pairs, t_kill, t_restore)
    rows.append(emit(
        "chaos/sim/link-flap", 0.0,
        f"lost={_lost(pairs)};{win};fetches={res.fetches};"
        f"prefetches={res.prefetches};fetch_retries={res.fetch_retries};"
        f"fetch_timeouts={res.fetch_timeouts};"
        f"breaker_opens={res.breaker_opens}"))

    # stalled recovery transfer: crash a server, then silently hang the
    # re-placement prefetches launched at detection — each stalled
    # transfer must blow its per-attempt deadline, back off, and
    # relaunch from a surviving source (the timeout/retry/alternate
    # path end to end, still loss-free)
    plan = FaultPlan.kill_one(t_kill, 0, t_restore)
    t_rec = t_kill + window
    for i in range(4):
        plan = FaultPlan(plan.events +
                         FaultPlan.stall(t_rec + 0.002 * (i + 1)).events)
    res = _sim(adapters, plan, window).run(copy.deepcopy(trace))
    pairs = _sim_pairs(res)
    win, _, _ = _windows_derived(pairs, t_kill, t_restore)
    rows.append(emit(
        "chaos/sim/kill-stall-fetch", 0.0,
        f"lost={_lost(pairs)};{win};redispatched={res.redispatched};"
        f"fetch_retries={res.fetch_retries};"
        f"fetch_timeouts={res.fetch_timeouts};"
        f"breaker_opens={res.breaker_opens}"))
    return pairs is not None


# ---------------------------------------------------------------------
# facade substrate (incremental poll loop + heartbeat detector)
# ---------------------------------------------------------------------
def _facade(adapters, plan=None, window=0.5):
    backend = SimBackend(3, adapter_nbytes={a.adapter_id: a.nbytes
                                            for a in adapters})
    return LoRAServeCluster(backend, adapters, network=NetworkModel(),
                            rebalance_period=1e9, seed=7, prefetch=True,
                            fault_plan=plan, detector_window=window,
                            durable_ssd=True)


def _facade_rows(rows, fast):
    n_adapters = 6 if fast else 16
    duration = 20.0 if fast else 60.0
    rps = 4.0 if fast else 8.0
    t_kill, t_restore = duration / 3, 2 * duration / 3
    window = 0.5
    adapters = make_adapters(n_adapters, seed=5)
    trace = synth_trace(adapters, rps=rps, duration=duration,
                        prompt_len=128, output_len=32, seed=13)

    base = _facade(adapters).run(copy.deepcopy(trace))
    base_pairs = _facade_pairs(base)
    rows.append(emit(
        "chaos/facade/baseline", 0.0,
        f"requests={len(trace)};lost={_lost(base_pairs)};"
        f"slo_attainment={_attainment(base_pairs):.4f}"))

    report = _facade(adapters,
                     FaultPlan.kill_one(t_kill, 0, t_restore),
                     window).run(copy.deepcopy(trace))
    pairs = _facade_pairs(report)
    det, rec = _recovery(report.recovery_records, t_kill)
    win, _, _ = _windows_derived(pairs, t_kill, t_restore)
    rows.append(emit(
        "chaos/facade/kill-one", rec * 1e6,
        f"detect_s={det:.3f};recover_s={rec:.3f};"
        f"detector_window_s={window};"
        f"failures={report.server_failures};"
        f"recoveries={report.recoveries};"
        f"redispatched={report.redispatched};lost={_lost(pairs)};{win};"
        f"fetch_retries={report.fetch_retries};"
        f"fetch_timeouts={report.fetch_timeouts};"
        f"breaker_opens={report.breaker_opens}"))
    return True


def run(fast: bool = True):
    rows = []
    _sim_rows(rows, fast)
    _facade_rows(rows, fast)

    # headline: loss-free on both substrates, SLO restored post-fault
    def field(name, key):
        for n, _, derived in rows:
            if n == name:
                for kv in derived.split(";"):
                    k, _, v = kv.partition("=")
                    if k == key:
                        return float(v)
        return float("nan")

    loss_free = (field("chaos/sim/kill-one", "lost") == 0.0
                 and field("chaos/facade/kill-one", "lost") == 0.0)
    restored = (field("chaos/sim/kill-one", "slo_restored") == 1.0
                and field("chaos/facade/kill-one", "slo_restored") == 1.0)
    rows.append(emit(
        "chaos/headline", 0.0,
        f"kill_one_loss_free_both={int(loss_free)};"
        f"slo_restored_both={int(restored)};"
        f"sim_recover_s={field('chaos/sim/kill-one', 'recover_s'):.3f};"
        f"facade_recover_s="
        f"{field('chaos/facade/kill-one', 'recover_s'):.3f}"))
    return rows
