"""Paper Fig 19/20 — the six derived traces (2 arrivals x 3 popularity)
x 4 policies: P95 TTFT and mean TBT, served through the unified
``LoRAServeCluster`` facade on the simulated backend."""
from __future__ import annotations

import copy

from repro.cluster import NetworkModel
from repro.serving import LoRAServeCluster, SimBackend
from repro.traces import make_adapters, six_traces

from .common import emit, timed

POLICIES = ["loraserve", "toppings", "slora-random", "slora-contiguous"]


def run(fast: bool = False):
    rows = []
    adapters = make_adapters(25, seed=1)
    nbytes = {a.adapter_id: a.nbytes for a in adapters}
    rps = 20
    traces = six_traces(adapters, rps=rps, duration=100 if fast else 150,
                        seed=2)
    for tname, trace in traces.items():
        if fast and tname.startswith("uniform-"):
            continue
        for pol in POLICIES:
            cluster = LoRAServeCluster(
                SimBackend(4, timeout=60, adapter_nbytes=nbytes),
                adapters, policy=pol, network=NetworkModel(),
                warmup=40, seed=3)
            res, us = timed(lambda: cluster.run(copy.deepcopy(trace)),
                            repeat=1)
            rows.append(emit(
                f"fig19-20/{tname}/{pol}", us,
                f"p95_ttft={res.p95_ttft():.3f}s;"
                f"mean_tbt_ms={res.mean_tbt() * 1e3:.1f};"
                f"rebalances={res.rebalances};"
                f"timeout={res.timed_out}"))
    return rows
