"""Kernel-level benchmark (Punica/S-LoRA claim): the max-rank padding tax
in the batched LoRA path, measured on the real jnp compute path (CPU),
plus the beyond-paper rank-bucketed dispatch win (analytic FLOPs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import sgmv_reference

from .common import emit, timed


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    T, d, do, Na = 256, 1024, 1024, 8
    x = jax.random.normal(key, (T, d))
    aid = jax.random.randint(key, (T,), 0, Na)

    ref = jax.jit(sgmv_reference)
    base_us = None
    for max_rank in (8, 16, 32, 64, 128):
        A = jax.random.normal(key, (Na, d, max_rank)) * 0.05
        B = jax.random.normal(key, (Na, max_rank, do)) * 0.05
        out = ref(x, A, B, aid)
        jax.block_until_ready(out)
        _, us = timed(lambda: jax.block_until_ready(ref(x, A, B, aid)),
                      repeat=5)
        if max_rank == 8:
            base_us = us
        rows.append(emit(f"kernel/sgmv_bank_r{max_rank}", us,
                         f"rel_vs_r8={us / base_us:.2f}"))

    # beyond-paper: rank-bucketed dispatch FLOP savings for a mixed batch
    # (half rank-8, half rank-128) vs max-rank-padded bank
    flops_padded = T * (2 * d * 128 + 2 * 128 * do)
    flops_bucketed = (T // 2) * (2 * d * 8 + 2 * 8 * do) + \
        (T // 2) * (2 * d * 128 + 2 * 128 * do)
    rows.append(emit("kernel/rank_bucketed_saving", 0.0,
                     f"flops_ratio={flops_bucketed / flops_padded:.3f}"))

    # Pallas flash kernel vs oracle (interpret mode, correctness-scale):
    # causal block-skip halves the scored blocks vs the full rectangle
    from repro.kernels.flash import flash_mha, flash_mha_ref
    B, H, S, hd = 1, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    out = flash_mha(q, kk, vv, causal=True, block_q=64, block_k=64,
                    interpret=True)
    ref = flash_mha_ref(q, kk, vv, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    nq = nk = S // 64
    skipped = sum(1 for i in range(nq) for j in range(nk)
                  if j * 64 > i * 64 + 63)
    rows.append(emit("kernel/flash_causal_skip", 0.0,
                     f"maxerr={err:.1e};blocks_skipped={skipped}/{nq*nk}"))
    return rows
