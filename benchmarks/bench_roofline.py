"""Roofline summary — reads the dry-run artifacts (experiments/dryrun/)
and reports the three roofline terms per (arch x shape), single-pod."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def run(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    paths = sorted(glob.glob(os.path.join(dryrun_dir,
                                          "*__single.json")))
    if not paths:
        rows.append(emit("roofline/missing", 0.0,
                         "run repro.launch.dryrun first"))
        return rows
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        dom = d["bottleneck"]
        tdom = d[f"t_{dom}"]
        rows.append(emit(
            f"roofline/{d['arch']}/{d['shape']}", d["compile_s"] * 1e6,
            f"tc={d['t_compute']:.3e};tm={d['t_memory']:.3e};"
            f"tx={d['t_collective']:.3e};bottleneck={dom};"
            f"useful_frac={d['useful_flops_frac']:.2f}"))
    return rows
