"""Ablation of Algorithm 1's components (design rationale, §IV-A):

  * full          — rank budgets + fractional packing + leftovers + permute
  * no-demand     — rank-aware but demand-oblivious (uniform demand prior,
                    never rebalanced): isolates the value of Step 1
  * no-rank       — demand-aware but rank-oblivious: operating point of
                    the *mean* rank for every adapter (isolates Step 2's
                    rank budgets)
  * no-permute    — Step 5 disabled: measures migration churn (fetches)
"""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator
from repro.core.baselines import LoraservePolicy
from repro.core.placement import assign_loraserve
from repro.traces import make_adapters, synth_trace

from .common import emit, timed


class NoDemandPolicy(LoraservePolicy):
    name = "ablate-no-demand"
    dynamic = False      # never rebalances => initial uniform prior only


class NoRankPolicy(LoraservePolicy):
    name = "ablate-no-rank"

    def place(self, ctx):
        mean_op = sum(ctx.operating_points.values()) / \
            len(ctx.operating_points)
        flat = dict(ctx.operating_points)
        for r in flat:
            flat[r] = mean_op
        ctx = copy.copy(ctx)
        ctx.operating_points = flat
        placement, self.last_stats = assign_loraserve(ctx)
        return placement


class NoPermutePolicy(LoraservePolicy):
    name = "ablate-no-permute"

    def place(self, ctx):
        ctx = copy.copy(ctx)
        ctx.prev_placement = None      # Step 5 sees no history
        placement, self.last_stats = assign_loraserve(ctx)
        return placement


def run(fast: bool = False):
    rows = []
    adapters = make_adapters(100, seed=1)
    trace = synth_trace(adapters, rps=20, duration=120 if fast else 180,
                        popularity="shifting", seed=2)
    variants = [("full", "loraserve"), ("no-demand", NoDemandPolicy()),
                ("no-rank", NoRankPolicy()),
                ("no-permute", NoPermutePolicy())]
    for name, pol in variants:
        sim = ClusterSimulator(4, adapters, policy=pol, seed=3,
                               timeout=60, warmup=40)
        res, us = timed(lambda: sim.run(copy.deepcopy(trace)), repeat=1)
        rows.append(emit(
            f"ablation/{name}", us,
            f"p95_ttft={res.p95_ttft():.3f}s;fetches={res.fetches};"
            f"timeout={res.timed_out}"))
    return rows
