"""Paper Fig 10 / §V headline — fewer GPUs under SLO with the closed
control loop.

Replays the drifting production trace (per-adapter Fig 10 patterns +
a diurnal aggregate load swing) against three fleets:

1. **static-max** — the over-provisioned baseline: peak-sized fleet for
   the whole run (what a fleet without autoscaling must do to hold the
   SLO through the peak);
2. **static-min** — a trough-sized fleet, showing why simply running
   fewer GPUs without a control loop breaks the SLO;
3. **autoscaled** — starts peak-sized with the ``ClusterController``
   attached: drift-triggered rebalances, scale-up on sustained SLO
   violation, drain + retire on sustained headroom.

Reported per fleet: GPU-hours (provision -> retire), SLO attainment,
P95 TTFT, and the control actions taken. The headline row is the
GPU-hour saving of the autoscaled fleet at equal-or-better attainment
than static-max.
"""
from __future__ import annotations

from repro.cluster import ClusterSimulator
from repro.controlplane import (ClusterController, ControllerConfig,
                                SLOSpec)
from repro.traces import production_trace_with_meta

from .common import emit

SLO_TTFT = 8.0


def _controller(min_servers: int, max_servers: int) -> ClusterController:
    return ClusterController(
        SLOSpec(ttft=SLO_TTFT, target=0.95, window=30.0),
        ControllerConfig(tick_period=5.0, min_servers=min_servers,
                         max_servers=max_servers, patience=2,
                         drain_patience=4, cooldown=25.0))


def _row(rows, name, res):
    att = res.slo_attainment(SLO_TTFT)
    rows.append(emit(
        f"autoscale/{name}", res.gpu_seconds * 1e6,
        f"gpu_hours={res.gpu_hours():.4f};slo_attainment={att:.4f};"
        f"p95_ttft_s={res.p95_ttft():.3f};completed={res.completed()};"
        f"timed_out={res.timed_out};scale_ups={res.scale_ups};"
        f"drains={res.drains};retires={res.retires};"
        f"final_servers={res.final_servers};"
        f"oob_rebalances={res.controller_rebalances};"
        f"drift_events={len(res.drift_events)}"))
    return att, res.gpu_seconds


def run(fast: bool = True):
    rows = []
    n_adapters = 40 if fast else 80
    rps = 14 if fast else 20
    duration = 240 if fast else 480
    n_max, n_min = (6, 2)

    trace, meta = production_trace_with_meta(
        n_adapters, rps=rps, duration=duration, seed=5,
        load_profile="diurnal")
    rows.append(emit("autoscale/trace", 0.0,
                     f"requests={len(trace)};load_profile=diurnal;"
                     f"rps_base={rps};duration_s={duration}"))

    def sim(n, controller=None):
        return ClusterSimulator(
            n, meta["adapters"], policy="loraserve", seed=7,
            timeout=60.0, warmup=0.0, rebalance_period=15.0,
            controller=controller)

    def replay(s):
        import copy
        return s.run(copy.deepcopy(trace))

    static_max = replay(sim(n_max))
    att_max, gpu_max = _row(rows, f"static-{n_max}", static_max)

    static_min = replay(sim(n_min))
    _row(rows, f"static-{n_min}", static_min)

    auto = replay(sim(n_max, controller=_controller(n_min, n_max + 2)))
    att_auto, gpu_auto = _row(rows, "autoscaled", auto)

    saving = 1.0 - gpu_auto / gpu_max if gpu_max else 0.0
    rows.append(emit(
        "autoscale/headline", 0.0,
        f"gpu_hour_saving={saving:.4f};"
        f"attainment_auto={att_auto:.4f};attainment_static={att_max:.4f};"
        f"auto_meets_or_beats_static={int(att_auto >= att_max - 1e-9)}"))
    return rows
