"""Paper Figs 1/3/4/5/6 — rank-interference characterization from the
calibrated cost model (and the simulator for Fig 6)."""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator, ServerModel, \
    co_serving_slowdown, make_server
from repro.core.types import AdapterInfo
from repro.traces import synth_trace

from .common import emit, timed


def run():
    rows = []
    # Fig 3: TTFT vs input size per rank (relative to rank-8)
    s = ServerModel(tp=1)
    for inp in (500, 1000, 2000, 4000):
        base, us = timed(s.prefill_time, inp, 8)
        for rank in (16, 32, 64, 128):
            ratio = s.prefill_time(inp, rank) / base
            rows.append(emit(f"fig3/prefill_rel/in{inp}/r{rank}", us,
                             f"rel_ttft={ratio:.2f}"))
    # Fig 3 bottom: TBT is far less rank-sensitive
    tbt_ratio = s.decode_time(16, 128) / s.decode_time(16, 8)
    rows.append(emit("fig3/decode_rel/r128", 0.0,
                     f"rel_tbt={tbt_ratio:.2f}"))
    # Fig 4: model size amplification (TP=8, input 2000)
    for model in ("llama-7b", "llama-30b", "llama-70b"):
        sm = make_server(model, tp=8)
        ratio = sm.prefill_time(2000, 128) / sm.prefill_time(2000, 8)
        rows.append(emit(f"fig4/model_size/{model}", 0.0,
                         f"rel_ttft_r128={ratio:.2f}"))
    # Fig 5: TP sweep (input 2000)
    for tp in (1, 2, 4, 8):
        st = ServerModel(tp=tp)
        ratio = st.prefill_time(2000, 128) / st.prefill_time(2000, 8)
        rows.append(emit(f"fig5/tp{tp}", 0.0,
                         f"rel_ttft_r128={ratio:.2f}"))
    # Fig 1: co-serving tax on the smaller rank
    s4 = ServerModel(tp=4)
    for pair in ((8, 8), (8, 32), (8, 128), (32, 128)):
        tax = co_serving_slowdown(s4, *pair)
        rows.append(emit(f"fig1/coserve/r{pair[0]}_with_r{pair[1]}", 0.0,
                         f"slowdown={tax:.2f}"))
    # Fig 6: single-server Poisson load by rank (P95 TTFT)
    for rank in (8, 32, 128):
        ad = [AdapterInfo(f"a{rank}", rank, 10_000_000)]
        tr = synth_trace(ad, rps=8, duration=120, arrival="poisson",
                         jitter=0.0, seed=5)
        sim = ClusterSimulator(1, ad, policy="slora-random", timeout=600)
        res, us = timed(lambda: sim.run(copy.deepcopy(tr)), repeat=1)
        rows.append(emit(f"fig6/poisson8rps/r{rank}", us,
                         f"p95_ttft={res.p95_ttft():.3f}s"))
    return rows
