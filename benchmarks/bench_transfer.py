"""Paper Fig 14 — adapter movement through the tiered data plane.

Three sweeps on the ``AdapterStore``/``NetworkModel`` API:

1. fetch latency by source (local host mem, IB GDR, SSD, TPU ICI) x
   transfer size x fabric contention — the paper's headline shape:
   IB GDR ~ local host->GPU, SSD prohibitive;
2. load-aware source quotes: the same IB GDR fetch priced against a
   source link already carrying 0/2/4 in-flight transfers (what
   ``FetchPlan`` source selection routes around);
3. access-mode A/B on a drifting workload (shifting rank popularity,
   dynamic LORASERVE placement): lazy migrate-on-miss vs GDR
   remote-read vs rebalance prefetch vs both — P95 TTFT plus data-plane
   telemetry per mode.
"""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator, NetworkModel
from repro.traces import make_adapters, synth_trace

from .common import emit

RANK_NBYTES = {r: r * 16_000_000 for r in (8, 16, 32, 64, 128)}


def run(fast: bool = True):
    rows = []
    # -- 1. Fig 14: source x size x contention --------------------------
    for contention in (1.0, 4.0):
        net = NetworkModel(contention=contention)
        for mb in (64, 256, 1024, 2048):
            nbytes = mb * 1024 * 1024
            for src in net.sources():
                lat = net.transfer_latency(nbytes, src)
                rows.append(emit(f"fig14/{src}/{mb}MB/x{contention:g}",
                                 lat * 1e6, f"latency_s={lat:.4f}"))
    net = NetworkModel()
    l_ib = net.transfer_latency(2 << 30, "ib_gdr")
    l_host = net.transfer_latency(2 << 30, "local_host")
    l_ssd = net.transfer_latency(2 << 30, "ssd")
    rows.append(emit("fig14/ib_vs_host", 0.0, f"ratio={l_ib / l_host:.2f}"))
    rows.append(emit("fig14/ssd_vs_host", 0.0,
                     f"ratio={l_ssd / l_host:.2f}"))

    # -- 2. link-load-aware quotes (FetchPlan source selection) ----------
    for load in (0, 2, 4):
        net = NetworkModel()
        for _ in range(load):
            net.begin_transfer(1 << 30, "ib_gdr", now=0.0, src_server=0)
        lat = net.plan_latency(256 << 20, "ib_gdr", now=0.0, src_server=0)
        rows.append(emit(f"link_load/ib_gdr/256MB/{load}_inflight",
                         lat * 1e6, f"latency_s={lat:.4f}"))
    pen = NetworkModel().remote_read_penalty(256 << 20)
    rows.append(emit("remote_read/iter_penalty/256MB", pen * 1e6,
                     f"penalty_s={pen:.4f}"))

    # -- 3. access-mode A/B under drift ----------------------------------
    adapters = make_adapters(32 if fast else 48,
                             nbytes_per_rank=RANK_NBYTES, seed=1)
    trace = synth_trace(adapters, rps=12 if fast else 14,
                        duration=60 if fast else 120,
                        popularity="shifting", seed=2)
    modes = [
        ("migrate", {}),
        ("remote-read", {"access_mode": "remote-read"}),
        ("migrate+prefetch", {"prefetch": True}),
        ("remote-read+prefetch", {"access_mode": "remote-read",
                                  "prefetch": True}),
    ]
    for name, kw in modes:
        sim = ClusterSimulator(4, adapters, policy="loraserve", seed=3,
                               warmup=15, timeout=60,
                               rebalance_period=8.0, **kw)
        res = sim.run(copy.deepcopy(trace))
        rows.append(emit(
            f"access_mode/{name}", res.p95_ttft() * 1e6,
            f"p95_ttft_s={res.p95_ttft():.4f};"
            f"p50_ttft_s={res.p50_ttft():.4f};"
            f"mean_tbt_ms={res.mean_tbt() * 1e3:.2f};"
            f"fetches={res.fetches};remote_reads={res.remote_reads};"
            f"prefetches={res.prefetches};"
            f"coalesced={res.coalesced_fetches};"
            f"timed_out={res.timed_out}"))
    return rows
