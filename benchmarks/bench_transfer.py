"""Paper Fig 14 — adapter fetch latency by source (host mem, IB GDR,
SSD; plus the TPU ICI deployment mapping)."""
from __future__ import annotations

from repro.cluster import NetworkModel

from .common import emit


def run():
    net = NetworkModel()
    rows = []
    for mb in (64, 256, 1024, 2048):
        nbytes = mb * 1024 * 1024
        for src in net.sources():
            lat = net.transfer_latency(nbytes, src)
            rows.append(emit(f"fig14/{src}/{mb}MB", lat * 1e6,
                             f"latency_s={lat:.4f}"))
    # paper's observation: IB GDR ~ local host->GPU
    l_ib = net.transfer_latency(2 << 30, "ib_gdr")
    l_host = net.transfer_latency(2 << 30, "local_host")
    l_ssd = net.transfer_latency(2 << 30, "ssd")
    rows.append(emit("fig14/ib_vs_host", 0.0,
                     f"ratio={l_ib / l_host:.2f}"))
    rows.append(emit("fig14/ssd_vs_host", 0.0,
                     f"ratio={l_ssd / l_host:.2f}"))
    return rows
