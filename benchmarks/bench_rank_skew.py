"""Paper Fig 22 — sensitivity to rank-popularity skew: power-law alpha
in {1/3, 1, 3}, 100 adapters, 4 servers — plus the beyond-paper
padded-vs-bucketed A/B: the same trace replayed with rank-bucketed
server banks, showing the max-rank padding tax (and its elimination)
per policy."""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator
from repro.traces import make_adapters, synth_trace

from .common import emit, timed

POLICIES = ["loraserve", "slora-random", "slora-contiguous"]
BANK_MODES = ["padded", "bucketed"]


def run(fast: bool = False):
    rows = []
    alphas = (1 / 3, 3.0) if fast else (1 / 3, 1.0, 3.0)
    adapters = make_adapters(100, seed=1)
    for alpha in alphas:
        trace = synth_trace(adapters, rps=20, duration=150,
                            popularity="powerlaw", alpha=alpha, seed=2)
        for pol in POLICIES:
            p95 = {}
            for mode in BANK_MODES:
                sim = ClusterSimulator(4, adapters, policy=pol, seed=3,
                                       timeout=60, warmup=40,
                                       bank_mode=mode)
                res, us = timed(lambda: sim.run(copy.deepcopy(trace)),
                                repeat=1)
                p95[mode] = res.p95_ttft()
                rows.append(emit(
                    f"fig22/alpha{alpha:.2f}/{pol}/{mode}", us,
                    f"p95_ttft={res.p95_ttft():.3f}s;"
                    f"timeout={res.timed_out}"))
            saved = 1.0 - p95["bucketed"] / p95["padded"] \
                if p95["padded"] > 0 else 0.0
            rows.append(emit(
                f"fig22/alpha{alpha:.2f}/{pol}/padding-tax", 0.0,
                f"p95_saving={saved:.3f}"))
    return rows
