"""Paper Fig 22 — sensitivity to rank-popularity skew: power-law alpha
in {1/3, 1, 3}, 100 adapters, 4 servers."""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator
from repro.traces import make_adapters, synth_trace

from .common import emit, timed

POLICIES = ["loraserve", "slora-random", "slora-contiguous"]


def run(fast: bool = False):
    rows = []
    alphas = (1 / 3, 3.0) if fast else (1 / 3, 1.0, 3.0)
    adapters = make_adapters(100, seed=1)
    for alpha in alphas:
        trace = synth_trace(adapters, rps=20, duration=150,
                            popularity="powerlaw", alpha=alpha, seed=2)
        for pol in POLICIES:
            sim = ClusterSimulator(4, adapters, policy=pol, seed=3,
                                   timeout=60, warmup=40)
            res, us = timed(lambda: sim.run(copy.deepcopy(trace)),
                            repeat=1)
            rows.append(emit(
                f"fig22/alpha{alpha:.2f}/{pol}", us,
                f"p95_ttft={res.p95_ttft():.3f}s;"
                f"timeout={res.timed_out}"))
    return rows
