"""Observability overhead + coverage — the flight-recorder tracing
layer must be cheap enough to leave on.

Replays a synthetic workload through the calibrated simulator with
tracing on, then derives the tracing overhead from first principles:

    overhead_frac = per-span record cost x span count / base run time

where the per-span cost is calibrated with a tight in-process loop
(``Tracer.record`` with the drift listener attached — exactly the
tracing-on hot path) and the base time is best-of-N tracing-off runs.
The direct A/B throughput delta is also reported
(``overhead_frac_e2e``) but only as a secondary signal: the tracing
cost is tens of milliseconds against a ~1.5 s run, well inside
machine-load jitter, so the derived number is the acceptance gate
(< 3% tokens/s).

Also reported:

* per-phase decomposition coverage — the fraction of finished requests
  whose fetch/queue/prefill/decode children telescope exactly to the
  root request span, plus each phase's share of total request time;
* cost-model drift per phase (bias ~0 on the sim substrate: modeled
  time IS sim time, so nonzero means the span pairing broke).

A sample Perfetto trace is written next to the CSV
(``experiments/bench/obs_sample.perfetto.json``) for loading in
ui.perfetto.dev.
"""
from __future__ import annotations

import copy
import os
import time

from repro.cluster import ClusterSimulator
from repro.obs import (REQUEST_PHASES, CostModelDrift, EventClock, Tracer,
                       write_perfetto)
from repro.traces import make_adapters, synth_trace

from .common import emit, timed

OUTDIR = "experiments/bench"


def _tokens(res) -> int:
    return sum(r.prompt_len + r.output_len for r in res.requests
               if r.finish >= 0)


def _calibrate_span_cost(n: int = 20000, batches: int = 5) -> float:
    """Seconds per ``Tracer.record`` call with the drift listener
    attached — the exact per-span cost the simulator pays when tracing
    is on. Tight-loop, min over batches: stable where an end-to-end
    A/B diff of the same quantity drowns in scheduler noise."""
    best = float("inf")
    for _ in range(batches):
        tr = Tracer(clock=EventClock())
        tr.add_listener(CostModelDrift().observe)
        attrs = {"predicted": 0.01, "batch": 8, "steps": 1,
                 "iters": 1, "bank_mode": "padded"}
        t0 = time.perf_counter()
        for i in range(n):
            tr.record("decode", 0.0, 0.01, cat="iteration",
                      track="server:0", attrs=attrs)
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def run(fast: bool = True):
    rows = []
    n_servers = 4
    n_adapters = 24 if fast else 48
    rps = 30.0 if fast else 60.0
    duration = 40.0 if fast else 120.0

    adapters = make_adapters(n_adapters, seed=11)
    trace = synth_trace(adapters, rps=rps, duration=duration,
                        prompt_len=256, output_len=64, seed=11)

    def replay(tracer=None):
        sim = ClusterSimulator(n_servers, adapters, policy="loraserve",
                               seed=11, timeout=120.0,
                               rebalance_period=10.0, tracer=tracer)
        return sim.run(copy.deepcopy(trace))

    # interleave the arms (off, on, off, on, ...) and take best-of-N
    # per arm: sequential best-of-N lets machine-load drift between the
    # two measurements masquerade as (even negative) tracing overhead
    repeat = 4 if fast else 6
    us_off = us_on = float("inf")
    res_off = res_on = tracer = None
    for _ in range(repeat):
        r, us = timed(replay, repeat=1)
        if us < us_off:
            res_off, us_off = r, us
        t = Tracer(clock=EventClock())
        r, us = timed(replay, t, repeat=1)
        if us < us_on:
            res_on, us_on, tracer = r, us, t
    tok_off = _tokens(res_off)
    tok_on = _tokens(res_on)

    tps_off = tok_off / (us_off / 1e6)
    tps_on = tok_on / (us_on / 1e6)
    overhead_e2e = 1.0 - tps_on / tps_off if tps_off else 0.0

    # primary overhead: calibrated per-span cost x span volume, against
    # the best-of-N base time — deterministic in span count, immune to
    # the run-to-run jitter that dominates the direct A/B delta
    span_cost_s = _calibrate_span_cost()
    derived_s = span_cost_s * tracer.n_spans
    overhead = derived_s / (us_off / 1e6) if us_off else 0.0

    rows.append(emit("obs/tracing-off", us_off,
                     f"requests={len(trace)};completed={res_off.completed()};"
                     f"tokens_per_s={tps_off:.0f}"))
    rows.append(emit("obs/tracing-on", us_on,
                     f"completed={res_on.completed()};"
                     f"spans={tracer.n_spans};"
                     f"flight_dumps={res_on.flight_dumps};"
                     f"tokens_per_s={tps_on:.0f}"))
    rows.append(emit("obs/span-cost", span_cost_s * 1e6,
                     f"us_per_span={span_cost_s * 1e6:.3f};"
                     f"spans={tracer.n_spans};"
                     f"derived_ms={derived_s * 1e3:.1f}"))
    rows.append(emit("obs/overhead", derived_s * 1e6,
                     f"overhead_frac={overhead:.4f};"
                     f"overhead_frac_e2e={overhead_e2e:.4f};"
                     f"within_3pct={int(overhead < 0.03)}"))

    # per-phase decomposition coverage over every finished request
    per_phase = dict.fromkeys(REQUEST_PHASES, 0.0)
    total = exact = 0
    root_time = 0.0
    for spans in tracer.by_request().values():
        roots = [s for s in spans if s.name == "request"]
        if not roots:
            continue
        root = roots[0]
        kids = {s.name: s.duration for s in spans
                if s.parent_id == root.span_id}
        total += 1
        if set(kids) == set(REQUEST_PHASES) and abs(
                sum(kids.values()) - root.duration) <= 1e-9:
            exact += 1
        for p in REQUEST_PHASES:
            per_phase[p] += kids.get(p, 0.0)
        root_time += root.duration
    shares = ";".join(
        f"{p}_share={per_phase[p] / root_time:.4f}" if root_time else
        f"{p}_share=0" for p in REQUEST_PHASES)
    rows.append(emit("obs/decomposition", 0.0,
                     f"requests={total};exact={exact};"
                     f"coverage={exact / total if total else 0:.4f};"
                     f"{shares}"))

    for phase, d in sorted(res_on.cost_drift.items()):
        rows.append(emit(
            f"obs/drift/{phase}", d["measured_s"] * 1e6,
            f"count={d['count']};modeled_s={d['modeled_s']:.3f};"
            f"bias={d['bias']:+.2e};mare={d['mean_abs_rel_err']:.2e}"))

    os.makedirs(OUTDIR, exist_ok=True)
    sample = os.path.join(OUTDIR, "obs_sample.perfetto.json")
    n = write_perfetto(tracer, sample)
    rows.append(emit("obs/sample-trace", 0.0,
                     f"spans={n};path={sample}"))
    return rows
