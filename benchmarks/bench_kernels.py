"""SGMV v2 microbenchmark: fused vs unfused kernel dispatch (tokens/s
and dispatch counts) across rank-skew adapter mixes, plus the engine's
fused multi-token decode (`decode_steps(k)`: host dispatches per token).

Paths compared per mix (same weights; all outputs bit-identical):
  * unfused       — `sgmv` on the max-rank padded bank (2 dispatches:
                    shrink + expand, rank-r intermediate via HBM)
  * fused         — `sgmv_fused` on the same bank (1 dispatch, VMEM
                    intermediate)
  * host_bucketed — `sgmv_rank_bucketed` (host loop: token_adapter sync
                    + 2 dispatches per non-empty rank bucket)
  * fused_bucketed— `sgmv_bucketed_fused` (1 dispatch total, each token
                    at its own bucket's rank)

Interpret-mode (CPU CI) numbers understate compiled-TPU wins; the
`kernels/fused_speedup_*` rows are the acceptance metric
(fused_bucketed vs unfused tokens/s on a rank-skewed mix). Caveat on
`host_bucketed` interpret times: its host sync is free once the timing
loop has the ids on host and its compacted sub-batches are smaller, so
it can look fast here — but it is not jittable (cannot live inside the
engine's traced step) and costs 2 launches per bucket; the fused path
exists to remove exactly that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import (sgmv, sgmv_bucketed_fused, sgmv_fused,
                           sgmv_rank_bucketed, sgmv_reference)

from .common import emit, timed

# token share of the low-rank bucket per mix (rank-8 vs rank-128 pair)
MIXES = {"skew_lowrank": 0.9375, "even": 0.5, "all_highrank": 0.0}


def _bank(key, n, d, r, do):
    kA, kB = jax.random.split(key)
    return (jax.random.normal(kA, (n, d, r)) * 0.05,
            jax.random.normal(kB, (n, r, do)) * 0.05)


def _time_paths(paths, repeat):
    """Median-of-rounds, rounds interleaved across paths (a paired
    design): shared CI machines flip between fast and slow states that
    persist for several calls, which scrambles sequential best-of-N
    timings — but calls inside one short round share the machine state,
    so per-round numbers are comparable and the median over rounds
    discards the corrupted ones."""
    import statistics
    import time as _t
    for fn in paths.values():
        jax.block_until_ready(fn())          # warm the traces
    rounds = {name: [] for name in paths}
    for _ in range(repeat):
        for name, fn in paths.items():
            t0 = _t.perf_counter()
            jax.block_until_ready(fn())
            rounds[name].append(_t.perf_counter() - t0)
    med = {name: statistics.median(ts) * 1e6
           for name, ts in rounds.items()}
    return med, rounds


def _paired_speedup(rounds, a: str, b: str) -> float:
    """Median of the per-round time ratios b/a (a's speedup over b)."""
    import statistics
    return statistics.median(tb / ta for ta, tb in
                             zip(rounds[a], rounds[b]))


def kernel_rows(fast: bool):
    rows = []
    # sizes/block_t where the rank-dependent dots and bank-block traffic
    # dominate the per-grid-step interpreter floor, so the tokens/s
    # ratios track the kernel design rather than framework overhead
    T, d, do = (512, 2048, 2048) if fast else (2048, 4096, 4096)
    bt = 64
    repeat = 16
    r_lo, r_hi = 8, 128
    key = jax.random.PRNGKey(0)
    kx, kb = jax.random.split(key)
    x = jax.random.normal(kx, (T, d))
    lo = _bank(kb, 2, d, r_lo, do)
    hi = _bank(jax.random.fold_in(kb, 1), 2, d, r_hi, do)
    # padded equivalent: all 4 adapters zero-padded to r_hi
    Apad = jnp.concatenate([jnp.pad(lo[0], ((0, 0), (0, 0),
                                            (0, r_hi - r_lo))), hi[0]])
    Bpad = jnp.concatenate([jnp.pad(lo[1], ((0, 0), (0, r_hi - r_lo),
                                            (0, 0))), hi[1]])
    bucket = jnp.asarray([0, 0, 1, 1], jnp.int32)
    local = jnp.asarray([0, 1, 0, 1], jnp.int32)
    banks = (lo, hi)

    speedups = {}
    for mix, frac_lo in MIXES.items():
        n_lo = int(T * frac_lo)
        aid = jnp.asarray([i % 2 for i in range(n_lo)]
                          + [2 + i % 2 for i in range(T - n_lo)],
                          jnp.int32)
        dispatches = {"unfused": 2, "fused": 1,
                      "host_bucketed": 2 * (2 if 0 < n_lo < T else 1),
                      "fused_bucketed": 1}
        paths = {
            "unfused": lambda a=aid: sgmv(
                x, Apad, Bpad, a, block_t=bt, interpret=True),
            "fused": lambda a=aid: sgmv_fused(
                x, Apad, Bpad, a, block_t=bt, interpret=True),
            "host_bucketed": lambda a=aid: sgmv_rank_bucketed(
                x, banks, a, bucket, adapter_local=local, block_t=bt,
                interpret=True),
            # block_t/resident from the kernels.tune heuristic table
            # (per-bucket geometry, memoized per bank signature) — the
            # static block_t=bt it replaced lost to the host loop on the
            # skewed mix by re-fetching the high-rank bank every step
            "fused_bucketed": lambda a=aid: sgmv_bucketed_fused(
                x, banks, a, bucket, local, interpret=True),
        }
        us, rounds = _time_paths(paths, repeat)
        tok_s = {name: T / (u * 1e-6) for name, u in us.items()}
        for name in paths:
            rows.append(emit(f"kernels/{mix}/{name}", us[name],
                             f"tok_s={tok_s[name]:.0f};"
                             f"dispatches={dispatches[name]}"))
        speedups[mix] = (_paired_speedup(rounds, "fused_bucketed",
                                         "unfused"),
                         _paired_speedup(rounds, "fused", "unfused"),
                         _paired_speedup(rounds, "fused_bucketed",
                                         "host_bucketed"))
    for mix, (sb, sf, sh) in speedups.items():
        rows.append(emit(f"kernels/fused_speedup_{mix}", 0.0,
                         f"bucketed_fused_vs_unfused={sb:.2f}x;"
                         f"fused_vs_unfused={sf:.2f}x;"
                         f"bucketed_fused_vs_host={sh:.2f}x"))
    return rows


def engine_rows(fast: bool):
    """decode_steps(k): host dispatches per decoded token, k=1 vs k=8."""
    import time as _t

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req, max_new = (6, 8) if fast else (12, 16)

    per_tok = {}
    rows = []
    for k in (1, 8):
        eng = ServingEngine(cfg, params, {"a-r8": 8, "b-r64": 64},
                            max_batch=4, max_len=40, decode_block=k)
        now = _t.monotonic()
        for i in range(n_req):
            eng.submit(Request(i, ["a-r8", "b-r64"][i % 2],
                               list(range(1, 9)), max_new, arrival=now))
        t0 = _t.perf_counter()
        eng.run_until_drained()
        us = (_t.perf_counter() - t0) * 1e6
        per_tok[k] = eng.decode_dispatches / max(1, eng.tokens_decoded)
        rows.append(emit(f"kernels/engine_decode_block{k}", us,
                         f"decode_dispatches={eng.decode_dispatches};"
                         f"tokens={eng.tokens_decoded};"
                         f"dispatch_per_tok={per_tok[k]:.3f}"))
    rows.append(emit("kernels/engine_dispatch_reduction", 0.0,
                     f"k8_vs_k1={per_tok[1] / per_tok[8]:.1f}x"))
    return rows


def padding_tax_rows():
    """Absorbed from the old bench_kernel.py (near-duplicate module):
    the max-rank padding tax on the reference jnp path, the analytic
    rank-bucketed FLOP saving, and the flash causal block-skip check.
    Metric names keep their historical `kernel/` prefix so existing CSV
    series stay comparable."""
    rows = []
    key = jax.random.PRNGKey(0)
    T, d, do, Na = 256, 1024, 1024, 8
    x = jax.random.normal(key, (T, d))
    aid = jax.random.randint(key, (T,), 0, Na)

    ref = jax.jit(sgmv_reference)
    base_us = None
    for max_rank in (8, 16, 32, 64, 128):
        A = jax.random.normal(key, (Na, d, max_rank)) * 0.05
        B = jax.random.normal(key, (Na, max_rank, do)) * 0.05
        out = ref(x, A, B, aid)
        jax.block_until_ready(out)
        _, us = timed(lambda: jax.block_until_ready(ref(x, A, B, aid)),
                      repeat=5)
        if max_rank == 8:
            base_us = us
        rows.append(emit(f"kernel/sgmv_bank_r{max_rank}", us,
                         f"rel_vs_r8={us / base_us:.2f}"))

    # beyond-paper: rank-bucketed dispatch FLOP savings for a mixed batch
    # (half rank-8, half rank-128) vs max-rank-padded bank
    flops_padded = T * (2 * d * 128 + 2 * 128 * do)
    flops_bucketed = (T // 2) * (2 * d * 8 + 2 * 8 * do) + \
        (T // 2) * (2 * d * 128 + 2 * 128 * do)
    rows.append(emit("kernel/rank_bucketed_saving", 0.0,
                     f"flops_ratio={flops_bucketed / flops_padded:.3f}"))

    # Pallas flash kernel vs oracle (interpret mode, correctness-scale):
    # causal block-skip halves the scored blocks vs the full rectangle
    from repro.kernels.flash import flash_mha, flash_mha_ref
    B, H, S, hd = 1, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    out = flash_mha(q, kk, vv, causal=True, block_q=64, block_k=64,
                    interpret=True)
    ref = flash_mha_ref(q, kk, vv, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    nq = nk = S // 64
    skipped = sum(1 for i in range(nq) for j in range(nk)
                  if j * 64 > i * 64 + 63)
    rows.append(emit("kernel/flash_causal_skip", 0.0,
                     f"maxerr={err:.1e};blocks_skipped={skipped}/{nq*nk}"))
    return rows


def run(fast: bool = True):
    return kernel_rows(fast) + engine_rows(fast) + padding_tax_rows()
