"""Paper Fig 17/18 — production traces, 50/100/200 adapters, 4 servers:
P95 TTFT + per-server balance + adapter storage per policy, served
through the unified ``LoRAServeCluster`` facade on the simulated
backend."""
from __future__ import annotations

import copy

from repro.cluster import NetworkModel
from repro.serving import LoRAServeCluster, SimBackend, percentile
from repro.traces import make_adapters, production_trace

from .common import emit, timed

POLICIES = ["loraserve", "toppings", "slora-random", "slora-contiguous"]


def run(fast: bool = False):
    rows = []
    sizes = (50, 100) if fast else (50, 100, 200)
    for n_adapters in sizes:
        adapters = make_adapters(n_adapters, seed=1)
        nbytes = {a.adapter_id: a.nbytes for a in adapters}
        trace = production_trace(n_adapters, rps=20, duration=150, seed=2)
        for pol in POLICIES:
            cluster = LoRAServeCluster(
                SimBackend(4, timeout=60, adapter_nbytes=nbytes),
                adapters, policy=pol, network=NetworkModel(),
                warmup=40, seed=3)
            res, us = timed(lambda: cluster.run(copy.deepcopy(trace)),
                            repeat=1)
            rows.append(emit(
                f"fig17/prod/{n_adapters}ad/{pol}", us,
                f"p95_ttft={res.p95_ttft():.3f}s;p50={res.p50_ttft():.3f}s;"
                f"timeout={res.timed_out};"
                f"max_adapters={res.max_adapters_per_server};"
                f"adapter_GB={res.total_adapter_bytes / 1e9:.2f}"))
            if n_adapters == 100:
                by_server = {}
                for r in res.results:
                    if r.finished and r.arrival >= res.warmup \
                            and r.ttft is not None:
                        by_server.setdefault(r.server, []).append(r.ttft)
                per = ";".join(
                    f"s{sid}={percentile(ts, 95):.2f}"
                    for sid, ts in sorted(by_server.items()))
                rows.append(emit(f"fig18/per_server/{pol}", 0.0, per))
    return rows
