"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV (and tees a copy per bench under
experiments/bench/).

  PYTHONPATH=src python -m benchmarks.run [--only placement,workloads] [--full]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from . import (bench_ablation, bench_autoscale, bench_chaos,
               bench_interference, bench_kernels, bench_mesh, bench_obs,
               bench_placement, bench_rank_skew, bench_roofline,
               bench_scalability, bench_server, bench_transfer,
               bench_workloads)
from .common import fmt_rows

BENCHES = {
    "autoscale": bench_autoscale.run,
    "chaos": bench_chaos.run,
    "interference": lambda fast: bench_interference.run(),
    "transfer": bench_transfer.run,
    # "kernel" (the old bench_kernel.py) was folded into "kernels":
    # its padding-tax / flash-skip rows now come from padding_tax_rows()
    "kernels": bench_kernels.run,
    "mesh": bench_mesh.run,
    "obs": bench_obs.run,
    "placement": bench_placement.run,
    "workloads": bench_workloads.run,
    "scalability": bench_scalability.run,
    "rank_skew": bench_rank_skew.run,
    "server": bench_server.run,
    "roofline": lambda fast: bench_roofline.run(),
    "ablation": bench_ablation.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list of bench names (default: all)")
    ap.add_argument("--full", action="store_true",
                    help="full-size sweeps (default: fast subsets)")
    ap.add_argument("--outdir", default="experiments/bench")
    args = ap.parse_args()

    names = [n for n in args.only.split(",") if n] or list(BENCHES)
    os.makedirs(args.outdir, exist_ok=True)
    all_rows = []
    for name in names:
        t0 = time.time()
        rows = BENCHES[name](not args.full)
        all_rows.extend(rows)
        csv = fmt_rows(rows)
        with open(os.path.join(args.outdir, f"{name}.csv"), "w") as f:
            f.write(csv + "\n")
        print(f"# {name} ({time.time() - t0:.1f}s)", file=sys.stderr)
    print(fmt_rows(all_rows))


if __name__ == "__main__":
    main()
