"""Paper Fig 21 — weak scaling: 4/8/12 servers with proportional adapters
and traffic; LORASERVE should sustain proportional RPS under the SLO."""
from __future__ import annotations

import copy

from repro.cluster import ClusterSimulator
from repro.traces import make_adapters, synth_trace

from .common import emit, timed


def run(fast: bool = False):
    rows = []
    sizes = (4, 8) if fast else (4, 8, 12)
    for n in sizes:
        adapters = make_adapters(25 * n // 4, seed=1)
        rps = 5 * n
        trace = synth_trace(adapters, rps=rps, duration=120,
                            popularity="exponential", seed=2)
        sim = ClusterSimulator(n, adapters, policy="loraserve", seed=3,
                               timeout=60, warmup=40)
        res, us = timed(lambda: sim.run(copy.deepcopy(trace)), repeat=1)
        rows.append(emit(
            f"fig21/servers{n}/rps{rps}", us,
            f"p95_ttft={res.p95_ttft():.3f}s;timeout={res.timed_out};"
            f"slo10s={'PASS' if res.meets_slo(10.0) else 'FAIL'}"))
    return rows
