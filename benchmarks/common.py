"""Benchmark plumbing: every bench yields (name, us_per_call, derived)
rows; run.py prints them as CSV."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str):
    return (name, us_per_call, derived)


def fmt_rows(rows):
    out = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        out.append(f"{name},{us:.2f},{derived}")
    return "\n".join(out)


def timed(fn, *args, repeat: int = 3, **kw):
    """Returns (result, us_per_call) — best of `repeat`."""
    best = float("inf")
    res = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        res = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return res, best * 1e6
