"""Streaming-gateway load bench: open-loop Poisson HTTP traffic against
an in-process ``ServeGateway`` over ``SimBackend``.

Client threads fire ``POST /v1/completions`` (SSE) at exponential
inter-arrival gaps — open-loop, so admission and queueing delays do not
throttle the offered load — and measure *client-side* TTFT (request
send to first SSE token frame) and end-to-end stream duration. Rows
report P50/P95 TTFT and aggregate streamed tokens/s per offered rate.
"""
from __future__ import annotations

import asyncio
import http.client
import json
import random
import threading
import time

from repro.cluster import NetworkModel
from repro.core import AdapterInfo
from repro.serving import LoRAServeCluster, SimBackend
from repro.server import ServeGateway

from .common import emit


def _percentile(xs, q):
    if not xs:
        return float("nan")
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))]


class _Gateway:
    """The gateway on its own event loop in a daemon thread."""

    def __init__(self, cluster):
        self.gw = ServeGateway(cluster, port=0)
        self._ready = threading.Event()
        self.loop = None
        self.thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        self.loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self.loop)

        async def main():
            await self.gw.start()
            self._ready.set()
            await self.gw.serve_until_stopped()

        try:
            self.loop.run_until_complete(main())
        finally:
            self.loop.close()

    def start(self):
        self.thread.start()
        if not self._ready.wait(60):
            raise RuntimeError("gateway failed to start")
        return self.gw.port

    def stop(self):
        self.loop.call_soon_threadsafe(self.gw.begin_shutdown)
        self.thread.join(300)


def _one_stream(port, adapter_id, max_tokens, out):
    """One SSE request; appends (ttft_s, n_tokens, duration_s)."""
    t0 = time.perf_counter()
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=300)
    try:
        conn.request("POST", "/v1/completions",
                     json.dumps({"adapter_id": adapter_id,
                                 "prompt_len": 16,
                                 "max_tokens": max_tokens}),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            resp.read()
            return
        ttft, n = None, 0
        while True:
            line = resp.fp.readline()
            if not line:
                break
            line = line.decode("utf-8").strip()
            if line == "data: [DONE]":
                break
            if not line.startswith("data: "):
                continue
            toks = json.loads(line[6:]).get("tokens") or []
            if toks and ttft is None:
                ttft = time.perf_counter() - t0
            n += len(toks)
        if ttft is not None:
            out.append((ttft, n, time.perf_counter() - t0))
    finally:
        conn.close()


def _load_round(port, adapters, rate_rps, n_requests, max_tokens,
                seed):
    """Open-loop Poisson arrivals: launch each request on its own
    thread at its scheduled instant regardless of completions."""
    rng = random.Random(seed)
    samples = []             # thread-safe via GIL-atomic list.append
    threads = []
    t_start = time.perf_counter()
    next_at = 0.0
    for i in range(n_requests):
        next_at += rng.expovariate(rate_rps)
        delay = t_start + next_at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t = threading.Thread(
            target=_one_stream,
            args=(port, adapters[i % len(adapters)].adapter_id,
                  max_tokens, samples),
            daemon=True)
        t.start()
        threads.append(t)
    for t in threads:
        t.join(300)
    wall = time.perf_counter() - t_start
    return samples, wall


def run(fast: bool = True):
    n_requests = 16 if fast else 64
    max_tokens = 20 if fast else 40
    rates = [20.0, 60.0] if fast else [20.0, 60.0, 120.0]
    rows = []
    for rate in rates:
        adapters = [AdapterInfo(f"b{i}-r{[8, 16, 32, 64][i % 4]}",
                                [8, 16, 32, 64][i % 4],
                                nbytes=8 << 20) for i in range(4)]
        backend = SimBackend(2, adapter_nbytes={
            a.adapter_id: a.nbytes for a in adapters})
        cluster = LoRAServeCluster(backend, adapters,
                                   network=NetworkModel(),
                                   rebalance_period=1e9, seed=0)
        gw = _Gateway(cluster)
        port = gw.start()
        try:
            samples, wall = _load_round(port, adapters, rate,
                                        n_requests, max_tokens, seed=1)
        finally:
            gw.stop()
        done = len(samples)
        tokens = sum(n for _, n, _ in samples)
        ttfts = [t for t, _, _ in samples]
        tok_rate = tokens / wall if wall > 0 else 0.0
        rows.append(emit(
            f"server/poisson_rate{rate:g}",
            _percentile(ttfts, 0.50) * 1e6 if ttfts else 0.0,
            f"p50_ttft_ms={_percentile(ttfts, 0.50) * 1e3:.1f} "
            f"p95_ttft_ms={_percentile(ttfts, 0.95) * 1e3:.1f} "
            f"streamed_tok_per_s={tok_rate:.0f} "
            f"completed={done}/{n_requests} "
            f"streamed_tokens={tokens}"))
    return rows


if __name__ == "__main__":
    from .common import fmt_rows
    print(fmt_rows(run(True)))
