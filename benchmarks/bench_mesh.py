"""Mesh-sharded serving benchmark: per-chip work, modeled-vs-simulated
iteration time, and real-engine tokens/s versus tensor-parallel degree.

Three row families per tp degree:

  * ``mesh/modeled_decode_tp{t}`` / ``mesh/modeled_prefill_tp{t}`` —
    the cost model's iteration times with the explicit ICI ring-all-
    reduce terms (`ServerModel(mesh_shape=(1, t))`), plus the per-chip
    weight bytes / FLOPs each degree leaves on one chip (strictly
    decreasing with tp: that is the point of sharding).
  * ``mesh/sim_iter_tp{t}`` — a discrete-event `SimServer` run of a
    ramping trace (staggered output lengths, mixed rank buckets). The
    simulated ICI seconds (mesh run minus an otherwise-identical
    no-mesh run) are compared against the closed-form steady-state ICI
    estimate (constant batch, every decode iteration alike) — the
    relative gap is the reported cost-model ICI error, nonzero because
    the real batch ramps down at the tail.
  * ``mesh/engine_tp{t}`` — the real JAX engine on a (1, t) device
    mesh: wall-clock us/token + tokens/s, and the *measured* per-chip
    parameter bytes of the sharded arrays (addressable shard 0).
    Degrees above the process device count are skipped — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    ``mesh`` job does) to sweep all of them. CPU wall-clock does not
    reward sharding (all "chips" share one socket); the acceptance
    signal is per-chip work, not CPU tokens/s.
"""
from __future__ import annotations

import time

from repro.cluster.costmodel import ServerModel
from repro.cluster.server import SimServer
from repro.core import SimRequest

from .common import emit

TP_DEGREES = (1, 2, 4, 8)


# -- cost model: iteration times + per-chip work ---------------------------

def modeled_rows(fast: bool):
    rows = []
    batch, rank, tokens = 32, 64, 2048
    for tp in TP_DEGREES:
        m = ServerModel(tp=tp, mesh_shape=(1, tp))
        ici = m.iteration_ici_time(batch, {rank: batch})
        per_chip_gb = 2.0 * m.n_params / tp / 1e9
        rows.append(emit(
            f"mesh/modeled_decode_tp{tp}",
            m.decode_time(batch, rank) * 1e6,
            f"ici_us={ici * 1e6:.1f} "
            f"per_chip_weight_gb={per_chip_gb:.3f}"))
        rows.append(emit(
            f"mesh/modeled_prefill_tp{tp}",
            m.prefill_time(tokens, rank) * 1e6,
            f"per_chip_flops_per_token_g={per_chip_gb:.3f}"))
    return rows


# -- discrete-event sim vs the closed-form ICI estimate --------------------

def _trace(n_req: int):
    # mixed rank buckets, staggered output lengths: the tail iterations
    # run at shrinking batch, which the constant-batch estimate ignores
    return [SimRequest(req_id=i, adapter_id=f"a{i}",
                       rank=(8, 64)[i % 2], prompt_len=128,
                       output_len=32 + (i % 3) * 16, arrival=0.0)
            for i in range(n_req)]


def _sim_run(model: ServerModel, n_req: int) -> SimServer:
    s = SimServer(0, model, bank_mode="bucketed")
    for r in _trace(n_req):
        s.enqueue(r)
    now = 0.0
    while s.waiting or s.running:
        now = s.step(now)
    return s


def sim_rows(fast: bool):
    rows = []
    n_req = 16 if fast else 32
    reqs = _trace(n_req)
    b = len(reqs)
    buckets = {8: sum(1 for r in reqs if r.rank == 8),
               64: sum(1 for r in reqs if r.rank == 64)}
    n_dec = max(r.output_len for r in reqs) - 1
    tokens = sum(r.prompt_len for r in reqs)
    for tp in TP_DEGREES:
        mesh = _sim_run(ServerModel(tp=tp, mesh_shape=(1, tp)), n_req)
        flat = _sim_run(ServerModel(tp=tp), n_req)
        sim_ici = mesh.busy_time - flat.busy_time
        m = ServerModel(tp=tp, mesh_shape=(1, tp))
        # steady-state closed form: one full-batch prefill, then every
        # decode iteration at the full batch / full bucket mix
        modeled_ici = m.iteration_ici_time(tokens, dict(buckets)) \
            + n_dec * m.iteration_ici_time(b, dict(buckets))
        err = abs(modeled_ici - sim_ici) / sim_ici if sim_ici > 0 \
            else 0.0
        rows.append(emit(
            f"mesh/sim_iter_tp{tp}",
            mesh.busy_time / mesh.iterations * 1e6,
            f"iters={mesh.iterations} "
            f"sim_ici_us={sim_ici * 1e6:.1f} "
            f"modeled_ici_us={modeled_ici * 1e6:.1f} "
            f"ici_err={err:.3f}"))
    return rows


# -- real engine on a (1, tp) device mesh ----------------------------------

def _per_chip_param_mb(params) -> float:
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        shards = getattr(leaf, "addressable_shards", None)
        total += shards[0].data.nbytes if shards else leaf.nbytes
    return total / 2**20


def engine_rows(fast: bool):
    import jax

    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_engine_mesh
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ranks = {"a-r8": 8, "b-r64": 64}
    n_new = 8 if fast else 16
    rows = []
    for tp in TP_DEGREES:
        if tp > len(jax.devices()):
            continue            # needs --xla_force_host_platform_device_count
        mesh = make_engine_mesh(1, tp) if tp > 1 else None
        eng = ServingEngine(cfg, params, dict(ranks), max_batch=4,
                            max_len=8 + n_new + 4, bank_mode="bucketed",
                            lora_kernel="einsum", mesh=mesh)

        def run(base):
            for i in range(4):
                eng.submit(Request(base + i, ("a-r8", "b-r64")[i % 2],
                                   list(range(1, 9)), n_new))
            eng.run_until_drained()

        run(0)                  # warm the traces
        t0 = time.perf_counter()
        run(100)
        dt = time.perf_counter() - t0
        toks = 4 * n_new
        rows.append(emit(
            f"mesh/engine_tp{tp}", dt / toks * 1e6,
            f"tokens_per_s={toks / dt:.1f} "
            f"per_chip_param_mb={_per_chip_param_mb(eng.params):.2f}"))
    return rows


def run(fast: bool = True):
    return modeled_rows(fast) + sim_rows(fast) + engine_rows(fast)
