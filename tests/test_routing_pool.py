"""Routing table phi-weighting + distributed adapter pool invariants."""
import random

import pytest
from hypothesis_shim import given, settings, st

from repro.cluster import NetworkModel
from repro.core import AdapterInfo, DistributedAdapterPool, RoutingTable


def test_route_respects_phi():
    table = RoutingTable({"a": {0: 0.25, 1: 0.75}}, seed=7)
    counts = {0: 0, 1: 0}
    for _ in range(4000):
        counts[table.route("a")] += 1
    frac = counts[1] / 4000
    assert 0.70 < frac < 0.80


def test_route_counts_tracked():
    table = RoutingTable({"a": {0: 1.0}, "b": {1: 1.0}})
    for _ in range(5):
        table.route("a", tokens=10)
    table.route("b", tokens=3)
    assert table.request_counts == {"a": 5, "b": 1}
    counts = table.reset_counts()
    assert counts["a"] == 5 and table.request_counts == {}


def _mk_pool(n_servers=4, n_adapters=6):
    adapters = [AdapterInfo(f"a{i}", 8, nbytes=1000 * (i + 1))
                for i in range(n_adapters)]
    pool = DistributedAdapterPool(n_servers, adapters, NetworkModel())
    placement = {a.adapter_id: {i % n_servers: 1.0}
                 for i, a in enumerate(adapters)}
    pool.seed(placement)
    return pool, adapters, placement


def test_pool_hit_is_free_miss_pays_fetch():
    pool, adapters, placement = _mk_pool()
    home = next(iter(placement["a0"]))
    lat, nbytes = pool.ensure_local(home, "a0")
    assert lat == 0.0 and nbytes == 0
    # placement moves a0 to another server; first access there fetches
    other = (home + 1) % 4
    pool.apply_placement({**placement, "a0": {other: 1.0}})
    lat, nbytes = pool.ensure_local(other, "a0")
    assert lat > 0.0 and nbytes == adapters[0].nbytes
    # second access on the new server is now a hit
    lat2, _ = pool.ensure_local(other, "a0")
    assert lat2 == 0.0
    # fetch to a server NOT in the desired placement is transient: the
    # delete-after-copy step GC's it while the desired copy survives
    third = (home + 2) % 4
    pool.ensure_local(third, "a0")
    assert pool.check_invariant()
    assert other in pool.index["a0"]


def test_pool_gc_after_migration_keeps_one_copy():
    pool, adapters, placement = _mk_pool()
    home = next(iter(placement["a0"]))
    new_home = (home + 2) % 4
    pool.apply_placement({**placement, "a0": {new_home: 1.0}})
    pool.ensure_local(new_home, "a0")
    assert pool.index["a0"] == {new_home}   # old copy GC'd
    assert pool.check_invariant()


def test_pool_never_loses_sole_copy():
    pool, adapters, placement = _mk_pool()
    home = next(iter(placement["a1"]))
    # desired moves a1 elsewhere, but no access happens on the new server;
    # a hit on the old server must not evict the only copy
    pool.apply_placement({**placement, "a1": {(home + 1) % 4: 1.0}})
    lat, _ = pool.ensure_local(home, "a1")   # still a hit on the old home
    assert pool.check_invariant()
    assert len(pool.index["a1"]) >= 1


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_pool_invariant_under_random_ops(seed):
    rng = random.Random(seed)
    pool, adapters, placement = _mk_pool(n_servers=3, n_adapters=5)
    aids = [a.adapter_id for a in adapters]
    for _ in range(60):
        op = rng.random()
        if op < 0.7:
            pool.ensure_local(rng.randrange(3), rng.choice(aids))
        else:
            new_pl = {aid: {rng.randrange(3): 1.0} for aid in aids}
            pool.apply_placement(new_pl)
        assert pool.check_invariant()
    # accounting sanity
    assert pool.total_bytes() >= max(a.nbytes for a in adapters)
    assert pool.max_adapters_per_server() <= len(adapters)
