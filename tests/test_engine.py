"""Real-JAX serving engine: determinism vs direct decode, co-batching
isolation, drain behavior."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import Request, ServingEngine

ADAPTERS = {"a-r8": 8, "b-r64": 64}


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 48)
    return ServingEngine(cfg, params, ADAPTERS, **kw)


def test_engine_matches_direct_decode(setup):
    cfg, params = setup
    eng = _mk_engine(cfg, params)
    prompt = list(range(1, 9))
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = M.prefill(cfg, params, toks, bank=eng.bank,
                              lora_idx=jnp.asarray([0]), cache_len=48,
                              cache_dtype=jnp.float32)
    want = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        l2, cache = M.decode_step(cfg, params, cache,
                                  jnp.asarray([want[-1]], jnp.int32),
                                  bank=eng.bank,
                                  lora_idx=jnp.asarray([0]))
        want.append(int(jnp.argmax(l2[0])))
    req = Request(0, "a-r8", prompt, max_new_tokens=5,
                  arrival=time.monotonic())
    eng.submit(req)
    eng.run_until_drained()
    assert req.output == want


def test_cobatching_preserves_outputs(setup):
    """A request's tokens are identical whether decoded alone or
    co-batched with a different-rank adapter (the interference is a
    *performance* effect, never a numerical one)."""
    cfg, params = setup
    prompt_a = list(range(1, 9))
    prompt_b = list(range(3, 14))

    solo = _mk_engine(cfg, params)
    ra = Request(0, "a-r8", prompt_a, 5, arrival=time.monotonic())
    solo.submit(ra)
    solo.run_until_drained()

    both = _mk_engine(cfg, params)
    ra2 = Request(0, "a-r8", prompt_a, 5, arrival=time.monotonic())
    rb2 = Request(1, "b-r64", prompt_b, 5, arrival=time.monotonic())
    both.submit(ra2)
    both.submit(rb2)
    both.run_until_drained()
    assert ra2.output == ra.output


def test_engine_drains_and_reports_metrics(setup):
    cfg, params = setup
    eng = _mk_engine(cfg, params)
    now = time.monotonic()
    for i in range(6):
        eng.submit(Request(i, ["a-r8", "b-r64"][i % 2],
                           list(range(1, 8 + i)), 4, arrival=now))
    summ = eng.run_until_drained()
    assert summ["finished"] == 6
    assert summ["p95_ttft"] > 0
    assert eng.active == 0 and not eng.queue


def test_bank_max_rank_padding(setup):
    cfg, params = setup
    eng = _mk_engine(cfg, params)
    assert eng.max_rank == 64
    # bank A tensors padded to max rank
    a = eng.bank["q"]["A"]
    assert a.shape[-1] == 64
