"""Flight-recorder tracing layer: Perfetto export golden schema,
span-tree well-formedness, exact (sim) / sub-1% (engine) per-request
phase decomposition, sim-vs-engine span-name parity through the facade,
cost-model drift accounting, flight-recorder dump triggers, and the
Prometheus rendering of histograms + drift metrics."""
import copy
import json
import math
import os

import pytest

import jax

from repro.cluster import ClusterSimulator, NetworkModel
from repro.configs import get_smoke_config
from repro.controlplane import (ClusterController, ControllerConfig,
                                SLOSpec, TelemetryHub)
from repro.core import AdapterInfo, ServeRequest
from repro.models import model as M
from repro.obs import (REQUEST_PHASES, CostModelDrift, EventClock,
                       FlightRecorder, Span, Tracer, WallClock,
                       predict_span_seconds, record_request_spans,
                       to_perfetto, write_jsonl, write_perfetto)
from repro.serving import EngineBackend, LoRAServeCluster, SimBackend
from repro.server.prom import render_metrics
from repro.traces import make_adapters, synth_trace


# ---------------------------------------------------------------------
# workload helpers
# ---------------------------------------------------------------------
def _sim_run(n_servers=2, n_adapters=8, rps=6.0, duration=8.0, seed=3,
             controller=None, recorder=None, **sim_kw):
    adapters = make_adapters(n_adapters, seed=seed)
    trace = synth_trace(adapters, rps=rps, duration=duration,
                        prompt_len=96, output_len=24, seed=seed)
    tracer = Tracer(clock=EventClock())
    sim = ClusterSimulator(n_servers, adapters, policy="loraserve",
                           seed=seed, timeout=120.0, warmup=0.0,
                           rebalance_period=4.0, controller=controller,
                           tracer=tracer, flight_recorder=recorder,
                           **sim_kw)
    res = sim.run(trace)
    return res, tracer


def _facade_adapters():
    return [AdapterInfo("ea-r8", 8, nbytes=8 << 20),
            AdapterInfo("eb-r16", 16, nbytes=16 << 20)]


def _facade_trace(adapters, cfg=None, n=6, prompt_len=6, output_len=4):
    import random
    rng = random.Random(7)
    trace = []
    for i in range(n):
        a = adapters[i % len(adapters)]
        prompt = None
        if cfg is not None:
            prompt = [rng.randrange(1, cfg.vocab_size)
                      for _ in range(prompt_len)]
        trace.append(ServeRequest(
            req_id=i, adapter_id=a.adapter_id, rank=a.rank,
            prompt_len=prompt_len, output_len=output_len,
            prompt=prompt, arrival=0.15 * i))
    return trace


def _run_facade(backend, adapters, trace, tracer, recorder=None,
                controller=None):
    cluster = LoRAServeCluster(
        backend, adapters, policy="loraserve", network=NetworkModel(),
        rebalance_period=1e9, seed=0, controller=controller,
        tracer=tracer, flight_recorder=recorder)
    report = cluster.run(trace)
    return report, cluster


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------
# span-tree well-formedness + decomposition
# ---------------------------------------------------------------------
def _request_trees(tracer):
    """{req_id: (root_span, {phase: child_span})} for finished reqs."""
    trees = {}
    for rid, spans in tracer.by_request().items():
        roots = [s for s in spans if s.name == "request"]
        if not roots:
            continue
        assert len(roots) == 1
        kids = {s.name: s for s in spans
                if s.parent_id == roots[0].span_id}
        trees[rid] = (roots[0], kids)
    return trees


def test_sim_span_tree_and_exact_decomposition():
    res, tracer = _sim_run()
    assert res.completed() > 0 and tracer.n_spans > 0
    by_id = {s.span_id: s for s in tracer.spans}
    for s in tracer.spans:
        if s.parent_id is None:
            continue
        parent = by_id.get(s.parent_id)     # no orphans
        assert parent is not None
        assert parent.start - 1e-9 <= s.start   # child within parent
        assert s.end <= parent.end + 1e-9
    trees = _request_trees(tracer)
    assert len(trees) == res.completed()
    for root, kids in trees.values():
        assert set(kids) == set(REQUEST_PHASES)
        total = sum(kids[p].duration for p in REQUEST_PHASES)
        # sim: decomposition telescopes exactly (event-clock stamps)
        assert math.isclose(total, root.duration,
                            rel_tol=0, abs_tol=1e-9)
    # root duration is the measured arrival->finish interval
    fin = {r.req_id: r for r in res.requests if r.finish is not None
           and r.finish >= 0}
    for rid, (root, _kids) in trees.items():
        assert math.isclose(root.duration,
                            fin[rid].finish - fin[rid].arrival,
                            rel_tol=0, abs_tol=1e-9)


def test_engine_decomposition_within_one_percent(engine_setup):
    cfg, params = engine_setup
    adapters = _facade_adapters()
    be = EngineBackend(cfg, params, 2, max_batch=2, max_len=40, seed=0)
    tracer = Tracer(clock=WallClock())
    report, _ = _run_facade(be, adapters,
                            _facade_trace(adapters, cfg), tracer)
    assert report.completed() > 0
    trees = _request_trees(tracer)
    assert len(trees) == report.completed()
    for root, kids in trees.values():
        assert set(kids) == set(REQUEST_PHASES)
        total = sum(kids[p].duration for p in REQUEST_PHASES)
        assert root.duration > 0
        assert abs(total - root.duration) / root.duration < 0.01


def test_sim_vs_engine_span_name_parity(engine_setup):
    """Both substrates, driven through the same facade, must emit the
    same span vocabulary — the whole point of one tracing layer."""
    cfg, params = engine_setup
    adapters = _facade_adapters()

    t_sim = Tracer(clock=EventClock())
    _run_facade(SimBackend(2), copy.deepcopy(adapters),
                _facade_trace(adapters), t_sim)

    t_eng = Tracer(clock=WallClock())
    be = EngineBackend(cfg, params, 2, max_batch=2, max_len=40, seed=0)
    _run_facade(be, copy.deepcopy(adapters),
                _facade_trace(adapters, cfg), t_eng)

    names_sim = {s.name for s in t_sim.spans}
    names_eng = {s.name for s in t_eng.spans}
    assert names_sim == names_eng
    assert {"request", "route", *REQUEST_PHASES} <= names_sim


# ---------------------------------------------------------------------
# Perfetto / JSONL export
# ---------------------------------------------------------------------
def test_perfetto_golden_schema(tmp_path):
    res, tracer = _sim_run(duration=4.0)
    doc = to_perfetto(tracer)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    slices = [e for e in events if e["ph"] == "X"]
    metas = [e for e in events if e["ph"] == "M"]
    assert len(slices) == tracer.n_spans and metas
    for e in slices:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0       # microseconds
        assert "span_id" in e["args"]
    for m in metas:
        assert m["name"] == "process_name"
        assert "name" in m["args"]
    # round-trips through json and lands on disk
    path = os.path.join(tmp_path, "run.perfetto.json")
    n = write_perfetto(tracer, path)
    with open(path) as f:
        again = json.load(f)
    assert n == tracer.n_spans
    assert len(again["traceEvents"]) == len(events)


def test_jsonl_export_round_trip(tmp_path):
    _res, tracer = _sim_run(duration=3.0)
    path = os.path.join(tmp_path, "spans.jsonl")
    n = write_jsonl(tracer, path)
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    assert len(rows) == n == tracer.n_spans
    names = {r["name"] for r in rows}
    assert {"request", *REQUEST_PHASES} <= names
    for r in rows:
        assert r["end"] >= r["start"]


# ---------------------------------------------------------------------
# cost-model drift
# ---------------------------------------------------------------------
def test_sim_drift_is_zero_validating_the_plumbing():
    """Sim iteration spans carry the exact time the simulator charged,
    so modeled==measured up to float noise — any real bias here means
    the pairing (not the model) is broken."""
    res, _tracer = _sim_run()
    drift = res.cost_drift
    assert set(drift) >= {"prefill", "decode"}
    for phase in ("prefill", "decode"):
        d = drift[phase]
        assert d["count"] > 0 and d["modeled_s"] > 0
        assert abs(d["bias"]) < 1e-9
        assert d["mean_abs_rel_err"] < 1e-9


def test_engine_drift_pairs_measured_with_model(engine_setup):
    cfg, params = engine_setup
    adapters = _facade_adapters()
    be = EngineBackend(cfg, params, 1, max_batch=2, max_len=40, seed=0)
    tracer = Tracer(clock=WallClock())
    report, _ = _run_facade(be, adapters,
                            _facade_trace(adapters, cfg), tracer)
    drift = report.cost_drift
    assert set(drift) >= {"prefill", "decode"}
    for phase in ("prefill", "decode"):
        d = drift[phase]
        assert d["count"] > 0
        assert d["modeled_s"] > 0 and d["measured_s"] > 0
        assert math.isfinite(d["bias"])


def test_predict_span_seconds_shapes():
    from repro.cluster.costmodel import ServerModel
    model = ServerModel()
    pre = Span("prefill", 0.0, 1.0, cat="iteration", track="server:0",
               attrs={"tokens": 256, "max_rank": 16, "batch": 2,
                      "bank_mode": "padded"})
    dec = Span("decode", 0.0, 1.0, cat="iteration", track="server:0",
               attrs={"batch": 2, "max_rank": 16, "steps": 4,
                      "iters": 4, "bank_mode": "padded"})
    p, d = predict_span_seconds(model, pre), predict_span_seconds(
        model, dec)
    assert p and math.isclose(p, model.prefill_time(256, 16))
    assert d and math.isclose(d, 4 * model.decode_time(2, 16, steps=4))
    # precomputed prediction (sim path) wins over shape-based
    pre.attrs["predicted"] = 0.123
    assert predict_span_seconds(model, pre) == 0.123
    # non-iteration shapes yield None
    assert predict_span_seconds(
        model, Span("route", 0.0, 0.0, cat="gateway",
                    track="control")) is None


# ---------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------
def test_flight_recorder_dumps_on_forced_slo_violation(tmp_path):
    """An impossible TTFT target forces a violation; the recorder must
    produce an audit record carrying the controller's decision inputs
    and the recent-span ring."""
    ctrl = ClusterController(
        SLOSpec(ttft=1e-4, target=0.99, window=10.0),
        ControllerConfig(tick_period=0.5, min_samples=1, patience=1,
                         max_servers=3))
    rec = FlightRecorder(capacity=512, out_dir=str(tmp_path),
                         min_interval=0.0)
    res, _tracer = _sim_run(controller=ctrl, recorder=rec)
    assert res.completed() > 0
    assert rec.n_dumps >= 1 and res.flight_dumps == rec.n_dumps
    reasons = {d["reason"] for d in rec.dumps}
    assert reasons & {"slo-violation", "scale-up"}
    by_reason = {d["reason"]: d for d in rec.dumps}
    d = by_reason.get("slo-violation") or by_reason["scale-up"]
    audit = d["audit"]
    for key in ("now", "violated", "attainment", "window_samples",
                "windowed_p95_ttft", "demand_servers"):
        assert key in audit
    assert d["spans"], "ring was empty at dump time"
    # on-disk artifacts: span dump + audit json per event
    files = sorted(os.listdir(tmp_path))
    assert any(f.endswith(".perfetto.json") for f in files)
    assert any(f.endswith(".audit.json") for f in files)
    apath = next(f for f in files if f.endswith(".audit.json"))
    with open(os.path.join(tmp_path, apath)) as f:
        on_disk = json.load(f)
    assert on_disk["reason"] in reasons and "spans" not in on_disk


def test_flight_recorder_ring_rate_limit_and_cap():
    rec = FlightRecorder(capacity=4, min_interval=5.0, max_dumps=2)
    for i in range(10):
        rec.observe(Span(f"s{i}", float(i), i + 0.5, track="t"))
    d0 = rec.dump("first", now=100.0)
    assert d0 is not None
    assert len(d0["spans"]) == 4          # ring kept only the newest 4
    assert d0["spans"][-1]["name"] == "s9"
    assert rec.dump("too-soon", now=101.0) is None   # rate-limited
    assert rec.suppressed == 1
    assert rec.dump("second", now=200.0) is not None
    assert rec.dump("over-cap", now=300.0) is None   # max_dumps hit
    assert rec.n_dumps == 2


def test_record_request_spans_skips_unfinished():
    t = Tracer(clock=EventClock())
    r = ServeRequest(req_id=0, adapter_id="a", rank=8, prompt_len=4,
                     output_len=4, arrival=1.0)
    assert record_request_spans(t, r) is None and t.n_spans == 0


# ---------------------------------------------------------------------
# /metrics rendering: histograms + drift families
# ---------------------------------------------------------------------
def test_prom_renders_histograms_and_drift():
    adapters = _facade_adapters()
    tracer = Tracer(clock=EventClock())
    report, cluster = _run_facade(SimBackend(2), adapters,
                                  _facade_trace(adapters, n=10), tracer)
    assert report.completed() > 0
    hub = cluster.hub
    text = render_metrics(report, hub.snapshot(cluster.clock()),
                          {"state": "serving"})
    assert "# TYPE repro_ttft_seconds histogram" in text
    assert 'repro_ttft_seconds_bucket{le="+Inf"}' in text
    assert "repro_ttft_seconds_sum" in text
    assert "repro_ttft_seconds_count" in text
    assert 'repro_costmodel_seconds_total{kind="modeled",phase="prefill"}' \
        in text
    assert 'repro_costmodel_drift_ratio{phase="decode"}' in text
    assert 'repro_costmodel_mean_abs_rel_err{phase="prefill"}' in text
    # bucket counts are cumulative and end at the total count
    lines = [ln for ln in text.splitlines()
             if ln.startswith("repro_ttft_seconds_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts)
    total = int(next(ln for ln in text.splitlines() if ln.startswith(
        "repro_ttft_seconds_count")).rsplit(" ", 1)[1])
    assert counts[-1] == total == hub.ttft_hist.count


def test_prom_omits_empty_histograms_and_drift():
    from repro.serving import ClusterReport
    empty = ClusterReport(results=[], summary={}, rebalances=0,
                          placements=[], per_server_counts=[],
                          timed_out=0, fetches=0, fetch_bytes=0,
                          max_adapters_per_server=0,
                          total_adapter_bytes=0, memory_profile=[])
    hub = TelemetryHub()
    text = render_metrics(empty, hub.snapshot(0.0),
                          {"state": "serving"})
    assert "repro_ttft_seconds_bucket" not in text
    assert "repro_costmodel" not in text
