"""Training substrate: learning, LoRA-freeze semantics, checkpoint
roundtrip, optimizer math."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.lora.adapter import init_adapter
from repro.models import model as M
from repro.training import (AdamWConfig, adamw_init, adamw_update,
                            global_norm, load_checkpoint,
                            make_lora_train_step, make_train_step,
                            save_checkpoint)


def test_loss_decreases():
    cfg = get_smoke_config("stablelm-1.6b")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    oc = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                     weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, oc))
    it = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8)).batches()
    losses = []
    for _ in range(40):
        t, l = next(it)
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(t),
                               "labels": jnp.asarray(l)})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_lora_training_freezes_base():
    cfg = get_smoke_config("llama-7b-paper")
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    adapter = init_adapter(cfg, 8, key)
    opt = adamw_init(adapter)
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    step = jax.jit(make_lora_train_step(cfg, oc))
    it = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4)).batches()
    base_before = jax.tree.map(lambda x: x.copy(), params)
    a0 = jax.tree.map(lambda x: x.copy(), adapter)
    for _ in range(3):
        t, l = next(it)
        adapter, opt, m = step(adapter, opt, params,
                               {"tokens": jnp.asarray(t),
                                "labels": jnp.asarray(l)})
    # base unchanged, adapter B matrices moved off zero
    for a, b in zip(jax.tree.leaves(base_before), jax.tree.leaves(params)):
        assert bool(jnp.array_equal(a, b))
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(a0), jax.tree.leaves(adapter)))
    assert moved


def test_adamw_clipping():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    opt = adamw_init(p)
    cfg = AdamWConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0,
                      weight_decay=0.0)
    p2, opt2, m = adamw_update(cfg, g, opt, p)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert bool(jnp.all(p2["w"] < p["w"]))
    assert int(opt2["step"]) == 1


def test_trainable_mask_freezes():
    p = {"a": jnp.ones((2,)), "b": jnp.ones((2,))}
    g = {"a": jnp.ones((2,)), "b": jnp.ones((2,))}
    opt = adamw_init(p)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0)
    mask = {"a": True, "b": False}
    p2, _, _ = adamw_update(cfg, g, opt, p, trainable_mask=mask)
    assert bool(jnp.all(p2["a"] != p["a"]))
    assert bool(jnp.array_equal(p2["b"], p["b"]))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("internlm2-1.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, state)
    restored = load_checkpoint(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    c = DataConfig(vocab_size=128, seq_len=16, batch_size=2, seed=3)
    a1 = next(SyntheticLM(c).batches())
    a2 = next(SyntheticLM(c).batches())
    np.testing.assert_array_equal(a1[0], a2[0])
    # labels are next-token shifted
    np.testing.assert_array_equal(a1[0][:, 1:], a1[1][:, :-1])
