"""Pallas flash-attention kernel vs oracle: shape/dtype/blocking sweeps
in interpret mode + causal block-skip semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_mha, flash_mha_ref


@pytest.mark.parametrize("B,H,Sq,Sk,hd,bq,bk", [
    (1, 2, 64, 64, 32, 32, 32),
    (2, 4, 100, 100, 64, 32, 64),     # ragged sequence vs block
    (1, 1, 128, 256, 32, 64, 64),     # cross-length (kv longer)
    (2, 2, 33, 33, 16, 16, 16),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, H, Sq, Sk, hd, bq, bk, causal, dtype):
    if causal and Sk != Sq:
        pytest.skip("causal test uses square attention")
    key = jax.random.PRNGKey(B * 100 + Sq)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, hd)).astype(dtype)
    out = flash_mha(q, k, v, causal=causal, block_q=bq, block_k=bk,
                    interpret=True)
    ref = flash_mha_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_flash():
    """Cross-check against the pure-JAX chunked flash used by the model
    zoo (same math, different layout)."""
    from repro.models.common import flash_attention
    key = jax.random.PRNGKey(7)
    B, S, H, hd = 2, 96, 4, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    model_out = flash_attention(q, k, v, causal=True,
                                q_positions=jnp.arange(S),
                                k_positions=jnp.arange(S),
                                chunk_q=32, chunk_k=32)
    kern_out = flash_mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True,
                         block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out.transpose(0, 2, 1, 3)),
                               np.asarray(model_out), atol=1e-4, rtol=1e-4)


def test_causal_first_token_attends_self_only():
    key = jax.random.PRNGKey(0)
    B, H, S, hd = 1, 1, 32, 16
    q = jax.random.normal(key, (B, H, S, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    out = flash_mha(q, k, v, causal=True, block_q=16, block_k=16,
                    interpret=True)
    # position 0 output == v[0] exactly (softmax over a single key)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]),
                               np.asarray(v[0, 0, 0]), atol=1e-5)
