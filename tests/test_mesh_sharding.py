"""Mesh-sharded serving: cost-model ICI terms (in-process) and token
parity of the (dp, tp)-sharded engine against the single-device engine
(subprocess — the suite's conftest pins this process to ONE CPU device,
so the 8-host-device mesh runs in its own interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
import os
import subprocess
import sys

from repro.cluster.costmodel import ICI_LATENCY, ServerModel

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# -- ICI collective terms ------------------------------------------------

def test_ici_zero_without_mesh_and_at_tp1():
    legacy = ServerModel(tp=4)                       # abstract TP only
    assert legacy.ici_collective_time(1e9) == 0.0
    assert legacy.iteration_ici_time(4096, {64: 4096}) == 0.0
    tp1 = ServerModel(tp=4, mesh_shape=(2, 1))       # dp-only mesh
    assert tp1.ici_collective_time(1e9) == 0.0
    # a trivial mesh changes nothing about iteration times
    assert legacy.prefill_time(4096, 64) == \
        ServerModel(tp=4, mesh_shape=(1, 1)).prefill_time(4096, 64)


def test_ici_monotone_in_bytes_and_tp():
    m = ServerModel(tp=4, mesh_shape=(1, 4))
    ts = [m.ici_collective_time(b) for b in (0, 1e6, 1e7, 1e8)]
    assert all(a < b for a, b in zip(ts, ts[1:]))
    assert ts[0] == 2 * 3 * ICI_LATENCY              # latency floor only
    # ring all-reduce moves 2(tp-1)/tp of the buffer: more shards move
    # a larger fraction (1.5x at tp=4 vs 1.0x at tp=2)
    m2 = ServerModel(tp=2, mesh_shape=(1, 2))
    big = 1e9
    assert m.ici_collective_time(big) > m2.ici_collective_time(big)


def test_ici_terms_enter_iteration_times():
    base = ServerModel(tp=4)
    mesh = ServerModel(tp=4, mesh_shape=(1, 4))
    assert mesh.prefill_time(4096, 64) > base.prefill_time(4096, 64)
    assert mesh.decode_time(32, 64) > base.decode_time(32, 64)
    assert mesh.prefill_time_bucketed({8: 2048, 64: 2048}) > \
        base.prefill_time_bucketed({8: 2048, 64: 2048})
    # the LoRA psum term scales with rank, not d_model: bucketed charges
    # each bucket at its own rank
    lo = mesh.iteration_ici_time(4096, {8: 4096})
    hi = mesh.iteration_ici_time(4096, {128: 4096})
    assert lo < hi


def test_sim_backend_mesh_shape_builds_sharded_server_model():
    from repro.serving.backend import SimBackend
    b = SimBackend(2, mesh_shape=(2, 4))
    assert b.model.mesh_shape == (2, 4)
    assert b.model.tp == 4 and b.model.tp_degree == 4
    assert b.model.dp_degree == 2


# -- sharded engine token parity (subprocess, 8 host devices) ------------

PARITY_SCRIPT = r"""
import time

import jax

assert len(jax.devices()) == 8, jax.devices()

from repro.configs import get_smoke_config
from repro.launch.mesh import make_engine_mesh
from repro.models import model as M
from repro.serving import Request, ServingEngine

cfg = get_smoke_config("llama-7b-paper")
params = M.init_params(cfg, jax.random.PRNGKey(0))
RANKS = {"a-r8": 8, "b-r64": 64}


def outputs(eng):
    return {r.req_id: tuple(r.output)
            for r in eng.completed + eng.drain_completed()}


def run(mesh, bank_mode, kern, decode_block=1):
    # Full lifecycle on one engine: batched prefill, decode, a
    # mid-flight adapter install (requests still co-batched), more
    # traffic on the installed adapter, then an evict + post-evict
    # rebuild traffic.
    eng = ServingEngine(cfg, params, dict(RANKS), max_batch=4,
                        max_len=40, bank_mode=bank_mode,
                        lora_kernel=kern, decode_block=decode_block,
                        mesh=mesh)
    now = time.monotonic()
    for i in range(4):
        eng.submit(Request(i, ["a-r8", "b-r64"][i % 2],
                           list(range(1, 9)), 5, arrival=now))
    eng.step()            # prefill admission
    eng.step()            # some decode progress, slots still live
    assert eng.install_adapter("c-r16", 16)     # mid-flight rebuild
    eng.submit(Request(10, "c-r16", list(range(2, 10)), 5, arrival=now))
    eng.run_until_drained()
    assert eng.evict_adapter("c-r16")           # mid-run shrink
    eng.submit(Request(11, "b-r64", list(range(3, 11)), 5, arrival=now))
    eng.run_until_drained()
    return outputs(eng)


mesh = make_engine_mesh(2, 4)
cases = [("padded", "einsum", 1), ("bucketed", "einsum", 1),
         ("bucketed", "einsum", 4), ("bucketed", "sgmv", 1)]
for bank_mode, kern, k in cases:
    ref = run(None, bank_mode, kern, k)
    out = run(mesh, bank_mode, kern, k)
    assert ref == out, (bank_mode, kern, k, ref, out)
    print(f"parity ok: {bank_mode}/{kern}/k={k} n={len(ref)}")
print("PARITY_OK")
"""


def test_mesh_sharded_engine_token_parity():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", PARITY_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=1800)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "PARITY_OK" in proc.stdout
