"""SGMV v2: fused shrink+expand and single-dispatch bucketed kernels
(bit-identical to the legacy two-kernel / host-loop paths), bucket-major
segment prep, the engine's fused multi-token decode (`decode_steps`),
batched prefill admission, and the mirrored cost-model terms."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (prepare_segments_bucketed, sgmv,
                           sgmv_bucketed_fused, sgmv_fused,
                           sgmv_rank_bucketed, sgmv_reference)
from repro.kernels.ops import padded_len

# ---------------------------------------------------------------------------
# sgmv_fused vs sgmv vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,d,r,do,Na,bt", [
    (7, 128, 8, 128, 2, 8),
    (63, 512, 64, 256, 5, 16),
    (16, 128, 128, 1024, 3, 4),     # d_out > block_o exercises n_ob > 1
    (1, 128, 8, 128, 1, 8),
    (48, 384, 32, 384, 6, 1),       # bt=1 == BGMV
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sgmv_fused_matches_unfused_bitwise(T, d, r, do, Na, bt, dtype):
    key = jax.random.PRNGKey(T * 7 + d)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d)).astype(dtype)
    A = (jax.random.normal(ks[1], (Na, d, r)) * 0.05).astype(dtype)
    B = (jax.random.normal(ks[2], (Na, r, do)) * 0.05).astype(dtype)
    aid = jax.random.randint(ks[3], (T,), 0, Na)
    y_u = np.asarray(sgmv(x, A, B, aid, block_t=bt, interpret=True))
    y_f = np.asarray(sgmv_fused(x, A, B, aid, block_t=bt, interpret=True))
    np.testing.assert_array_equal(y_u, y_f)   # the fused contract
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    y_r = np.asarray(sgmv_reference(x, A, B, aid), np.float32)
    np.testing.assert_allclose(np.asarray(y_f, np.float32), y_r,
                               atol=tol, rtol=tol)


def _mixed_setup(seed=3, T=29, d=128, do=256):
    """3 buckets (ranks 8/16/64), 5 adapters, ragged token mix; returns
    compact per-bucket banks + the equivalent max-rank padded bank."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    x = jax.random.normal(ks[0], (T, d))
    banks, Apad, Bpad = [], [], []
    ranks = [8, 16, 64]
    members = [[0, 2], [3], [1, 4]]       # adapter -> bucket layout
    for b, r in enumerate(ranks):
        n = len(members[b])
        A = jax.random.normal(ks[2 * b + 1], (n, d, r)) * 0.1
        B = jax.random.normal(ks[2 * b + 2], (n, r, do)) * 0.1
        banks.append((A, B))
    bucket = np.zeros(5, np.int32)
    local = np.zeros(5, np.int32)
    pad_a, pad_b = [None] * 5, [None] * 5
    for b, mem in enumerate(members):
        for j, aid in enumerate(mem):
            bucket[aid], local[aid] = b, j
            A, B = banks[b]
            pad_a[aid] = jnp.pad(A[j], ((0, 0), (0, 64 - ranks[b])))
            pad_b[aid] = jnp.pad(B[j], ((0, 64 - ranks[b]), (0, 0)))
    aid = jax.random.randint(ks[7], (T,), 0, 5)
    return (x, banks, (jnp.stack(pad_a), jnp.stack(pad_b)), aid,
            jnp.asarray(bucket), jnp.asarray(local))


@pytest.mark.parametrize("block_t", [16, 8, 1])   # 1 == decode (BGMV)
def test_bucketed_fused_bit_identical_to_host_loop(block_t):
    x, banks, (Apad, Bpad), aid, bucket, local = _mixed_setup()
    y_host = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                                block_t=block_t, interpret=True)
    y_dev = sgmv_bucketed_fused(x, banks, aid, bucket, local,
                                block_t=block_t, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_host), np.asarray(y_dev))
    y_r = sgmv_reference(x, Apad, Bpad, aid)
    np.testing.assert_allclose(np.asarray(y_dev), np.asarray(y_r),
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucketed_fused_dtypes(dtype):
    x, banks, _, aid, bucket, local = _mixed_setup()
    x = x.astype(dtype)
    banks = [(A.astype(dtype), B.astype(dtype)) for A, B in banks]
    y_host = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                                interpret=True)
    y_dev = sgmv_bucketed_fused(x, banks, aid, bucket, local,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(y_host), np.asarray(y_dev))


def test_bucketed_fused_full_width_banks():
    """adapter_local=None: every bucket bank indexed by the global id."""
    key = jax.random.PRNGKey(2)
    A8 = jax.random.normal(key, (3, 128, 8)) * 0.1
    B8 = jax.random.normal(key, (3, 8, 256)) * 0.1
    A64 = jax.random.normal(key, (3, 128, 64)) * 0.1
    B64 = jax.random.normal(key, (3, 64, 256)) * 0.1
    bucket = jnp.array([0, 1, 0])
    x = jax.random.normal(key, (24, 128))
    aid = jax.random.randint(key, (24,), 0, 3)
    banks = [(A8, B8), (A64, B64)]
    y_host = sgmv_rank_bucketed(x, banks, aid, bucket, interpret=True)
    y_dev = sgmv_bucketed_fused(x, banks, aid, bucket, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_host), np.asarray(y_dev))


def test_bucketed_fused_empty_bucket_and_scaling():
    x, banks, (Apad, Bpad), _, bucket, local = _mixed_setup()
    aid = jnp.full((x.shape[0],), 1, jnp.int32)    # only the rank-64 one
    y = sgmv_bucketed_fused(x, banks, aid, bucket, local, scaling=2.0,
                            interpret=True)
    y_r = sgmv_reference(x, Apad, Bpad, aid, scaling=2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), atol=1e-4)


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):                 # closed sub-jaxprs
                n += _count_pallas_calls(v.jaxpr)
            elif hasattr(v, "eqns"):
                n += _count_pallas_calls(v)
    return n


def test_bucketed_fused_single_traced_dispatch():
    """The whole heterogeneous delta is ONE pallas_call, traceable with
    an abstract token_adapter (no host sync, no per-bucket host loop)."""
    x, banks, _, aid, bucket, local = _mixed_setup()

    def f(x, aid):
        return sgmv_bucketed_fused(x, banks, aid, bucket, local,
                                   interpret=True)

    jaxpr = jax.make_jaxpr(f)(x, aid)    # aid abstract: device-resident
    assert _count_pallas_calls(jaxpr.jaxpr) == 1
    # and the legacy host-loop path is indeed not traceable
    with pytest.raises(Exception):
        jax.make_jaxpr(lambda x, a: sgmv_rank_bucketed(
            x, banks, a, bucket, adapter_local=local, interpret=True)
        )(x, aid)


def test_prepare_segments_bucketed_properties():
    """dest injective; blocks homogeneous per adapter; bucket-major:
    occupied blocks are sorted by (bucket, adapter)."""
    key = jax.random.PRNGKey(11)
    Na, bt, T = 6, 8, 57
    aid = jax.random.randint(key, (T,), 0, Na)
    bucket_of = jnp.asarray([0, 2, 0, 1, 2, 1], jnp.int32)
    dest, block_adapter = prepare_segments_bucketed(aid, bucket_of, Na, 3,
                                                    bt)
    dest, ba = np.asarray(dest), np.asarray(block_adapter)
    aid_np = np.asarray(aid)
    assert len(set(dest.tolist())) == T
    assert dest.max() < padded_len(T, Na, bt)
    blocks = dest // bt
    for t in range(T):
        assert ba[blocks[t]] == aid_np[t]
    occupied = sorted(set(blocks.tolist()))
    keys = [(int(bucket_of[ba[b]]), int(ba[b])) for b in occupied]
    assert keys == sorted(keys)


# ---------------------------------------------------------------------------
# Engine: fused multi-token decode + batched prefill admission
# ---------------------------------------------------------------------------

ADAPTERS = {"a-r8": 8, "b-r64": 64}


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_engine(cfg, params, decode_block, bank_mode, rebuild_at=None,
                prompts=None):
    from repro.serving import Request, ServingEngine
    eng = ServingEngine(cfg, params, dict(ADAPTERS), max_batch=4,
                        max_len=40, bank_mode=bank_mode,
                        decode_block=decode_block)
    now = time.monotonic()
    prompts = prompts or [list(range(1, 8 + i)) for i in range(4)]
    reqs = [Request(i, ["a-r8", "b-r64"][i % 2], p, 5 + i % 3, arrival=now)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    it = 0
    while eng.queue or eng.active:
        eng.step()
        it += 1
        if rebuild_at is not None and it == rebuild_at:
            eng.load_adapters({"c-r16": 16})
    return [r.output for r in reqs], eng


@pytest.mark.parametrize("bank_mode", ["padded", "bucketed"])
def test_decode_steps_token_identical(setup, bank_mode):
    cfg, params = setup
    out1, e1 = _run_engine(cfg, params, 1, bank_mode)
    out8, e8 = _run_engine(cfg, params, 8, bank_mode)
    assert out1 == out8
    assert e1.tokens_decoded == e8.tokens_decoded
    # the point of the fusion: >= 4x fewer host dispatches per token
    assert e8.decode_dispatches * 4 <= e1.decode_dispatches


@pytest.mark.parametrize("bank_mode", ["padded", "bucketed"])
def test_decode_steps_survives_bank_rebuild(setup, bank_mode):
    """A mid-flight load_adapters (bank reshape + slot remap) between
    fused blocks leaves token streams identical to the k=1 engine."""
    cfg, params = setup
    out1, _ = _run_engine(cfg, params, 1, bank_mode, rebuild_at=3)
    out4, e4 = _run_engine(cfg, params, 4, bank_mode, rebuild_at=1)
    assert out1 == out4
    assert e4.bank_rebuilds == 1


def test_decode_steps_exhausted_budget_finishes(setup):
    """Regression: a slot admitted with no decode budget left
    (max_new_tokens=1 — prefill already produced its token) must still
    decode-and-finish under decode_block>1 instead of leaking the slot
    and livelocking run_until_drained."""
    from repro.serving import Request, ServingEngine
    cfg, params = setup
    outs = []
    for k in (1, 8):
        eng = ServingEngine(cfg, params, dict(ADAPTERS), max_batch=2,
                            max_len=40, decode_block=k)
        req = Request(0, "a-r8", [1, 2, 3], 1, arrival=time.monotonic())
        eng.submit(req)
        eng.run_until_drained(max_iters=50)
        assert eng.active == 0 and not eng.queue
        outs.append(req.output)
    assert outs[0] == outs[1]


def test_sim_decode_block_amortizes_dispatch_floor():
    """The simulator mirrors the engine's fused decode: decode_block=k
    charges ITER_OVERHEAD once per k-token dispatch."""
    from repro.cluster.costmodel import ServerModel
    from repro.cluster.server import SimServer
    from repro.serving.backend import SimBackend

    m = ServerModel()
    reqs = type("R", (), {"rank": 8, "remote_penalty": 0.0,
                          "remote_until": 0.0})
    s1 = SimServer(0, m)
    s8 = SimServer(0, m, decode_block=8)
    assert s8._decode_cost([reqs()]) < s1._decode_cost([reqs()])
    b = SimBackend(2, decode_block=8)
    assert all(sv.decode_block == 8 for sv in b.servers)
    b.add_server()
    assert b.servers[-1].decode_block == 8


def test_batched_prefill_admission(setup):
    """Same-length queued prompts prefill in ONE dispatch; token streams
    match the solo (one-request) engine."""
    cfg, params = setup
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9, 10, 11], [2, 4, 6, 8, 10],
               [9, 8, 7, 6, 5, 4]]
    outs, eng = _run_engine(cfg, params, 1, "padded", prompts=prompts)
    # 4 admitted requests, 2 distinct lengths -> 2 prefill dispatches
    assert eng.prefill_dispatches == 2
    solo, _ = _run_engine(cfg, params, 1, "padded", prompts=[prompts[0]])
    assert outs[0] == solo[0]


# ---------------------------------------------------------------------------
# Cost model mirrors
# ---------------------------------------------------------------------------


def test_costmodel_fused_terms():
    from repro.cluster.costmodel import ITER_OVERHEAD, make_server
    s = make_server()
    # the calibration IS the fused path; legacy dispatchers cost extra
    assert s.prefill_time(2048, 64, fused=False) > s.prefill_time(2048, 64)
    assert s.decode_time(32, 64, fused=False) > s.decode_time(32, 64)
    # host-loop bucketed dispatch pays per-bucket launches
    two = s.decode_time_bucketed({8: 16, 64: 16}, fused=False)
    one = s.decode_time_bucketed({64: 32}, fused=False)
    assert two - s.decode_time_bucketed({8: 16, 64: 16}) > \
        one - s.decode_time_bucketed({64: 32})
    # decode_steps(k): dispatch floor amortized over k tokens
    t1 = s.decode_time(32, 64)
    t8 = s.decode_time(32, 64, steps=8)
    assert np.isclose(t1 - t8, ITER_OVERHEAD * (1 - 1 / 8))
    b8 = s.decode_time_bucketed({8: 16, 64: 16}, steps=8)
    assert b8 < s.decode_time_bucketed({8: 16, 64: 16})


# ---------------------------------------------------------------------------
# LoRA callback kernel=sgmv path (model-level)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bank_mode", ["padded", "bucketed"])
def test_lora_cb_sgmv_kernel_matches_einsum(setup, bank_mode):
    from repro.lora.bank import build_bank
    from repro.models import model as M
    cfg, params = setup
    bank = build_bank(cfg, dict(ADAPTERS), jax.random.PRNGKey(1),
                      mode=bank_mode, n_layers=cfg.n_layers)
    toks = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    idx = bank.lora_idx(jnp.asarray([0, 1], jnp.int32))
    le, ce = M.prefill(cfg, params, toks, bank=bank.data, lora_idx=idx,
                       cache_len=8)
    lk, ck = M.prefill(cfg, params, toks, bank=bank.data, lora_idx=idx,
                       cache_len=8, lora_kernel="sgmv")
    np.testing.assert_allclose(np.asarray(le), np.asarray(lk), atol=1e-5)
    nxt = jnp.argmax(le, axis=-1).astype(jnp.int32)
    l2e, _ = M.decode_step(cfg, params, ce, nxt, bank=bank.data,
                           lora_idx=idx)
    l2k, _ = M.decode_step(cfg, params, ck, nxt, bank=bank.data,
                           lora_idx=idx, lora_kernel="sgmv")
    np.testing.assert_allclose(np.asarray(l2e), np.asarray(l2k),
                               atol=1e-5)
