"""End-to-end system tests: orchestrator + real engines (mini cluster),
and the full simulated paper pipeline."""
import copy
import random
import time

import jax
import pytest

from repro.cluster import (ClusterSimulator, NetworkModel, ServerModel,
                           profile_operating_points)
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ClusterOrchestrator
from repro.models import model as M
from repro.serving import Request, ServingEngine
from repro.traces import make_adapters, production_trace


def test_mini_cluster_end_to_end():
    """Real JAX engines behind the paper's orchestrator: route requests,
    fetch adapters through the pool, drain, verify invariants."""
    rng = random.Random(0)
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    adapters = [AdapterInfo(f"ad{i}-r{r}", r, nbytes=r * 1000)
                for i, r in enumerate([8, 8, 64, 128])]
    ranks = {a.adapter_id: a.rank for a in adapters}
    ops = profile_operating_points(ServerModel(),
                                   {a.rank for a in adapters})
    orch = ClusterOrchestrator(2, adapters, ops, policy="loraserve",
                               network=NetworkModel(), seed=0)
    engines = [ServingEngine(cfg, params, ranks, max_batch=2, max_len=32)
               for _ in range(2)]
    for i in range(8):
        aid = rng.choice(adapters).adapter_id
        sid, _ = orch.route(aid, tokens=16)
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(8)]
        engines[sid].submit(Request(i, aid, prompt, 4,
                                    arrival=time.monotonic()))
    total = 0
    for eng in engines:
        summ = eng.run_until_drained()
        total += summ["finished"]
    assert total == 8
    assert orch.pool.check_invariant()
    orch.end_of_timestep(10.0)
    assert orch.pool.check_invariant()


def test_production_trace_pipeline():
    """Paper §V-F setup in miniature: production-like trace, 4 servers,
    LORASERVE completes within SLO while contiguous static placement
    struggles."""
    adapters = make_adapters(50, seed=1)
    trace = production_trace(50, rps=18, duration=120, seed=2)
    lora = ClusterSimulator(4, adapters, policy="loraserve", seed=3,
                            warmup=30).run(copy.deepcopy(trace))
    cont = ClusterSimulator(4, adapters, policy="slora-contiguous",
                            seed=3, warmup=30).run(copy.deepcopy(trace))
    assert lora.timed_out == 0
    assert lora.p95_ttft() <= cont.p95_ttft() * 1.5


def test_dryrun_importable_without_flag_leak():
    """Importing launch modules must not set the 512-device flag
    globally (only executing dryrun as __main__ may)."""
    import repro.launch.mesh  # noqa: F401
    import repro.launch.specs  # noqa: F401
    assert len(jax.devices()) == 1
