"""Cluster simulator end-to-end behavior + paper-claim directions."""
import copy

import pytest

from repro.cluster import ClusterSimulator
from repro.traces import make_adapters, synth_trace


@pytest.fixture(scope="module")
def setup():
    adapters = make_adapters(60, seed=1)
    trace = synth_trace(adapters, rps=22, duration=150,
                        popularity="exponential", seed=2)
    return adapters, trace


def _run(adapters, trace, policy, n=4):
    sim = ClusterSimulator(n, adapters, policy=policy, seed=3,
                           timeout=60, warmup=40)
    return sim.run(copy.deepcopy(trace))


def test_all_policies_complete(setup):
    adapters, trace = setup
    for pol in ["loraserve", "slora-random", "slora-contiguous",
                "toppings"]:
        res = _run(adapters, trace, pol)
        assert res.completed() + res.timed_out == len(trace)
        assert res.p50_ttft() >= 0


def test_loraserve_beats_random_on_skewed_trace(setup):
    """Paper Fig 19 direction: LORASERVE's P95 TTFT beats S-LoRA Random
    under skewed popularity."""
    adapters, trace = setup
    lora = _run(adapters, trace, "loraserve")
    rand = _run(adapters, trace, "slora-random")
    assert lora.p95_ttft() < rand.p95_ttft()


def test_loraserve_memory_beats_toppings(setup):
    """Paper Fig 18-bottom: Toppings replicates every adapter everywhere;
    LORASERVE stores only what each server needs."""
    adapters, trace = setup
    lora = _run(adapters, trace, "loraserve")
    top = _run(adapters, trace, "toppings")
    assert top.max_adapters_per_server == len(adapters)
    assert lora.max_adapters_per_server < len(adapters)
    assert lora.total_adapter_bytes < top.total_adapter_bytes


def test_loraserve_tbt_competitive(setup):
    """Fig 20: TBT similar or better (paper: up to 15% better)."""
    adapters, trace = setup
    lora = _run(adapters, trace, "loraserve")
    top = _run(adapters, trace, "toppings")
    assert lora.mean_tbt() < top.mean_tbt() * 1.10


def test_pool_fetches_only_for_dynamic_policy(setup):
    adapters, trace = setup
    lora = _run(adapters, trace, "loraserve")
    rand = _run(adapters, trace, "slora-random")
    assert rand.fetches == 0            # static placement never migrates
    assert lora.rebalances > 0


def test_weak_scaling_direction():
    """Fig 21: doubling servers roughly doubles sustainable load."""
    adapters = make_adapters(40, seed=5)
    t4 = synth_trace(adapters, rps=20, duration=120,
                     popularity="uniform", seed=6)
    t8 = synth_trace(adapters, rps=40, duration=120,
                     popularity="uniform", seed=6)
    r4 = ClusterSimulator(4, adapters, policy="loraserve", seed=7,
                          warmup=30).run(copy.deepcopy(t4))
    r8 = ClusterSimulator(8, adapters, policy="loraserve", seed=7,
                          warmup=30).run(copy.deepcopy(t8))
    # same per-server load => comparable tail latency (within 4x)
    assert r8.p95_ttft() < max(4 * r4.p95_ttft(), 2.0)
