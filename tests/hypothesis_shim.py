"""Use real `hypothesis` when installed; otherwise a tiny fallback so
the property tests still collect and run (seeded random sampling, no
shrinking). Only the strategy surface these tests use is implemented:
integers / floats / sampled_from / lists / tuples + @given + @settings.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _StModule:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elem.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def tuples(*elems):
            return _Strategy(
                lambda rng: tuple(e.draw(rng) for e in elems))

    st = _StModule()

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def wrapper():
                # @settings sits above @given, so it annotates `wrapper`
                n = getattr(wrapper, "_max_examples", 20)
                for example in range(n):
                    rng = random.Random(0xC0FFEE + example)
                    drawn = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*drawn, **drawn_kw)
            # no functools.wraps: pytest must see the zero-arg signature,
            # not the inner function's drawn parameters (as fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
