"""Demand estimator + cost-model calibration facts from the paper."""
import pytest

from repro.cluster import ServerModel, co_serving_slowdown, make_server, \
    profile_operating_points
from repro.core import DemandEstimator


def test_demand_tracks_level():
    d = DemandEstimator()
    for _ in range(10):
        d.observe("a", 100.0)
    assert abs(d.extrapolate("a") - 100.0) < 5.0


def test_demand_extrapolates_trend():
    d = DemandEstimator()
    for t in range(10):
        d.observe("a", 100.0 + 10.0 * t)
    # next value should be projected above the last observation
    assert d.extrapolate("a") > 190.0


def test_demand_nonnegative():
    d = DemandEstimator()
    for t in range(10):
        d.observe("a", max(0.0, 100.0 - 30.0 * t))
    assert d.extrapolate("a") >= 0.0


def test_fig3_rank_ratio_tp1():
    """Fig 3: rank-128 prefill ~2.7x rank-8 at input 2000, TP=1."""
    s = ServerModel(tp=1)
    r = s.prefill_time(2000, 128) / s.prefill_time(2000, 8)
    assert 2.3 < r < 3.1


def test_fig5_tp8_residual():
    """Fig 5: ~20% residual TTFT inflation for rank-128 at TP=8."""
    s = ServerModel(tp=8)
    r = s.prefill_time(2000, 128) / s.prefill_time(2000, 8)
    assert 1.1 < r < 1.35


def test_fig4_model_size_amplifies():
    """Fig 4: rank heterogeneity penalty grows with model size (~45%
    degradation at 70B TP=8)."""
    s7 = make_server("llama-7b", tp=8)
    s70 = make_server("llama-70b", tp=8)
    r7 = s7.prefill_time(2000, 128) / s7.prefill_time(2000, 8)
    r70 = s70.prefill_time(2000, 128) / s70.prefill_time(2000, 8)
    assert r70 > r7
    assert 1.3 < r70 < 1.7


def test_fig1_coserving_tax():
    """Fig 1: co-serving r8 with r128 slows the rank-8 batch by a large
    margin (the paper's P95 skew is +84%; the iteration-level tax here is
    the max-rank inflation)."""
    s = ServerModel(tp=4)
    assert co_serving_slowdown(s, 8, 128) > 1.3
    assert co_serving_slowdown(s, 8, 8) == 1.0
    # symmetric-rank co-serving costs nothing extra
    assert co_serving_slowdown(s, 128, 8) == pytest.approx(1.0)


def test_operating_points_decrease_with_rank():
    ops = profile_operating_points(ServerModel(), [8, 16, 32, 64, 128])
    vals = [ops[r] for r in sorted(ops)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_decode_max_rank_padding_tax():
    """BGMV decode: batch cost tracks the max rank present."""
    s = ServerModel()
    t_mixed = s.decode_time(16, 128)
    t_pure = s.decode_time(16, 8)
    assert t_mixed > t_pure
