import os

# Tests see the single real CPU device; only launch/dryrun.py (run as its
# own process) forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
# Pin the backend to the single real CPU device NOW, before any test
# module import can touch XLA_FLAGS (repro.launch.dryrun sets the
# 512-placeholder-device flag at import for its own __main__ use).
assert len(jax.devices()) == 1
