"""Chaos plane: fault injection, failure detection, and loss-free
recovery across the serving stack.

The claims under test, per ISSUE acceptance:

- kill-a-server under live traffic is **loss-free on both substrates**:
  every request finishes or is re-dispatched, with zero lost and zero
  duplicated tokens (stream watermarks), and the real engine's
  re-dispatched outputs are token-identical to a fault-free run;
- routing to a confirmed-dead server stops within one detector window,
  and windowed SLO attainment returns to its pre-fault level;
- a stalled fetch blows its per-attempt deadline and retries from an
  alternate source/tier; the per-peer circuit breaker walks
  closed -> open -> half-open -> closed deterministically;
- the heartbeat detector never confirms a healthy server dead, however
  violently the virtual clock jumps.
"""
import copy
import http.client
import json
import os
import random
import threading
import time

import pytest

import jax

from repro.cluster import ClusterSimulator, NetworkModel
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ServeRequest
from repro.core.pool import AdapterStore, CircuitBreaker, FetchRetryPolicy
from repro.faults import FailureDetector, FaultPlan
from repro.models import model as M
from repro.serving import EngineBackend, LoRAServeCluster, SimBackend
from repro.traces import make_adapters, synth_trace

from test_server import GatewayHarness, http_json, sse_request

SLO_TTFT = 0.25


# ---------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------
def _attainment(reqs, t0=0.0, t1=float("inf")):
    """Windowed TTFT attainment over sim requests, bucketed by arrival;
    unfinished requests count as misses."""
    w = [r for r in reqs if t0 <= r.arrival < t1]
    if not w:
        return 1.0
    return sum(1 for r in w if r.prefill_done >= 0
               and r.ttft <= SLO_TTFT and r.finish >= 0) / len(w)


def _drive(cluster, trace, max_steps=200_000):
    """submit/poll the trace on the virtual clock (what ``run`` does,
    but keeping every ClusterEvent for watermark accounting)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    cluster.start()
    events, submits = [], []
    now, i, n = 0.0, 0, len(trace)
    for _ in range(max_steps):
        while i < n and trace[i].arrival <= now + 1e-12:
            cluster.submit(trace[i], now)
            submits.append((now, trace[i].req_id,
                            cluster.routed[trace[i].req_id]))
            i += 1
        events.extend(cluster.poll(now))
        if i >= n and cluster.backend.pending() == 0 \
                and not cluster.orch.draining:
            break
        nxt = cluster._next_time(now, i < n,
                                 trace[i].arrival if i < n else None)
        if nxt is None:
            break
        now = max(now, nxt)
    else:
        pytest.fail("drive loop did not drain")
    events.extend(cluster.drain())
    return events, submits


def _token_counts(events):
    """Tokens surfaced per request across the whole event stream —
    exactly-once accounting means this equals output_len, never more
    (duplicates) and never less (losses)."""
    counts = {}
    for ev in events:
        if ev.kind in ("token", "finish") and ev.tokens:
            counts[ev.req.req_id] = counts.get(ev.req.req_id, 0) \
                + len(ev.tokens)
    return counts


# ---------------------------------------------------------------------
# kill-a-server: discrete-event substrate
# ---------------------------------------------------------------------
def test_sim_kill_a_server_loss_free():
    """Crash a server mid-trace (and restore it later): every request
    completes with exactly its output_len tokens accounted, stranded
    work re-dispatches, and post-restore SLO attainment returns to the
    pre-fault level."""
    t_kill, t_restore, window = 8.0, 16.0, 0.5
    adapters = make_adapters(8, seed=3)
    trace = synth_trace(adapters, rps=14.0, duration=24.0,
                        popularity="shifting", prompt_len=128,
                        output_len=64, seed=11)
    sim = ClusterSimulator(3, adapters, policy="loraserve", seed=7,
                           timeout=1e9, rebalance_period=6.0,
                           prefetch=True,
                           fault_plan=FaultPlan.kill_one(t_kill, 0,
                                                         t_restore),
                           detector_window=window, durable_ssd=True)
    res = sim.run(copy.deepcopy(trace))

    assert res.server_failures == 1 and res.recoveries == 1
    assert res.redispatched >= 1
    # loss-free: every request finished, token ledger exact
    assert all(r.finish >= 0 for r in res.requests)
    assert all(r.decoded == r.output_len for r in res.requests)
    assert len(res.requests) == len(trace)
    # detection within one window of the crash
    (rec,) = res.recovery_records
    assert rec.server == 0
    assert abs(rec.detected_at - (t_kill + window)) < 1e-6
    assert rec.redispatched == res.redispatched
    # the SLO dips during the fault and restores after
    pre = _attainment(res.requests, 0.0, t_kill)
    post = _attainment(res.requests, t_restore)
    assert post >= pre - 1e-9


def test_sim_kill_without_restore_survivors_carry():
    """No restore: the two survivors absorb the victim's load and the
    run still drains loss-free."""
    adapters = make_adapters(6, seed=3)
    trace = synth_trace(adapters, rps=10.0, duration=18.0,
                        prompt_len=128, output_len=48, seed=9)
    sim = ClusterSimulator(3, adapters, policy="loraserve", seed=7,
                           timeout=1e9, rebalance_period=1e9,
                           prefetch=True,
                           fault_plan=FaultPlan.kill_one(6.0, 1),
                           detector_window=0.5, durable_ssd=True)
    res = sim.run(copy.deepcopy(trace))
    assert res.server_failures == 1 and res.recoveries == 1
    assert all(r.finish >= 0 for r in res.requests)
    assert all(r.decoded == r.output_len for r in res.requests)
    # nothing arriving after confirmation landed on the dead server
    (rec,) = res.recovery_records
    assert all(r.server != 1 for r in res.requests
               if r.arrival > rec.detected_at)


# ---------------------------------------------------------------------
# kill-a-server: facade substrate (stream watermarks + routing stop)
# ---------------------------------------------------------------------
def test_facade_kill_a_server_loss_free_watermarks():
    """Kill a SimBackend server under the incremental API with token
    streaming on: the event stream carries each request's tokens
    exactly once (continuations resume at the watermark, no replay,
    no gap), and no request submitted after confirmation routes to the
    dead server."""
    t_kill, window = 3.0, 0.5
    adapters = [AdapterInfo(f"a{i}-r{[8, 16, 32, 64][i % 4]}",
                            [8, 16, 32, 64][i % 4], nbytes=8 << 20)
                for i in range(4)]
    backend = SimBackend(2, adapter_nbytes={a.adapter_id: a.nbytes
                                            for a in adapters})
    cluster = LoRAServeCluster(
        backend, adapters, network=NetworkModel(),
        rebalance_period=1e9, seed=0, track_tokens=True,
        fault_plan=FaultPlan.kill_one(t_kill, 0),
        detector_window=window, durable_ssd=True)
    rng = random.Random(4)
    trace = [ServeRequest(req_id=i, adapter_id=adapters[i % 4].adapter_id,
                          rank=adapters[i % 4].rank, prompt_len=64,
                          output_len=8 + rng.randrange(8),
                          arrival=i * 0.125)
             for i in range(48)]

    events, submits = _drive(cluster, copy.deepcopy(trace))
    report = cluster.report()

    assert report.server_failures == 1 and report.recoveries == 1
    assert report.completed() == len(trace)
    assert report.redispatched >= 1

    # stream watermarks: exactly-once token accounting per request
    counts = _token_counts(events)
    want = {r.req_id: r.output_len for r in trace}
    assert counts == want

    # routing to the confirmed-dead server stops within one detector
    # window of the crash (margin: one extra window for the poll grid)
    late = [(t, rid, sid) for t, rid, sid in submits
            if t >= t_kill + 2 * window]
    assert late, "trace must outlive the detection window"
    assert all(sid != 0 for _, _, sid in late)

    # ...and the SLO recovers once the survivor owns the full load
    by_id = {r.req_id: r for r in trace}
    pairs = [(by_id[r.req_id].arrival, r) for r in report.results]
    pre = [r for a, r in pairs if a < t_kill]
    post = [r for a, r in pairs if a >= t_kill + 2 * window]
    att = lambda rs: (sum(1 for r in rs if r.finished and r.ttft is not None
                          and r.ttft <= SLO_TTFT) / len(rs)) if rs else 1.0
    assert att(post) >= att(pre) - 1e-9


# ---------------------------------------------------------------------
# kill-a-server: real engine (token parity with the fault-free run)
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine_cluster(cfg, params, adapters, plan=None):
    be = EngineBackend(cfg, params, 2, max_batch=2, max_len=48, seed=0)
    return LoRAServeCluster(be, adapters, network=NetworkModel(),
                            rebalance_period=1e9, seed=0,
                            fault_plan=plan, detector_window=0.3,
                            durable_ssd=True)


def test_engine_kill_a_server_token_parity(engine_setup):
    """Crash one of two real JAX engine servers mid-run: stranded
    requests re-dispatch as continuations (re-prefill of prompt +
    already-emitted tokens), and every request's final output is
    bit-identical to a fault-free run — the strongest form of the
    zero-lost/zero-duplicated claim."""
    cfg, params = engine_setup
    rng = random.Random(2)
    adapters = [AdapterInfo("fa-r8", 8, nbytes=8 << 20),
                AdapterInfo("fb-r16", 16, nbytes=16 << 20)]

    def trace():
        return [ServeRequest(
            req_id=i, adapter_id=adapters[i % 2].adapter_id,
            rank=adapters[i % 2].rank,
            prompt_len=6, output_len=10,
            prompt=[rng.randrange(1, cfg.vocab_size) for _ in range(6)],
            arrival=0.15 * i) for i in range(8)]

    base = trace()               # one rng draw, replayed twice
    ref = copy.deepcopy(base)
    _engine_cluster(cfg, params, adapters).run(ref)
    want = {r.req_id: list(r.output) for r in ref}
    assert all(len(t) == 10 for t in want.values())

    chaotic = copy.deepcopy(base)
    cluster = _engine_cluster(cfg, params, adapters,
                              plan=FaultPlan.kill_one(0.25, 0))
    report = cluster.run(chaotic)

    assert report.server_failures == 1 and report.recoveries == 1
    assert report.completed() == len(base)
    got = {r.req_id: list(r.output) for r in chaotic}
    assert got == want           # token-identical despite the crash


# ---------------------------------------------------------------------
# fetch stall -> timeout -> retry from an alternate source
# ---------------------------------------------------------------------
def _store(n, adapters, **kw):
    return AdapterStore(n, adapters, network=NetworkModel(),
                        retry=FetchRetryPolicy(), **kw)


def test_fetch_stall_retries_from_alternate_peer():
    """Stall an in-flight transfer: the per-attempt deadline fires,
    the attempt fails, and — with the original peer's link down — the
    relaunch re-sources from the other replica and lands the copy."""
    adapters = [AdapterInfo("a", 16, nbytes=64 << 20)]
    store = _store(3, adapters)
    store.seed({"a": {0: 0.5, 2: 0.5}})
    store.desired["a"].add(1)            # routing wants a copy on 1

    plan = store.start_fetch(1, "a", now=0.0)
    assert plan.src_server == 0          # cheapest idle peer, lowest id
    assert store.stall_transfer(1, "a")
    store.network.set_link_down(0)       # and the old source goes dark

    p = store._inflight[(1, "a")]
    assert p.eta == float("inf") and p.deadline < float("inf")
    store.poll(p.deadline + 0.01)        # deadline blows -> backoff
    assert store.fetch_timeouts == 1
    p = store._inflight[(1, "a")]
    assert p.retry_at > 0 and p.source == "retry-wait"

    store.poll(p.retry_at + 0.01)        # backoff elapses -> relaunch
    assert store.fetch_retries == 1
    p = store._inflight[(1, "a")]
    assert p.src_server == 2             # alternate replica, not 0
    store.poll(p.eta + 0.01)
    assert "a" in store.local[1]         # copy landed


def test_fetch_stall_falls_back_to_ssd_tier_and_opens_breaker():
    """Three consecutive stalled attempts against the same peer open
    its circuit breaker; the next relaunch skips the poisoned peer and
    recovers the copy from the durable SSD tier."""
    adapters = [AdapterInfo("a", 16, nbytes=64 << 20)]

    def transcript():
        store = _store(2, adapters, durable_ssd=True)
        store.seed({"a": {0: 1.0}})
        store.desired["a"].add(1)        # routing wants a copy on 1
        store.start_fetch(1, "a", now=0.0)
        log = []
        for _ in range(3):
            assert store.stall_transfer(1, "a")
            p = store._inflight[(1, "a")]
            store.poll(p.deadline + 0.001)
            p = store._inflight[(1, "a")]
            log.append(("timeout", round(p.retry_at, 9)))
            store.poll(p.retry_at + 0.001)
            p = store._inflight[(1, "a")]
            log.append(("relaunch", p.source, p.src_server,
                        round(p.eta, 9)))
        p = store._inflight[(1, "a")]
        store.poll(p.eta + 0.001)
        log.append(("landed", "a" in store.local[1],
                    store.fetch_timeouts, store.fetch_retries,
                    store.breakers[0].opens, store.breakers[0].state))
        return log

    log = transcript()
    # the breaker opened on the third failure, so the final relaunch
    # came from the SSD tier, not peer 0
    assert log[-2][1] == "ssd" and log[-2][2] == -1
    landed, timeouts, retries, opens, state = log[-1][1:]
    assert landed and timeouts == 3 and retries == 3 and opens == 1
    # deterministic: the seeded jitter reproduces the exact schedule
    assert transcript() == log


def test_circuit_breaker_open_half_open_closed_determinism():
    br = CircuitBreaker(threshold=3, cooldown=1.0)
    assert br.allows(0.0) and br.state == "closed"
    br.record_failure(0.0)
    br.record_failure(0.1)
    assert br.allows(0.2) and br.state == "closed"   # under threshold
    br.record_failure(0.2)                           # third: opens
    assert br.state == "open" and br.opens == 1
    assert not br.allows(0.2) and not br.allows(1.19)
    assert br.allows(1.2)                            # cooldown elapsed
    assert br.state == "half-open"                   # single probe
    br.record_failure(1.3)                           # probe failed
    assert br.state == "open" and br.opens == 2
    assert br.allows(2.3) and br.state == "half-open"
    br.record_success()                              # probe landed
    assert br.state == "closed" and br.failures == 0
    assert br.allows(2.4)


def test_retry_backoff_deterministic_and_bounded():
    pol = FetchRetryPolicy()
    a = [pol.backoff(i, random.Random(42)) for i in range(12)]
    b = [pol.backoff(i, random.Random(42)) for i in range(12)]
    assert a == b
    assert all(x <= pol.max_backoff * (1 + pol.jitter) + 1e-12 for x in a)
    assert all(a[i] >= pol.base_backoff for i in range(len(a)))


# ---------------------------------------------------------------------
# failure detector: no false positives, ever
# ---------------------------------------------------------------------
def test_detector_no_false_positives_on_jumpy_clock():
    """Beat-then-check per poll: however far the virtual clock jumps
    between polls, a server the host still beats is never confirmed."""
    det = FailureDetector(window=0.5)
    now = 0.0
    rng = random.Random(0)
    for _ in range(200):
        now += rng.random() * 50.0       # jumps way past the window
        for sid in range(3):
            det.beat(sid, now)
        assert det.check(now) == []
    assert det.confirmed_count == 0
    # ...and a genuinely silent server is confirmed exactly once
    det.beat(0, now + 1.0)
    det.beat(1, now + 1.0)
    assert det.check(now + 1.0) == [2]
    det.beat(0, now + 10.0)              # survivors keep beating
    det.beat(1, now + 10.0)
    assert det.check(now + 10.0) == []   # 2 reported exactly once
    assert det.confirmed_count == 1


def test_healthy_cluster_run_confirms_nothing():
    """A fault-free facade run with a tiny detector window and a jumpy
    virtual clock (arrival gaps far exceed the window) confirms no
    server dead and records no failures."""
    adapters = [AdapterInfo(f"a{i}", 8, nbytes=8 << 20) for i in range(3)]
    backend = SimBackend(2, adapter_nbytes={a.adapter_id: a.nbytes
                                            for a in adapters})
    cluster = LoRAServeCluster(backend, adapters, network=NetworkModel(),
                               rebalance_period=1e9, seed=0,
                               detector_window=0.05, durable_ssd=True)
    trace = [ServeRequest(req_id=i, adapter_id=adapters[i % 3].adapter_id,
                          rank=8, prompt_len=64, output_len=8,
                          arrival=5.0 * i)       # gaps >> window
             for i in range(12)]
    report = cluster.run(copy.deepcopy(trace))
    assert report.completed() == len(trace)
    assert report.server_failures == 0 and report.recoveries == 0
    assert cluster.detector.confirmed_count == 0


def test_detector_window_validation():
    with pytest.raises(ValueError):
        FailureDetector(window=0.0)


# ---------------------------------------------------------------------
# gateway: client disconnect cancels the request and frees the slot
# ---------------------------------------------------------------------
def test_gateway_client_disconnect_cancels_and_frees():
    """Drop the TCP connection mid-stream: the gateway's EOF watcher
    cancels the request (no orphaned slot, admission released) and the
    next stream on the same adapter runs to completion."""
    adapters = [AdapterInfo("a0-r8", 8, nbytes=8 << 20)]
    backend = SimBackend(1, adapter_nbytes={a.adapter_id: a.nbytes
                                            for a in adapters})
    cluster = LoRAServeCluster(backend, adapters, network=NetworkModel(),
                               rebalance_period=1e9, seed=0,
                               track_tokens=True)
    with GatewayHarness(cluster) as h:
        conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                          timeout=30)
        conn.request("POST", "/v1/completions",
                     json.dumps({"adapter_id": "a0-r8",
                                 "prompt_len": 64, "max_tokens": 512}),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200
        line = resp.fp.readline()        # stream is live...
        assert line
        resp.close()                     # ...client vanishes
        conn.close()

        def disconnects():
            _, text, _ = http_json(h.port, "GET", "/metrics")
            for ln in text.splitlines():
                if ln.startswith("repro_gateway_client_disconnects_total"):
                    return int(float(ln.split()[-1]))
            return 0

        deadline = time.time() + 20
        while time.time() < deadline and disconnects() < 1:
            time.sleep(0.05)
        assert disconnects() == 1

        # slot and admission are free again: a full stream completes
        status, chunks = sse_request(h.port, {"adapter_id": "a0-r8",
                                              "prompt_len": 16,
                                              "max_tokens": 8})
        assert status == 200
        assert sum(len(c.get("tokens") or []) for c in chunks) == 8
    assert cluster.cancelled >= 1


# ---------------------------------------------------------------------
# seeded fault storm (CI sweeps REPRO_CHAOS_SEED across a matrix)
# ---------------------------------------------------------------------
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


def test_sim_random_fault_storm_drains_loss_free():
    """A seeded Poisson fault storm: whatever the plan throws —
    overlapping crashes, link flaps, stalled transfers — the run
    drains, nothing is lost, every token ledger closes, and every
    confirmed crash leaves a well-formed recovery record."""
    adapters = make_adapters(6, seed=3)
    trace = synth_trace(adapters, rps=10.0, duration=18.0,
                        popularity="shifting", prompt_len=64,
                        output_len=32, seed=100 + CHAOS_SEED)
    plan = FaultPlan.random_plan(CHAOS_SEED, horizon=16.0, n_servers=3,
                                 rate=0.4)
    sim = ClusterSimulator(3, adapters, policy="loraserve", seed=7,
                           timeout=1e9, rebalance_period=6.0,
                           prefetch=True, fault_plan=plan,
                           detector_window=0.5, durable_ssd=True)
    res = sim.run(trace)
    assert all(r.finish >= 0 for r in res.requests)
    assert all(r.decoded == r.output_len for r in res.requests)
    # sub-window flaps heal before detection and run no recovery, so
    # recoveries may trail failures but each one must be recorded
    assert res.recoveries == len(res.recovery_records)
    assert res.recoveries <= res.server_failures
    for rec in res.recovery_records:
        assert rec.recovered_at >= rec.detected_at
