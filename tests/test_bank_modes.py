"""Rank-bucketed LoRA banks end-to-end: padded-vs-bucketed parity on the
real engine (token-identical outputs, allclose logits), the bucketed
cost-model primitives (strictly cheaper for mixed-rank batches), the
Pallas dispatch helper, and the simulator's bucketed iteration costs."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ClusterSimulator, ServerModel
from repro.configs import get_smoke_config
from repro.lora import LoRABank, apply_bank_sgmv, build_bank, rank_bucket
from repro.models import model as M
from repro.serving import Request, ServingEngine
from repro.traces import make_adapters, synth_trace

ADAPTERS = {"a-r8": 8, "b-r64": 64, "c-r8": 8}


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# -- bank construction ----------------------------------------------------
def test_rank_bucket_power_of_two():
    assert [rank_bucket(r) for r in (1, 2, 5, 8, 9, 64, 100, 128)] == \
        [1, 2, 8, 8, 16, 64, 128, 128]
    with pytest.raises(ValueError):
        rank_bucket(0)


def test_build_bank_layouts(setup):
    cfg, _ = setup
    key = jax.random.PRNGKey(1)
    pb = build_bank(cfg, ADAPTERS, key, mode="padded")
    bb = build_bank(cfg, ADAPTERS, key, mode="bucketed")
    assert isinstance(pb, LoRABank) and isinstance(bb, LoRABank)
    assert pb.adapter_ids == bb.adapter_ids
    assert pb.max_rank == bb.max_rank == 64
    assert pb.signature[0] == "padded"
    assert bb.signature == ("bucketed", ((8, 2), (64, 1)))
    # padded: one bank at max rank; bucketed: per-bucket banks at own rank
    assert pb.data["q"]["A"].shape[-1] == 64
    assert bb.data[0]["q"]["A"].shape[-1] == 8
    assert bb.data[1]["q"]["A"].shape[-1] == 64
    # bucketed holds strictly fewer parameters than max-rank padding
    assert bb.nbytes() < pb.nbytes()
    # same adapter -> identical weights in both layouts (padding inert)
    i = pb.index("a-r8")
    b, loc = int(bb.adapter_bucket[i]), int(bb.adapter_local[i])
    np.testing.assert_array_equal(
        np.asarray(pb.data["q"]["A"][:, i, :, :8]),
        np.asarray(bb.data[b]["q"]["A"][:, loc, :, :8]))


def test_lora_idx_shapes(setup):
    cfg, _ = setup
    key = jax.random.PRNGKey(1)
    pb = build_bank(cfg, ADAPTERS, key, mode="padded")
    bb = build_bank(cfg, ADAPTERS, key, mode="bucketed")
    gi = jnp.asarray([0, 1, 2], jnp.int32)
    assert pb.lora_idx(gi).shape == (3,)
    li = bb.lora_idx(gi)
    assert li.shape == (3, 2)
    # a-r8 -> bucket 0 row 0; b-r64 -> bucket 1 row 0; c-r8 -> bucket 0 row 1
    np.testing.assert_array_equal(np.asarray(li),
                                  [[0, 0], [1, 0], [0, 1]])


# -- numerical parity -----------------------------------------------------
def test_model_logits_allclose_across_modes(setup):
    """The acceptance bar: bucketed produces logits allclose to padded on
    the real compute path, for every hosted adapter."""
    cfg, params = setup
    key = jax.random.PRNGKey(2)
    pb = build_bank(cfg, ADAPTERS, key, mode="padded")
    bb = build_bank(cfg, ADAPTERS, key, mode="bucketed")
    toks = jnp.arange(1, 7)[None, :]
    for aidx in range(len(ADAPTERS)):
        gi = jnp.asarray([aidx], jnp.int32)
        lp, cp = M.prefill(cfg, params, toks, bank=pb.data,
                           lora_idx=pb.lora_idx(gi), cache_len=16,
                           cache_dtype=jnp.float32)
        lb, cb = M.prefill(cfg, params, toks, bank=bb.data,
                           lora_idx=bb.lora_idx(gi), cache_len=16,
                           cache_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lb),
                                   atol=1e-5)
        nxt = jnp.argmax(lp, axis=-1).astype(jnp.int32)
        dp, _ = M.decode_step(cfg, params, cp, nxt, bank=pb.data,
                              lora_idx=pb.lora_idx(gi))
        db, _ = M.decode_step(cfg, params, cb, nxt, bank=bb.data,
                              lora_idx=bb.lora_idx(gi))
        np.testing.assert_allclose(np.asarray(dp), np.asarray(db),
                                   atol=1e-5)


def test_engine_tokens_identical_across_modes(setup):
    """Mixed-rank co-batched workload: bank_mode='bucketed' emits exactly
    the tokens of bank_mode='padded' on the real engine."""
    cfg, params = setup

    def run(mode):
        eng = ServingEngine(cfg, params, ADAPTERS, max_batch=4,
                            max_len=32, bank_mode=mode)
        reqs = [Request(i, ["a-r8", "b-r64", "c-r8"][i % 3],
                        list(range(1, 7 + i)), 4,
                        arrival=time.monotonic()) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return eng, [r.output for r in reqs]

    eng_p, out_p = run("padded")
    eng_b, out_b = run("bucketed")
    assert out_p == out_b
    assert eng_b.bank_mode == "bucketed"
    assert isinstance(eng_b.bank, tuple)        # per-bucket pytrees


def test_engine_bucketed_rebalance_midflight(setup):
    """Bucketed banks survive the mid-flight load/evict path: rebuilds
    remap slots to new (bucket, local) indices and requests complete."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, {"a-r8": 8, "b-r16": 16},
                        max_batch=2, max_len=24, bank_mode="bucketed")
    req = Request(0, "b-r16", list(range(1, 7)), 4)
    eng.submit(req)
    eng.step()
    assert eng.active == 1
    eng.load_adapters({"z-r64": 64})        # adds a new bucket mid-flight
    assert eng.lora_bank.bucket_ranks == (8, 16, 64)
    assert not eng.evict_adapter("b-r16")   # in flight -> refused
    eng.run_until_drained()
    assert len(req.output) >= 4
    assert eng.evict_adapter("b-r16")
    assert eng.lora_bank.bucket_ranks == (8, 64)


def test_apply_bank_sgmv_modes_agree(setup):
    """The Pallas dispatch helper: padded sgmv and token-compacting
    bucketed sgmv produce the same delta from the same LoRABank ids."""
    cfg, _ = setup
    key = jax.random.PRNGKey(3)
    pb = build_bank(cfg, ADAPTERS, key, mode="padded")
    bb = build_bank(cfg, ADAPTERS, key, mode="bucketed")
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(4), (T, cfg.d_model))
    aid = jnp.asarray([0, 1, 2] * (T // 3), jnp.int32)
    y_p = apply_bank_sgmv(x, pb, "q", 0, aid, interpret=True)
    y_b = apply_bank_sgmv(x, bb, "q", 0, aid, interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_b),
                               atol=1e-4)


# -- cost model -----------------------------------------------------------
@pytest.mark.parametrize("mix", [
    {8: 500, 128: 100},
    {8: 100, 16: 100, 64: 100},
    {16: 1, 128: 1},
])
def test_prefill_bucketed_cheaper_for_mixed_batches(mix):
    s = ServerModel()
    total, max_r = sum(mix.values()), max(mix)
    assert s.prefill_time_bucketed(mix) < s.prefill_time(total, max_r)


def test_prefill_bucketed_equals_padded_single_bucket():
    s = ServerModel()
    assert s.prefill_time_bucketed({64: 800}) == \
        pytest.approx(s.prefill_time(800, 64))


def test_decode_bucketed_cheaper_for_mixed_batches():
    s = ServerModel()
    mixed = {8: 12, 128: 4}
    assert s.decode_time_bucketed(mixed) < s.decode_time(16, 128)
    assert s.decode_time_bucketed({128: 16}) == \
        pytest.approx(s.decode_time(16, 128))


def test_decode_time_seq_len_param():
    """The KV read term scales with seq_len (and the default reproduces
    the original hard-coded calibration)."""
    s = ServerModel()
    assert s.decode_time(16, 8, seq_len=2048) > s.decode_time(16, 8)
    assert s.kv_read_bytes(512) == pytest.approx(2 * 2 * 32 * 1024 * 512)


# -- simulator ------------------------------------------------------------
def test_sim_bucketed_shrinks_rank_skew():
    """The padded-mode P95 TTFT skew from co-batching heterogeneous
    ranks shrinks when the simulated servers run bucketed banks."""
    adapters = make_adapters(24, seed=1)
    trace = synth_trace(adapters, rps=25, duration=40,
                        popularity="powerlaw", alpha=1.0, seed=2)
    import copy
    res = {}
    for mode in ("padded", "bucketed"):
        sim = ClusterSimulator(2, adapters, policy="slora-random", seed=3,
                               timeout=60, warmup=10, bank_mode=mode)
        res[mode] = sim.run(copy.deepcopy(trace))
    assert res["bucketed"].p95_ttft() < res["padded"].p95_ttft()
    assert res["bucketed"].completed() >= res["padded"].completed()
