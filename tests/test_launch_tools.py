"""Unit tests for the dry-run tooling: collective-bytes HLO parser
(trip-count multipliers), jaxpr FLOP counter, and spec fitting."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.specs import fit_spec


def _dryrun():
    # imported lazily: repro.launch.dryrun sets XLA_FLAGS at module level
    # (harmless after conftest pins the backend, but keep imports scoped)
    from repro.launch import dryrun
    return dryrun

HLO = """
HloModule test

%cond.1 (arg: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.1 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main.1 (a: f32[16]) -> f32[16] {
  %a = f32[16] parameter(0)
  %ag = f32[16]{0} all-gather(%a), channel_id=2, dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[16] add(%ag, %ag)
}
"""


def test_collective_parser_trip_counts():
    totals = _dryrun().collective_bytes(HLO)
    # all-gather in entry: 16 floats = 64 bytes, once
    assert totals["all-gather"] == 64
    # all-reduce inside the while body: 8 floats = 32 bytes x 24 trips
    assert totals["all-reduce"] == 32 * 24


def test_jaxpr_flops_dot_and_scan():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c

    x = jnp.ones((4, 8))
    w = jnp.ones((8, 8))
    jaxpr = jax.make_jaxpr(f)(x, w)
    flops = _dryrun().jaxpr_flops(jaxpr.jaxpr)
    # 5 scan steps x 2*4*8*8 dot flops
    assert flops == 5 * 2 * 4 * 8 * 8


def test_jaxpr_flops_counts_elementwise():
    def f(x):
        return jnp.exp(x) * 2.0

    jaxpr = jax.make_jaxpr(f)(jnp.ones((16,)))
    assert _dryrun().jaxpr_flops(jaxpr.jaxpr) >= 16


def test_fit_spec_drops_indivisible():
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 4}

    spec = fit_spec(FakeMesh(), P(None, "model"), (10, 64))
    assert spec == P(None, "model")
    spec = fit_spec(FakeMesh(), P(None, "model"), (10, 8))
    assert spec == P(None, None)          # 8 % 16 != 0 -> dropped
    spec = fit_spec(FakeMesh(), P(("data", "model"), None), (64, 8))
    assert spec == P(("data", "model"), None)
    spec = fit_spec(FakeMesh(), P(("data", "model"), None), (32, 8))
    assert spec == P(None, None)          # 32 % 64 != 0


def test_model_flops_sanity():
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("internlm2-1.8b")
    model_flops = _dryrun().model_flops
    mf = model_flops(cfg, INPUT_SHAPES["train_4k"])
    # 6 * ~1.9e9 params * 1M tokens ~ 1.2e16
    assert 0.5e16 < mf < 3e16
    mf_dec = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert mf_dec < mf / 1000
