"""Unified cluster serving API: sim-vs-engine backend parity, mid-flight
rebalancing under workload drift, placement-aware engine banks, routing
errors, and replay non-mutation."""
import copy
import random

import jax
import pytest

from repro.cluster import NetworkModel
from repro.configs import get_smoke_config
from repro.core import (AdapterInfo, RoutingTable, ServeRequest,
                        UnknownAdapterError)
from repro.models import model as M
from repro.serving import (EngineBackend, LoRAServeCluster, Request,
                           ServingEngine, SimBackend, replay)
from repro.traces import make_adapters, synth_trace


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mini_trace(adapters, cfg, n, prompt_len=6, output_len=3, gap=0.05):
    rng = random.Random(7)
    out = []
    for i in range(n):
        a = adapters[i % len(adapters)]
        prompt = [rng.randrange(1, cfg.vocab_size)
                  for _ in range(prompt_len)]
        out.append(ServeRequest(req_id=i, adapter_id=a.adapter_id,
                                rank=a.rank, prompt_len=prompt_len,
                                output_len=output_len, prompt=prompt,
                                arrival=i * gap))
    return out


def test_sim_engine_backend_parity(setup):
    """Same trace + same policy seed => identical request->server routing
    on both substrates (the facade's control plane is backend-agnostic)."""
    cfg, params = setup
    adapters = [AdapterInfo(f"ad{i}-r{r}", r, nbytes=r * 1000)
                for i, r in enumerate([8, 8, 16, 64])]
    trace = _mini_trace(adapters, cfg, 6)

    def make(backend):
        return LoRAServeCluster(backend, adapters, policy="loraserve",
                                network=NetworkModel(),
                                rebalance_period=1e9, seed=5)

    sim = make(SimBackend(2, adapter_nbytes={a.adapter_id: a.nbytes
                                             for a in adapters}))
    sim_res = sim.run(copy.deepcopy(trace))
    eng = make(EngineBackend(cfg, params, 2, max_batch=2, max_len=16))
    eng_res = eng.run(copy.deepcopy(trace))

    assert sim.routed == eng.routed
    assert sim_res.per_server_counts == eng_res.per_server_counts
    assert sim_res.completed() == len(trace)
    assert eng_res.completed() == len(trace)
    # engine results carry real decoded tokens
    assert all(r.n_output >= 3 for r in eng_res.results)


def test_drift_triggers_midflight_rebalance():
    """Shifting popularity: the control loop re-places adapters while the
    trace is in flight, and post-rebalance requests follow the updated
    routing (land outside their initial placement)."""
    adapters = make_adapters(16, seed=1)
    trace = synth_trace(adapters, rps=20, duration=60,
                        popularity="shifting", seed=2)
    backend = SimBackend(3, timeout=60)
    cluster = LoRAServeCluster(backend, adapters, policy="loraserve",
                               network=NetworkModel(),
                               rebalance_period=10.0, seed=3)
    res = cluster.run(trace)
    assert res.completed() == len(trace)
    assert res.rebalances >= 1
    assert res.placement_changed()
    p0, pN = res.placements[0], res.placements[-1]
    moved = [aid for aid in p0 if set(p0[aid]) != set(pN[aid])]
    assert moved, "rebalance should re-place at least one adapter"
    assert any(r.server not in p0[r.adapter_id]
               for r in res.results
               if r.finished and r.adapter_id in moved), \
        "post-rebalance requests must follow the updated routing"


def test_static_policy_never_rebalances():
    adapters = make_adapters(8, seed=1)
    trace = synth_trace(adapters, rps=10, duration=30, seed=2)
    cluster = LoRAServeCluster(SimBackend(2, timeout=60), adapters,
                               policy="slora-random",
                               network=NetworkModel(),
                               rebalance_period=5.0, seed=3)
    res = cluster.run(trace)
    assert res.rebalances == 0 and len(res.placements) == 1
    assert res.completed() == len(trace)


def test_engine_bank_is_placed_subset_only(setup):
    """A server hosting ranks {8, 16} pads its bank to 16 — not to the
    global max rank — and rebalances reshape it without perturbing the
    weights of retained adapters."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, {"a-r8": 8, "b-r16": 16},
                        max_batch=2, max_len=16)
    assert eng.max_rank == 16
    assert eng.bank["q"]["A"].shape[-1] == 16
    a_before = eng.bank["q"]["A"][:, 0, :, :8]

    assert eng.load_adapters({"c-r128": 128})
    assert eng.max_rank == 128
    assert eng.bank["q"]["A"].shape[-1] == 128
    a_after = eng.bank["q"]["A"][:, 0, :, :8]     # "a-r8" still index 0
    assert jax.numpy.allclose(a_before, a_after)

    assert eng.evict_adapter("c-r128")
    assert eng.max_rank == 16
    assert not eng.evict_adapter("missing")


def test_engine_rebalance_with_inflight_requests(setup):
    """Loading/evicting adapters mid-decode remaps co-batched slots; the
    in-flight request still completes with the right token budget."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, {"a-r8": 8, "b-r16": 16},
                        max_batch=2, max_len=24)
    req = Request(0, "b-r16", list(range(1, 7)), 4)
    eng.submit(req)
    eng.step()                       # prefill + first decode
    assert eng.active == 1
    eng.load_adapters({"z-r64": 64})   # mid-flight bank reshape
    assert not eng.evict_adapter("b-r16")   # in flight -> refused
    eng.run_until_drained()
    assert len(req.output) >= 4
    assert eng.evict_adapter("b-r16")       # drained -> allowed


def test_unknown_adapter_raises_clear_error():
    table = RoutingTable({"a": {0: 1.0}})
    with pytest.raises(UnknownAdapterError) as ei:
        table.route("ghost")
    assert "ghost" in str(ei.value)
    with pytest.raises(UnknownAdapterError):
        table.servers("ghost")
    assert isinstance(ei.value, KeyError)     # old callers still catch


def test_replay_does_not_mutate_arrivals(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, {"a-r8": 8}, max_batch=2, max_len=16)
    reqs = [Request(i, "a-r8", list(range(1, 6)), 2, arrival=i * 0.01)
            for i in range(3)]
    arrivals = [r.arrival for r in reqs]
    summ = replay(eng, reqs, speedup=4.0)
    assert summ["finished"] == 3
    assert [r.arrival for r in reqs] == arrivals
    assert all(r.ttft is not None and r.ttft >= 0 for r in reqs)
