"""SLO-driven control plane: drift detection against trace ground
truth, loss-free drains, scale-up under surge, and no-op stability."""
import copy

import pytest

from repro.cluster import ClusterSimulator, NetworkModel
from repro.controlplane import (ClusterController, ControllerConfig,
                                DriftDetector, SLOSpec, SLOTracker,
                                TelemetryHub)
from repro.core import AdapterInfo, ServeRequest
from repro.core.pool import AdapterStore
from repro.core.routing import RetiredServerError, RoutingTable
from repro.traces import (make_adapters, production_trace_with_meta,
                          synth_trace)


def _controller(min_servers, max_servers, **cfg_kw):
    cfg = dict(tick_period=5.0, min_servers=min_servers,
               max_servers=max_servers, patience=2, drain_patience=3,
               cooldown=15.0)
    cfg.update(cfg_kw)
    return ClusterController(SLOSpec(ttft=8.0, target=0.95, window=30.0),
                             ControllerConfig(**cfg))


# -- drift detection vs ground truth -----------------------------------

def _tick_rates(reqs, window=30.0, tick=5.0, horizon=None):
    """Replay arrivals into a TelemetryHub exactly as the controller
    does, yielding (t, head-filtered windowed rates) per tick."""
    hub = TelemetryHub(window=window)
    reqs = sorted(reqs, key=lambda r: r.arrival)
    horizon = horizon or max(r.arrival for r in reqs)
    t, i = tick, 0
    while t <= horizon + tick:
        while i < len(reqs) and reqs[i].arrival <= t:
            r = reqs[i]
            hub.observe_arrival(r.adapter_id, 0,
                                r.prompt_len + r.output_len, r.arrival)
            i += 1
        rates = hub.adapter_rates(t)
        floor = 0.02 * sum(rates.values())
        yield t, {a: v for a, v in rates.items() if v >= floor}
        t += tick


def test_detector_flags_surge_not_stable():
    """The Fig 10 surge adapter must be detected (as a surge) and the
    stable head adapter must stay silent, against the generator's own
    pattern labels."""
    reqs, meta = production_trace_with_meta(50, rps=20, duration=300,
                                            seed=3)
    patterns = meta["patterns"]
    surge = next(a for a, p in patterns.items() if p == "surge")
    stable_heads = [a for a, p in patterns.items()
                    if p == "stable" and a.endswith("-a0")]
    det = DriftDetector()
    for t, rates in _tick_rates(reqs, horizon=300):
        det.observe(rates, t)
    kinds = {e.kind for e in det.events_for(surge)}
    assert "surge" in kinds, f"surge adapter {surge} not flagged: {kinds}"
    for aid in stable_heads:
        assert not det.events_for(aid), \
            f"stable adapter {aid} falsely flagged"


def test_detector_direction_on_trends():
    reqs, meta = production_trace_with_meta(50, rps=20, duration=300,
                                            seed=3)
    patterns = meta["patterns"]
    det = DriftDetector()
    for t, rates in _tick_rates(reqs, horizon=300):
        det.observe(rates, t)
    rising = next(a for a, p in patterns.items() if p == "rising")
    falling = next(a for a, p in patterns.items() if p == "falling")
    assert any(e.kind in ("rising", "surge")
               for e in det.events_for(rising))
    assert any(e.kind in ("falling", "diurnal")
               for e in det.events_for(falling))


def test_detector_synthetic_shapes():
    """Direct unit check on clean signals: step up -> surge, slow ramp
    -> rising, flat -> nothing."""
    det = DriftDetector()
    for i in range(40):
        lvl = 10.0 if i < 20 else 30.0
        det.update("step", lvl, float(i))
    events = det.events_for("step")
    assert events and events[0].kind == "surge"
    assert events[0].time >= 20.0   # no detection before the step

    det2 = DriftDetector()
    for i in range(60):
        det2.update("ramp", 10.0 + i, float(i))
        det2.update("flat", 10.0, float(i))
    assert any(e.kind in ("rising", "surge")
               for e in det2.events_for("ramp"))
    assert not det2.events_for("flat")


# -- telemetry primitives ----------------------------------------------

def test_sliding_window_rate_divides_by_elapsed_not_now():
    """Regression: early-window rates used ``now`` as the divisor,
    assuming the clock started at 0 — a feed starting late (engine wall
    clock, offset-arrival trace) had its rates silently deflated."""
    from repro.controlplane.telemetry import SlidingWindow
    w = SlidingWindow(horizon=30.0)
    assert w.rate(100.0) == 0.0            # never pushed
    w.push(100.0, 50.0)
    w.push(105.0, 50.0)
    # 100 tokens over the 10s actually covered, not over 110s of clock
    assert w.rate(110.0) == pytest.approx(10.0)
    # once the window is saturated the divisor is the horizon
    w.push(140.0, 60.0)
    assert w.rate(145.0) == pytest.approx(w.total(145.0) / 30.0)
    # degenerate zero-elapsed feed must not divide by zero
    w2 = SlidingWindow(horizon=30.0)
    w2.push(7.0, 5.0)
    assert w2.rate(7.0) == pytest.approx(5.0)


def test_histogram_prometheus_bucket_semantics():
    from repro.controlplane.telemetry import Histogram
    h = Histogram(bounds=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 5.0, 100.0):
        h.observe(v)
    # `le` is inclusive: 0.1 lands in the 0.1 bucket
    assert list(h.cumulative()) == [(0.1, 2), (1.0, 3), (10.0, 4),
                                    ("+Inf", 5)]
    assert h.count == 5 and h.sum == pytest.approx(105.65)
    d = h.to_dict()
    assert d["buckets"][-1] == ("+Inf", 5) and d["count"] == 5


def test_hub_feeds_latency_histograms():
    hub = TelemetryHub(window=5.0)
    for i in range(4):
        hub.observe_completion(
            ServeRequest(req_id=i, adapter_id="a", arrival=0.0,
                         output_len=5, prefill_done=0.3, finish=1.0),
            float(i))
    snap = hub.snapshot(100.0)
    # windowed percentiles aged out; cumulative histograms did not
    assert snap["ttft_p95"] is None
    assert snap["ttft_hist"]["count"] == 4
    assert snap["tbt_hist"]["count"] == 4


# -- SLO tracker -------------------------------------------------------

def test_slo_tracker_windowed_attainment():
    spec = SLOSpec(ttft=1.0, target=0.9, window=10.0)
    tr = SLOTracker(spec)
    for t in range(5):
        tr.observe(ServeRequest(req_id=t, adapter_id="a", arrival=0.0,
                                prefill_done=0.5), float(t))
    assert tr.attainment(4.0) == 1.0
    for t in range(5, 10):
        tr.observe(ServeRequest(req_id=t, adapter_id="a", arrival=0.0,
                                prefill_done=5.0), float(t))
    assert tr.attainment(9.0) == 0.5
    assert tr.violated(9.0)
    # old scores age out of the window
    assert tr.attainment(16.0) == 0.0
    tr.observe_timeout(17.0)
    assert tr.sample_count(17.0) == 4
    assert tr.lifetime_attainment() == pytest.approx(5 / 11)


# -- store + routing drain/retire semantics ----------------------------

def test_store_drain_and_retire():
    adapters = [AdapterInfo(f"a{i}", 8, nbytes=1000) for i in range(6)]
    store = AdapterStore(3, adapters, NetworkModel())
    placement = {f"a{i}": {i % 3: 1.0} for i in range(6)}
    store.seed(placement)
    # re-place without server 2, then drain it
    new = {f"a{i}": {i % 2: 1.0} for i in range(6)}
    store.apply_placement(new, now=0.0)
    plans = store.drain_server(2, now=0.0)
    assert plans, "drain of a populated server must start migrations"
    assert all(p.mode == "drain" for p in plans)
    with pytest.raises(RuntimeError):
        store.retire_server(2)          # still holds copies / transfers
    store.poll(max(p.eta for p in plans) + 1.0)
    assert store.server_adapter_count(2) == 0
    assert store.check_invariant()
    store.retire_server(2)
    with pytest.raises(RuntimeError):
        store.start_fetch(2, "a0", now=99.0)
    assert store.live_servers() == [0, 1]


def test_routing_block_server():
    table = RoutingTable({"a": {0: 0.5, 1: 0.5}, "b": {1: 1.0}}, seed=0)
    table.block_server(0)
    for _ in range(20):
        assert table.route("a") == 1
    with pytest.raises(RetiredServerError):
        table.update({"a": {0: 1.0}})
    with pytest.raises(RetiredServerError):
        table.block_server(1)           # "b" would lose its only route


# -- closed loop on the simulator --------------------------------------

def _surge_trace(adapters, seed=2):
    """Quiet first half, heavy second half: the load step a static
    fleet cannot absorb."""
    lo = synth_trace(adapters, rps=4, duration=60,
                     popularity="exponential", seed=seed)
    hi = synth_trace(adapters, rps=26, duration=60,
                     popularity="exponential", seed=seed + 1)
    for r in hi:
        r.arrival += 60.0
    out = lo + hi
    for i, r in enumerate(sorted(out, key=lambda r: r.arrival)):
        r.req_id = i
    return out


def test_scale_up_restores_slo_under_surge():
    adapters = make_adapters(24, seed=1)
    trace = _surge_trace(adapters)
    static = ClusterSimulator(2, adapters, policy="loraserve", seed=3,
                              timeout=120)
    res_static = static.run(copy.deepcopy(trace))
    auto = ClusterSimulator(
        2, adapters, policy="loraserve", seed=3, timeout=120,
        controller=_controller(2, 6, patience=2, cooldown=10.0))
    res_auto = auto.run(copy.deepcopy(trace))
    assert res_auto.scale_ups >= 1
    assert res_auto.final_servers > 2
    att_auto = res_auto.slo_attainment(8.0)
    att_static = res_static.slo_attainment(8.0)
    assert att_auto > att_static
    assert res_auto.p95_ttft() < res_static.p95_ttft()


def test_drain_is_loss_free_with_live_traffic():
    """Drains interleaved with live arrivals: every request finishes,
    and nothing is ever routed to a retired server."""
    adapters = make_adapters(24, seed=1)
    trace = synth_trace(adapters, rps=2.0, duration=150,
                        popularity="exponential", seed=2)
    ctrl = _controller(1, 6, drain_patience=2, cooldown=10.0)
    sim = ClusterSimulator(4, adapters, policy="loraserve", seed=3,
                           timeout=120, controller=ctrl)
    res = sim.run(copy.deepcopy(trace))
    assert res.retires >= 1, "fleet never shrank; test is vacuous"
    assert res.timed_out == 0
    assert res.completed() == len(trace)
    assert all(r.finish >= 0 for r in res.requests)
    retire_time = {a.server: a.time for a in res.actions
                   if a.kind == "retire"}
    for r in res.requests:
        if r.server in retire_time:
            assert r.arrival <= retire_time[r.server], \
                (f"req {r.req_id} routed to server {r.server} after "
                 f"it retired at {retire_time[r.server]}")
    # a retired server stops billing: strictly cheaper than keeping
    # the whole initial fleet up for the entire run
    end = max(r.finish for r in res.requests)
    assert res.gpu_seconds < 4 * end


def test_controller_noop_on_stable_trace():
    """Stable demand on a right-sized fleet: no scaling, no drains, no
    drift-triggered rebalances."""
    adapters = make_adapters(24, seed=1)
    trace = synth_trace(adapters, rps=10, duration=90,
                        popularity="exponential", seed=2)
    ctrl = _controller(3, 6)   # min == initial n: drains impossible
    sim = ClusterSimulator(3, adapters, policy="loraserve", seed=3,
                           timeout=120, controller=ctrl)
    res = sim.run(copy.deepcopy(trace))
    assert res.scale_ups == 0
    assert res.drains == 0
    assert res.retires == 0
    assert [a for a in res.actions if a.kind != "rebalance"] == []
    assert res.slo_attainment(8.0) >= 0.95


def test_facade_drain_loss_free_simbackend():
    """Same loss-free guarantee through the serving facade path
    (LoRAServeCluster + SimBackend + real AdapterStore data plane)."""
    from repro.serving import LoRAServeCluster, SimBackend
    adapters = make_adapters(16, seed=1)
    trace = synth_trace(adapters, rps=1.5, duration=100,
                        popularity="exponential", seed=2)
    ctrl = _controller(1, 5, drain_patience=2, cooldown=10.0)
    cluster = LoRAServeCluster(
        SimBackend(4, timeout=120), adapters, policy="loraserve",
        network=NetworkModel(), rebalance_period=15.0, controller=ctrl)
    rep = cluster.run(copy.deepcopy(trace))
    assert rep.retires >= 1
    assert rep.timed_out == 0
    assert rep.completed() == len(trace)
    retire_time = {a.server: a.time for a in rep.controller_actions
                   if a.kind == "retire"}
    for r in rep.results:
        if r.server in retire_time:
            assert r.arrival <= retire_time[r.server]


def test_backend_add_and_retire_server():
    from repro.serving import SimBackend
    b = SimBackend(2)
    sid = b.add_server()
    assert sid == 2 and b.n_servers == 3
    b.load_adapters(2, {"a0": 8})
    assert b.hosted_adapters(2) == {"a0": 8}
    b.retire_server(2)
    assert b.hosted_adapters(2) == {}


def test_provision_delay_defers_capacity():
    adapters = make_adapters(24, seed=1)
    trace = _surge_trace(adapters)
    auto = ClusterSimulator(
        2, adapters, policy="loraserve", seed=3, timeout=120,
        controller=_controller(2, 6, patience=2, cooldown=10.0),
        provision_delay=10.0)
    res = auto.run(copy.deepcopy(trace))
    assert res.scale_ups >= 1
    first_up = next(a.time for a in res.actions if a.kind == "scale-up")
    # billed from the request, but capacity (and placement) lands later
    assert res.gpu_seconds > 0
    assert res.final_servers > 2
    assert first_up >= 60.0   # surge starts at t=60


# -- satellites --------------------------------------------------------

def test_percentile_interpolates():
    from repro.serving.metrics import percentile
    assert percentile([1.0, 2.0], 50) == pytest.approx(1.5)
    vs = [float(v) for v in range(1, 101)]
    assert percentile(vs, 95) == pytest.approx(95.05)
    assert percentile(vs, 100) == 100.0
    assert percentile(vs, 0) == 1.0
    assert percentile([7.0], 99) == 7.0


def test_replay_exhausted_flag():
    """A truncated replay must say so instead of masquerading as a
    complete run (satellite on serving/scheduler.py)."""
    from repro.serving.metrics import MetricsCollector
    from repro.serving.scheduler import replay

    class StubEngine:
        def __init__(self, consume):
            self.queue, self.active = [], 0
            self.metrics = MetricsCollector()
            self._clock = lambda: 0.0
            self._consume = consume

        def submit(self, r):
            self.queue.append(r)

        def step(self):
            if self._consume and self.queue:
                self.metrics.record(self.queue.pop(0))

    reqs = [ServeRequest(req_id=i, adapter_id="a", arrival=0.0,
                         prefill_done=0.1) for i in range(5)]
    done = replay(StubEngine(consume=True), list(reqs))
    assert done["exhausted"] is False
    with pytest.warns(RuntimeWarning, match="truncated"):
        stuck = replay(StubEngine(consume=False), list(reqs),
                       max_iters=10)
    assert stuck["exhausted"] is True


def test_production_trace_meta_ground_truth():
    reqs, meta = production_trace_with_meta(50, rps=10, duration=60,
                                            seed=4)
    pats = meta["patterns"]
    assert set(pats.values()) == {"rising", "falling", "diurnal",
                                  "stable", "surge"}
    assert all(r.adapter_id in pats for r in reqs)
    heads = [a for a, p in pats.items() if p != "stable"]
    assert len(heads) == 4          # 5 head slots, one labeled stable
    assert meta["load_profile"] == "flat"
    _, meta2 = production_trace_with_meta(50, rps=10, duration=60,
                                          seed=4, load_profile="diurnal")
    assert meta2["load_profile"] == "diurnal"
