"""Per-architecture smoke tests (required deliverable f): instantiate the
REDUCED variant of each assigned family, run one forward + one train step
on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.training import AdamWConfig, adamw_init, make_train_step


def _frontend(cfg, B, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (B, cfg.n_frontend_tokens,
                                       cfg.d_model)) * 0.02
    if cfg.family == "audio":
        return jax.random.normal(key, (B, cfg.encoder.n_frames,
                                       cfg.d_model)) * 0.02
    return None


@pytest.mark.parametrize("arch", ASSIGNED_ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, aux = M.forward(cfg, params, tokens,
                       frontend=_frontend(cfg, B, key))
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ASSIGNED_ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    fe = _frontend(cfg, B, key)
    if fe is not None:
        batch["frontend"] = fe
    params2, opt2, m = step(params, opt, batch)
    assert not bool(jnp.isnan(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Decode after prefill must match the teacher-forced forward."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    fe = _frontend(cfg, B, key)
    h, _ = M.forward(cfg, params, tokens, frontend=fe)
    full = h[:, -1].astype(jnp.float32) @ M.lm_head(cfg, params).astype(
        jnp.float32)
    _, cache = M.prefill(cfg, params, tokens[:, :S], frontend=fe,
                         cache_len=S + 4)
    dec, _ = M.decode_step(cfg, params, cache, tokens[:, S])
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 1e-3, f"{arch}: decode/forward mismatch {rel}"
