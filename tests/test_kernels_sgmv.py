"""SGMV Pallas kernels vs the pure-jnp oracle: shape/dtype sweeps in
interpret mode + segment-preparation properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels import (bgmv, prepare_segments, sgmv, sgmv_rank_bucketed,
                           sgmv_reference)
from repro.kernels.ops import padded_len


@pytest.mark.parametrize("T,d,r,do,Na,bt", [
    (7, 128, 8, 128, 2, 8),
    (32, 256, 16, 512, 4, 16),
    (63, 512, 64, 256, 5, 16),
    (16, 128, 128, 1024, 3, 4),
    (1, 128, 8, 128, 1, 8),
    (48, 384, 32, 384, 6, 1),       # bt=1 == BGMV
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sgmv_matches_ref(T, d, r, do, Na, bt, dtype):
    key = jax.random.PRNGKey(T * 7 + d)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d)).astype(dtype)
    A = (jax.random.normal(ks[1], (Na, d, r)) * 0.05).astype(dtype)
    B = (jax.random.normal(ks[2], (Na, r, do)) * 0.05).astype(dtype)
    aid = jax.random.randint(ks[3], (T,), 0, Na)
    y_k = np.asarray(sgmv(x, A, B, aid, block_t=bt, interpret=True),
                     np.float32)
    y_r = np.asarray(sgmv_reference(x, A, B, aid), np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(y_k, y_r, atol=tol, rtol=tol)


def test_scaling_applied():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 64))
    A = jax.random.normal(key, (2, 64, 8)) * 0.1
    B = jax.random.normal(key, (2, 8, 64)) * 0.1
    aid = jnp.zeros((8,), jnp.int32)
    y1 = sgmv(x, A, B, aid, scaling=2.0, interpret=True)
    y2 = sgmv(x, A, B, aid, scaling=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), 2 * np.asarray(y2),
                               rtol=1e-5)


def test_zero_padded_rank_is_inert():
    """An adapter zero-padded from rank 8 to the bank rank 64 must give
    exactly the rank-8 result — the padding tax is compute, not numerics."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16, 128))
    A8 = jax.random.normal(key, (1, 128, 8)) * 0.1
    B8 = jax.random.normal(key, (1, 8, 128)) * 0.1
    A64 = jnp.pad(A8, ((0, 0), (0, 0), (0, 56)))
    B64 = jnp.pad(B8, ((0, 0), (0, 56), (0, 0)))
    aid = jnp.zeros((16,), jnp.int32)
    y8 = sgmv(x, A8, B8, aid, interpret=True)
    y64 = sgmv(x, A64, B64, aid, interpret=True)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-5)


def test_rank_bucketed_matches_padded_bank():
    key = jax.random.PRNGKey(2)
    A8 = jax.random.normal(key, (3, 128, 8)) * 0.1
    B8 = jax.random.normal(key, (3, 8, 256)) * 0.1
    A64 = jax.random.normal(key, (3, 128, 64)) * 0.1
    B64 = jax.random.normal(key, (3, 64, 256)) * 0.1
    bucket = jnp.array([0, 1, 0])
    Apad = jnp.where(bucket[:, None, None] == 0,
                     jnp.pad(A8, ((0, 0), (0, 0), (0, 56))), A64)
    Bpad = jnp.where(bucket[:, None, None] == 0,
                     jnp.pad(B8, ((0, 0), (0, 56), (0, 0))), B64)
    x = jax.random.normal(key, (24, 128))
    aid = jax.random.randint(key, (24,), 0, 3)
    y_b = sgmv_rank_bucketed(x, [(A8, B8), (A64, B64)], aid, bucket,
                             interpret=True)
    y_r = sgmv_reference(x, Apad, Bpad, aid)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r), atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    T=st.integers(min_value=1, max_value=100),
    Na=st.integers(min_value=1, max_value=8),
    bt=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_prepare_segments_properties(T, Na, bt, seed):
    """dest is injective; every block holds exactly one adapter's tokens."""
    key = jax.random.PRNGKey(seed)
    aid = jax.random.randint(key, (T,), 0, Na)
    dest, block_adapter = prepare_segments(aid, Na, bt)
    dest = np.asarray(dest)
    aid_np = np.asarray(aid)
    assert len(set(dest.tolist())) == T                # injective
    assert dest.max() < padded_len(T, Na, bt)
    blocks = dest // bt
    ba = np.asarray(block_adapter)
    for t in range(T):
        assert ba[blocks[t]] == aid_np[t]              # block homogeneity
