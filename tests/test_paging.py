"""Unified paging pool (S-LoRA §II-B.2): allocation, decode growth,
adapter LRU eviction under KV pressure, pool invariants (hypothesis)."""
import pytest
from hypothesis_shim import given, settings, st

from repro.serving.paging import OutOfPages, UnifiedPagePool


def test_kv_alloc_and_growth():
    pool = UnifiedPagePool(n_pages=10, page_tokens=16)
    pool.alloc_kv("s0", 20)           # 2 pages
    assert pool.used_pages == 2
    pool.grow_kv("s0", 33)            # -> 3 pages
    assert pool.used_pages == 3
    pool.grow_kv("s0", 33)            # idempotent
    assert pool.used_pages == 3
    pool.free_kv("s0")
    assert pool.used_pages == 0
    assert pool.check_invariant()


def test_adapter_page_in_and_hit():
    pool = UnifiedPagePool(n_pages=8, page_bytes=1000)
    assert pool.ensure_adapter("a", 2500) is True     # 3 pages
    assert pool.ensure_adapter("a", 2500) is False    # hit
    assert pool.pages_by_kind()["adapter"] == 3
    assert pool.adapter_page_ins == 1


def test_kv_pressure_evicts_lru_adapter():
    pool = UnifiedPagePool(n_pages=6, page_tokens=16, page_bytes=1000)
    pool.ensure_adapter("old", 1000)      # 1 page, lru
    pool.ensure_adapter("new", 1000)      # 1 page
    pool.ensure_adapter("new", 1000)      # touch
    pool.alloc_kv("s0", 16 * 5)           # needs 5 pages -> evict "old"
    assert not pool.has_adapter("old")
    assert pool.has_adapter("new")
    assert pool.adapter_evictions == 1
    assert pool.check_invariant()


def test_pinned_adapter_never_evicted():
    pool = UnifiedPagePool(n_pages=4, page_tokens=16, page_bytes=1000)
    pool.ensure_adapter("hot", 1000)
    pool.pin_adapter("hot")
    pool.ensure_adapter("other", 1000)
    with pytest.raises(OutOfPages):
        pool.alloc_kv("s0", 16 * 4)       # would need all 4 pages
    assert pool.has_adapter("hot")


def test_kv_never_evicted():
    pool = UnifiedPagePool(n_pages=4, page_tokens=16, page_bytes=1000)
    pool.alloc_kv("s0", 16 * 3)
    with pytest.raises(OutOfPages):
        pool.alloc_kv("s1", 16 * 2)
    assert pool.used_pages == 3


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 60)),
                min_size=1, max_size=60),
       st.integers(8, 40))
def test_pool_invariant_random_ops(ops, n_pages):
    pool = UnifiedPagePool(n_pages=n_pages, page_tokens=8,
                           page_bytes=1000)
    live_kv = []
    for i, (op, arg) in enumerate(ops):
        try:
            if op == 0:
                sid = f"s{i}"
                pool.alloc_kv(sid, arg)
                live_kv.append(sid)
            elif op == 1 and live_kv:
                pool.grow_kv(live_kv[-1], arg + 60)
            elif op == 2 and live_kv:
                pool.free_kv(live_kv.pop())
            else:
                pool.ensure_adapter(f"a{arg % 5}", arg * 100)
        except OutOfPages:
            pass
        assert pool.check_invariant()
    assert pool.used_pages <= n_pages


def test_engine_with_page_pool():
    """Engine drives the unified pool: KV pages live per request, adapter
    pages pinned only while co-batched."""
    import time

    import jax

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pool = UnifiedPagePool(n_pages=512, page_tokens=8, page_bytes=50_000)
    eng = ServingEngine(cfg, params, {"a-r8": 8, "b-r64": 64},
                        max_batch=2, max_len=32, page_pool=pool)
    now = time.monotonic()
    for i in range(4):
        eng.submit(Request(i, ["a-r8", "b-r64"][i % 2],
                           list(range(1, 9)), 4, arrival=now))
    summ = eng.run_until_drained()
    assert summ["finished"] == 4
    assert pool.check_invariant()
    # all KV freed after drain; adapters may stay resident (cached)
    assert pool.pages_by_kind()["kv"] == 0
    assert pool.adapter_page_ins >= 2
