"""Algorithm 1 unit + property tests (hypothesis) — the paper's §IV-A
invariants."""
import math

import pytest
from hypothesis_shim import given, settings, st

from repro.core import AdapterInfo, PlacementContext, assign_loraserve
from repro.core.placement import _budgets

OPS = {8: 4000.0, 16: 3900.0, 32: 3700.0, 64: 3400.0, 128: 2900.0}
RANKS = sorted(OPS)


def make_ctx(n_servers, demands, prev=None):
    adapters = [AdapterInfo(aid, rank) for (aid, rank) in demands]
    return PlacementContext(
        n_servers=n_servers,
        adapters=adapters,
        demand_tps={aid: tps for (aid, _), tps in
                    zip(demands, [d[2] for d in demands])},
        operating_points=OPS,
        prev_placement=prev,
    )


def ctx_from(n_servers, triples, prev=None):
    adapters = [AdapterInfo(a, r) for a, r, _ in triples]
    return PlacementContext(
        n_servers=n_servers, adapters=adapters,
        demand_tps={a: d for a, _, d in triples},
        operating_points=OPS, prev_placement=prev)


def test_basic_placement_covers_all_adapters():
    triples = [(f"a{i}", RANKS[i % 5], 100.0 * (i + 1)) for i in range(20)]
    placement, stats = assign_loraserve(ctx_from(4, triples))
    assert set(placement) == {t[0] for t in triples}
    for aid, entry in placement.items():
        assert entry, aid
        assert abs(sum(entry.values()) - 1.0) < 1e-9
        assert all(0 <= s < 4 for s in entry)


def test_budgets_sum_to_servers():
    ru = {8: 2.0, 128: 1.5, 32: 0.4}
    b = _budgets(ru, sum(ru.values()) / 6, 6)
    assert sum(b.values()) == 6
    assert all(v >= 0 for v in b.values())


def test_hot_adapter_gets_split():
    """An adapter whose demand exceeds one server's operating point must
    be fractionally split (phi on >= 2 servers)."""
    triples = [("hot", 128, 8000.0)] + \
        [(f"c{i}", 8, 10.0) for i in range(10)]
    placement, _ = assign_loraserve(ctx_from(4, triples))
    assert len(placement["hot"]) >= 2


def test_rank_segregation_under_uniform_demand():
    """With balanced per-rank demand, servers should be rank-dominated:
    the same-rank adapters land together (Fig 12's 'LoRAServe' panel)."""
    triples = [(f"a{r}-{i}", r, 1000.0) for r in (8, 128) for i in range(4)]
    placement, _ = assign_loraserve(ctx_from(2, triples))
    # count utilization-weighted rank mix per server
    mix = {0: {8: 0.0, 128: 0.0}, 1: {8: 0.0, 128: 0.0}}
    for (aid, r, _) in triples:
        for sid, phi in placement[aid].items():
            mix[sid][r] += phi
    # each server must be dominated (>=70%) by a single rank — capacity
    # pressure may spill one fractional adapter (Algorithm 1 Step 4)
    doms = set()
    for sid, m in mix.items():
        tot = m[8] + m[128]
        dom = max(m, key=m.get)
        assert m[dom] / tot >= 0.7, f"server {sid} not rank-dominated: {m}"
        doms.add(dom)
    assert doms == {8, 128}    # the two ranks get distinct home servers


def test_permutation_minimizes_movement():
    triples = [(f"a{i}", RANKS[i % 5], 100.0 + i) for i in range(16)]
    p1, _ = assign_loraserve(ctx_from(4, triples))
    p2, stats = assign_loraserve(ctx_from(4, triples, prev=p1))
    # identical demand => the permuted placement should keep most
    # adapters on their previous servers
    same = sum(1 for aid in p1 if set(p1[aid]) & set(p2[aid]))
    assert same >= len(p1) * 0.75
    assert stats.moved_adapters <= len(p1) * 0.5


@settings(max_examples=50, deadline=None)
@given(
    n_servers=st.integers(min_value=1, max_value=12),
    data=st.lists(
        st.tuples(st.sampled_from(RANKS),
                  st.floats(min_value=0.0, max_value=1e5,
                            allow_nan=False)),
        min_size=1, max_size=60),
)
def test_placement_invariants(n_servers, data):
    """Property: every adapter placed, phi normalized, server ids valid —
    for arbitrary demand distributions including all-zero."""
    triples = [(f"a{i}", r, d) for i, (r, d) in enumerate(data)]
    placement, stats = assign_loraserve(ctx_from(n_servers, triples))
    assert set(placement) == {t[0] for t in triples}
    for aid, entry in placement.items():
        assert math.isclose(sum(entry.values()), 1.0, rel_tol=1e-6)
        assert all(phi > 0 for phi in entry.values())
        assert all(0 <= sid < n_servers for sid in entry)
    assert sum(stats.rank_server_budget.values()) == n_servers


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=100),
    n=st.integers(min_value=4, max_value=40),
)
def test_placement_deterministic(seed, n):
    import random
    rng = random.Random(seed)
    triples = [(f"a{i}", rng.choice(RANKS), rng.uniform(0, 5000))
               for i in range(n)]
    p1, _ = assign_loraserve(ctx_from(4, triples))
    p2, _ = assign_loraserve(ctx_from(4, triples))
    assert p1 == p2
