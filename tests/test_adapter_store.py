"""Tiered adapter data plane: async FetchPlan lifecycle, coalescing,
GC-vs-in-flight safety, source selection under link load, host-cache
tier, rebalance prefetch, remote-read serving — and migrate-vs-
remote-read token parity on the real JAX engine."""
import copy
import random

import jax
import jax.numpy as jnp
import pytest
from hypothesis_shim import given, settings, st

from repro.cluster import ClusterSimulator, NetworkModel
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, AdapterStore, ServeRequest
from repro.lora.bank import build_bank
from repro.models import model as M
from repro.serving import (EngineBackend, LoRAServeCluster, Request,
                           ServingEngine)
from repro.traces import make_adapters, synth_trace


def _store(n_servers=4, n_adapters=6, nbytes=200_000_000, **kw):
    adapters = [AdapterInfo(f"a{i}", 8, nbytes=nbytes)
                for i in range(n_adapters)]
    store = AdapterStore(n_servers, adapters, NetworkModel(), **kw)
    placement = {a.adapter_id: {i % n_servers: 1.0}
                 for i, a in enumerate(adapters)}
    store.seed(placement)
    return store, adapters, placement


# ---------------------------------------------------------------- async
def test_async_fetch_lifecycle():
    store, adapters, placement = _store()
    store.apply_placement({**placement, "a0": {1: 1.0}})
    plan = store.start_fetch(1, "a0", now=0.0)
    assert not plan.hit and plan.latency > 0.0
    assert plan.eta == pytest.approx(plan.latency)
    assert plan.src_server == 0 and plan.source == "ib_gdr"
    # transfer in flight: copy not installed, source link occupied
    assert "a0" not in store.local[1]
    assert store.next_event_time(0.0) == pytest.approx(plan.eta)
    assert store.network.link_load(0, plan.eta / 2) == 1
    assert store.poll(plan.eta / 2) == []
    done = store.poll(plan.eta)
    assert [p.adapter_id for p in done] == ["a0"]
    assert "a0" in store.local[1] and 1 in store.index["a0"]
    assert store.network.link_load(0, plan.eta) == 0
    assert store.next_event_time(plan.eta) is None


def test_duplicate_inflight_fetches_coalesce():
    store, _, _ = _store()
    p1 = store.start_fetch(1, "a0", now=0.0)
    p2 = store.start_fetch(1, "a0", now=0.1)
    assert p2.coalesced and p2.eta == pytest.approx(p1.eta)
    assert store.fetches == 1 and store.coalesced == 1
    assert len(store.poll(p1.eta)) == 1


def test_gc_skips_adapters_with_transfers_in_flight():
    """Regression (old `_gc`-on-hit bug): a hit must not delete a peer
    copy that an in-flight fetch on another server is reading from."""
    adapters = [AdapterInfo("a0", 8, nbytes=100_000_000),
                AdapterInfo("a1", 8, nbytes=100_000_000)]
    store = AdapterStore(4, adapters, NetworkModel())
    store.seed({"a0": {0: 0.5, 1: 0.5}, "a1": {3: 1.0}})
    # placement drops server 0's copy; migration is lazy
    store.apply_placement({"a0": {1: 1.0}, "a1": {3: 1.0}})
    # server 2 starts fetching a0 — source selection picks server 0
    plan = store.start_fetch(2, "a0", now=0.0)
    assert plan.src_server == 0
    # a *hit* on server 1 runs GC: with the old pool this deleted the
    # undesired server-0 copy mid-transfer; now GC must skip a0
    hit = store.start_fetch(1, "a0", now=0.1)
    assert hit.hit
    assert 0 in store.index["a0"], "in-flight source copy was GC'd"
    # once the transfer lands, delete-after-copy GC runs as usual
    store.poll(plan.eta)
    assert store.index["a0"] == {1}
    assert store.check_invariant()
    # the dropped copies were demoted to the host tier, not lost
    assert store.tier(0, "a0") == "host"


def test_prefetch_on_rebalance_warms_new_copies():
    store, _, placement = _store()
    new = dict(placement)
    new["a0"] = {2: 1.0}        # a0 moves 0 -> 2
    plans = store.apply_placement(new, now=5.0, prefetch=True)
    assert [p.adapter_id for p in plans] == ["a0"]
    assert plans[0].mode == "prefetch" and store.prefetches == 1
    store.poll(plans[0].eta)
    assert "a0" in store.local[2]
    # first routed access is now a hit — no lazy migrate-on-miss
    assert store.start_fetch(2, "a0", now=plans[0].eta).hit


def test_source_selection_prefers_unloaded_link():
    adapters = [AdapterInfo("a0", 8, nbytes=100_000_000)]
    store = AdapterStore(4, adapters, NetworkModel())
    store.seed({"a0": {0: 0.5, 1: 0.5}})
    # saturate server 0's egress with a fat unrelated transfer
    store.network.begin_transfer(2 << 30, "ib_gdr", now=0.0, src_server=0)
    plan = store.start_fetch(3, "a0", now=0.0)
    assert plan.src_server == 1, "should route around the loaded link"


def test_host_cache_tier_serves_refetches():
    store, _, placement = _store()
    # migrate a0 away; the old HBM copy demotes to server 0's host cache
    store.apply_placement({**placement, "a0": {1: 1.0}})
    store.ensure_local(1, "a0")
    assert store.index["a0"] == {1}
    assert store.tier(0, "a0") == "host"
    # flip back: the refetch reads the local host tier, not a peer
    store.apply_placement({**placement, "a0": {0: 1.0}})
    plan = store.start_fetch(0, "a0", now=10.0)
    assert plan.source == "local_host"
    assert plan.latency < store.network.transfer_latency(
        plan.nbytes, "ib_gdr")
    store.poll(plan.eta)
    assert store.tier(0, "a0") == "hbm"


def test_remote_read_plan_and_background_warm():
    store, _, placement = _store()
    store.apply_placement({**placement, "a0": {1: 1.0}})
    plan = store.start_remote_read(1, "a0", now=0.0)
    assert plan.mode == "remote-read" and not plan.hit
    assert plan.read_peer == 0 and plan.token_penalty > 0.0
    assert not plan.blocking and plan.eta > 0.0
    assert store.remote_reads == 1
    # remote reads cost less per iteration than a blocking migrate fetch
    assert plan.token_penalty < plan.latency
    store.poll(plan.eta)
    assert "a0" in store.local[1]
    assert store.start_remote_read(1, "a0", now=plan.eta).hit


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_invariant_under_interleaved_rebalance_and_async_fetches(seed):
    """Satellite: 'every adapter lives on >= 1 server' must hold under
    any interleaving of async fetches, remote reads, rebalances (with
    and without prefetch), and completion polls."""
    rng = random.Random(seed)
    store, adapters, _ = _store(n_servers=3, n_adapters=5)
    aids = [a.adapter_id for a in adapters]
    now = 0.0
    for _ in range(80):
        now += rng.random() * 0.05
        op = rng.random()
        if op < 0.4:
            store.start_fetch(rng.randrange(3), rng.choice(aids), now=now)
        elif op < 0.6:
            store.start_remote_read(rng.randrange(3), rng.choice(aids),
                                    now=now)
        elif op < 0.8:
            pl = {aid: {rng.randrange(3): 1.0} for aid in aids}
            store.apply_placement(pl, now=now,
                                  prefetch=rng.random() < 0.5)
        else:
            store.poll(now)
        assert store.check_invariant()
    store.poll(now + 1e9)
    assert store.check_invariant()
    assert store.total_bytes() >= max(a.nbytes for a in adapters)


# ------------------------------------------------------------ simulator
def test_simulator_remote_read_and_prefetch_end_to_end():
    adapters = make_adapters(12, seed=1)
    trace = synth_trace(adapters, rps=10, duration=60,
                        popularity="shifting", seed=2)

    def run(**kw):
        sim = ClusterSimulator(3, adapters, policy="loraserve", seed=3,
                               timeout=60, **kw)
        return sim.run(copy.deepcopy(trace))

    migrate = run()
    remote = run(access_mode="remote-read")
    pre = run(prefetch=True)
    for res in (migrate, remote, pre):
        assert res.completed() == len(trace)
    assert remote.remote_reads > 0
    assert pre.prefetches > 0
    assert migrate.remote_reads == 0 and migrate.prefetches == 0
    # remote-read never blocks on a fetch; migrate pays them on misses
    assert all(r.fetch_latency == 0.0 for r in remote.requests)
    assert any(r.fetch_latency > 0.0 for r in migrate.requests)


# ------------------------------------------------------- real JAX engine
@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trees_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("mode", ["padded", "bucketed"])
def test_bank_get_set_adapter_roundtrip(setup, mode):
    cfg, _ = setup
    bank = build_bank(cfg, {"a": 8, "b": 16, "c": 8},
                      jax.random.PRNGKey(1), mode=mode, n_layers=2)
    w = bank.get_adapter("b")
    assert w["q"]["A"].shape[-1] == 16
    before_a = bank.get_adapter("a")
    perturbed = jax.tree.map(lambda x: x + 1.0, w)
    bank2 = bank.set_adapter("b", perturbed)
    assert _trees_equal(bank2.get_adapter("b"), perturbed)
    assert _trees_equal(bank2.get_adapter("a"), before_a)


def test_engine_remote_install_serves_peer_bytes(setup):
    """install_adapter must serve the *peer's* bytes, not re-materialize
    locally: a perturbation on the peer propagates through the install,
    and unperturbed weights yield token-identical outputs."""
    cfg, params = setup
    eng0 = ServingEngine(cfg, params, {"a-r8": 8}, max_batch=2,
                         max_len=16)
    eng1 = ServingEngine(cfg, params, {"b-r16": 16}, max_batch=2,
                         max_len=16)
    w = eng0.adapter_weights("a-r8")
    eng1.install_adapter("a-r8", 8, w)
    assert _trees_equal(eng1.adapter_weights("a-r8"), w)
    # peer bytes, not local regeneration
    wp = jax.tree.map(lambda x: x + 0.5, w)
    eng0.lora_bank = eng0.lora_bank.set_adapter("a-r8", wp)
    eng0.bank = eng0.lora_bank.data
    eng2 = ServingEngine(cfg, params, {"b-r16": 16}, max_batch=2,
                         max_len=16)
    eng2.install_adapter("a-r8", 8, eng0.adapter_weights("a-r8"))
    assert _trees_equal(eng2.adapter_weights("a-r8"), wp)
    # token parity: local copy vs remote-installed copy
    prompt = list(range(1, 7))
    local = ServingEngine(cfg, params, {"a-r8": 8, "b-r16": 16},
                          max_batch=2, max_len=16)
    r_local = Request(0, "a-r8", prompt, 4)
    r_remote = Request(0, "a-r8", prompt, 4)
    local.submit(r_local)
    eng1.submit(r_remote)
    local.run_until_drained()
    eng1.run_until_drained()
    assert r_local.output == r_remote.output


def _mini_trace(adapters, cfg, n, duration):
    rng = random.Random(7)
    out = []
    for i in range(n):
        a = adapters[i % len(adapters)]
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(6)]
        out.append(ServeRequest(req_id=i, adapter_id=a.adapter_id,
                                rank=a.rank, prompt_len=6, output_len=3,
                                prompt=prompt,
                                arrival=i * duration / n))
    return out


def test_engine_backend_access_mode_token_parity(setup):
    """Acceptance: migrate and remote-read produce identical tokens on
    the real engine backend (remote reads serve bit-identical weights)."""
    cfg, params = setup
    adapters = [AdapterInfo(f"ad{i}-r{r}", r, nbytes=r * 1_000_000)
                for i, r in enumerate([8, 8, 16, 32, 64, 16])]
    trace = _mini_trace(adapters, cfg, 12, duration=1.2)

    def run(access_mode):
        reqs = copy.deepcopy(trace)
        backend = EngineBackend(cfg, params, 2, max_batch=2, max_len=16)
        cluster = LoRAServeCluster(
            backend, adapters, policy="loraserve",
            network=NetworkModel(), rebalance_period=0.4, seed=5,
            access_mode=access_mode, prefetch=False)
        report = cluster.run(reqs)
        return report, {r.req_id: list(r.output) for r in reqs}

    mig, mig_tokens = run("migrate")
    rem, rem_tokens = run("remote-read")
    assert mig.completed() == len(trace)
    assert rem.completed() == len(trace)
    assert rem.access_mode == "remote-read"
    assert all(toks for toks in mig_tokens.values())
    assert mig_tokens == rem_tokens
